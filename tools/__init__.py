"""Repo tooling namespace: stdlib-only CI gates that run before
dependency install (`tools.rtlint`, `tools/check_docs.py`) and the
shared machinery both build on (`tools.pylib`)."""
