"""Cached AST parsing + code-vs-docstring token classification."""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

_WORD = re.compile(r"[A-Za-z_]\w*")

#: parse cache: absolute path -> (mtime, PyFile)
_CACHE: dict[str, tuple[float, "PyFile"]] = {}


@dataclass
class PyFile:
    """One parsed Python source file.

    ``tree`` is ``None`` when the file does not parse (the gates skip
    unparseable files rather than crash — CI's syntax check is pytest's
    own collection, not ours). ``docstring_ids`` holds the ``id()`` of
    every docstring ``ast.Constant`` so visitors can classify string
    literals as code or prose in O(1).
    """

    path: str  # absolute, "" for in-memory sources
    rel: str  # repo-relative posix path (or the given pseudo-path)
    source: str
    tree: ast.AST | None
    docstring_ids: frozenset[int] = frozenset()
    lines: list[str] = field(default_factory=list)

    def line(self, lineno: int) -> str:
        """1-indexed physical source line ("" out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def docstring_exprs(tree: ast.AST) -> frozenset[int]:
    """``id()`` of every docstring string-Constant node in ``tree``."""
    ids: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(
            node,
            (ast.Module, ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef),
        ):
            body = node.body
            if (
                body
                and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)
            ):
                ids.add(id(body[0].value))
    return frozenset(ids)


def from_source(source: str, rel: str = "<memory>", path: str = "") -> PyFile:
    """Parse an in-memory source string (the lint test corpus path)."""
    try:
        tree = ast.parse(source)
    except SyntaxError:
        tree = None
    return PyFile(
        path=path,
        rel=rel,
        source=source,
        tree=tree,
        docstring_ids=docstring_exprs(tree) if tree is not None else frozenset(),
        lines=source.splitlines(),
    )


def load(path: str, root: str | None = None) -> PyFile:
    """Parse ``path`` through the cache (keyed by mtime)."""
    path = os.path.abspath(path)
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        mtime = -1.0
    hit = _CACHE.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    with open(path, encoding="utf-8") as f:
        source = f.read()
    rel = path
    if root:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
    pf = from_source(source, rel=rel, path=path)
    _CACHE[path] = (mtime, pf)
    return pf


def clear_cache() -> None:
    _CACHE.clear()


def code_words(pf: PyFile) -> set[str]:
    """Every identifier that appears in *code* (names, attributes,
    def/class names, args, keywords, import aliases) plus words inside
    non-docstring string literals. Comments and docstrings are excluded
    on purpose — a symbol that survives only in prose must not count as
    alive (the `tools/check_docs.py` contract)."""
    out: set[str] = set()
    if pf.tree is None:
        return out
    for node in ast.walk(pf.tree):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.arg):
            out.add(node.arg)
        elif isinstance(node, ast.keyword) and node.arg:
            out.add(node.arg)
        elif isinstance(node, ast.alias):
            for part in (node.name or "").split("."):
                out.add(part)
            if node.asname:
                out.add(node.asname)
        elif (
            isinstance(node, ast.Constant)
            and isinstance(node.value, str)
            and id(node) not in pf.docstring_ids
        ):
            out.update(_WORD.findall(node.value))
    return out
