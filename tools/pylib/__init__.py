"""Shared stdlib-only machinery for the repo's pre-dependency CI gates.

Both `tools/check_docs.py` (docs freshness) and `tools.rtlint` (the
real-time-invariant linter) need the same three primitives:

- a deterministic repo file walk (`repo_root`, `iter_files`),
- a cached AST parse of every Python file (`load`, `PyFile`),
- code-vs-docstring token classification (`docstring_exprs`,
  `code_words`) — identifiers that appear in *code* versus words that
  survive only in prose.

Everything here is importable with no third-party dependencies so the
gates run in CI before `pip install`.
"""
from tools.pylib.repo import CODE_DIRS, iter_files, repo_root
from tools.pylib.parse import (
    PyFile,
    clear_cache,
    code_words,
    docstring_exprs,
    from_source,
    load,
)

__all__ = [
    "CODE_DIRS",
    "PyFile",
    "clear_cache",
    "code_words",
    "docstring_exprs",
    "from_source",
    "iter_files",
    "load",
    "repo_root",
]
