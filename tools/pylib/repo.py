"""Deterministic repo file walk for the pre-dependency gates."""
from __future__ import annotations

import os

#: the top-level directories that hold Python code (the default walk)
CODE_DIRS = ("src", "benchmarks", "examples", "tests", "tools")

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache"}


def repo_root() -> str:
    """Absolute path of the repository root (two levels above here)."""
    return os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )


def rel_posix(path: str, root: str) -> str:
    """Repo-relative path with ``/`` separators (the lint/report key)."""
    return os.path.relpath(path, root).replace(os.sep, "/")


def iter_files(
    tops=CODE_DIRS,
    *,
    root: str | None = None,
    suffix: str | None = ".py",
):
    """Yield absolute file paths under ``tops``, sorted for determinism.

    ``suffix`` filters by extension (``None`` yields every file). Cache
    and VCS directories are skipped.
    """
    root = root or repo_root()
    for top in tops:
        base = os.path.join(root, top)
        if os.path.isfile(base):
            if suffix is None or base.endswith(suffix):
                yield base
            continue
        for dirpath, dirs, files in os.walk(base):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for f in sorted(files):
                if suffix is None or f.endswith(suffix):
                    yield os.path.join(dirpath, f)
