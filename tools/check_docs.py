#!/usr/bin/env python
"""Docs-freshness gate: fail CI when README/docs reference code that no
longer exists.

The check is deliberately grep-shaped (no repo imports, stdlib only) so
it runs before dependencies are installed:

1. Build a **live-symbol index** from every Python file under ``src/``,
   ``benchmarks/``, ``examples/``, ``tests/`` and ``tools/``: all
   identifiers that appear in *code* (names, attributes, def/class
   names, args, keywords, import aliases) plus words inside non-
   docstring string literals. Comments and docstrings are excluded on
   purpose — a removed symbol that survives only in prose ("the old
   ``virtual_period_scale`` quantization") must not count as alive.
   File/directory names and ``pyproject.toml``/workflow words join the
   index so module paths and CLI flags resolve.
2. Scan ``README.md`` and ``docs/*.md``. Every inline code span that
   *looks like code* (bare identifier, dotted path, repo path) must
   resolve: repo paths must exist on disk, identifiers and dotted
   components must be in the live index. Free-form spans (shell
   one-liners, math, prose) are skipped — this is a freshness check,
   not a linter.
3. A small **tombstone list** of symbols past PRs removed is checked
   against the full doc text: referencing one of them at all (outside
   an explicit "removed"/"old"/"retired" context sentence) fails.

The repo walk, the AST parse cache and the code-vs-docstring token
classification live in `tools.pylib` (shared with `tools/rtlint`).

Run: ``python tools/check_docs.py`` (from the repo root; CI does).
Exit 0 = fresh; exit 1 prints every stale reference with its file.
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # `python tools/check_docs.py` direct invocation
    sys.path.insert(0, ROOT)

from tools.pylib import CODE_DIRS, code_words, iter_files, load  # noqa: E402

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in (
        os.listdir(os.path.join(ROOT, "docs"))
        if os.path.isdir(os.path.join(ROOT, "docs"))
        else []
    )
    if f.endswith(".md")
)

#: path prefixes whose references must exist on disk
PATH_DIRS = (
    "src",
    "docs",
    "benchmarks",
    "examples",
    "tests",
    "tools",
    ".github",
)
#: generated-output prefixes: referenced paths need not exist in-tree
GENERATED_DIRS = ("experiments",)

#: symbols deliberately removed from the codebase: docs must not present
#: them as current API (mentioning them next to removed/old/retired is
#: fine — that is documentation of history)
TOMBSTONES = ("virtual_period_scale",)
_HISTORY_WORDS = ("removed", "old", "retired", "replaced", "gone", "era")

#: words that legitimately appear in backticks without being repo
#: symbols (tooling, ecosystems, spec words)
ALLOW = {
    "pip",
    "python",
    "bash",
    "git",
    "mermaid",
    "toml",
    "yaml",
    "yml",
    "json",
    "jax",
    "jnp",
    "numpy",
    "pallas",
    "pytest",
    "hypothesis",
    "ubuntu",
    "github",
    "tpu",
    "gemm",
    "fifo",
    "edf",
    "wcet",
    "wcets",
    "des",
    "dse",
    "srt",
    "llm",
    "rtos",
}

_IDENT = re.compile(r"[A-Za-z_]\w{2,}$")
_DOTTED = re.compile(r"[A-Za-z_][\w]*(\.[A-Za-z_*][\w]*)+$")
_PATHLIKE = re.compile(r"[\w.\[\]*-]+(/[\w.\[\]*-]+)+/?$")
_SPAN = re.compile(r"`([^`\n]+)`")
_WORD = re.compile(r"[A-Za-z_]\w*")


def build_index() -> set[str]:
    index: set[str] = set(ALLOW)
    for full in iter_files(CODE_DIRS, root=ROOT, suffix=None):
        rel_parts = os.path.relpath(full, ROOT).split(os.sep)
        for part in rel_parts:
            index.add(part)
            index.add(part.rsplit(".", 1)[0])
        if full.endswith(".py"):
            index.update(code_words(load(full, root=ROOT)))
    # top-level files + misc config words (flags, extras, job names)
    for f in os.listdir(ROOT):
        index.add(f)
        index.add(f.rsplit(".", 1)[0])
    for extra in ("pyproject.toml", os.path.join(".github", "workflows")):
        full = os.path.join(ROOT, extra)
        paths = (
            [os.path.join(full, f) for f in os.listdir(full)]
            if os.path.isdir(full)
            else [full]
        )
        for p in paths:
            if os.path.isfile(p):
                with open(p, encoding="utf-8") as fh:
                    index.update(_WORD.findall(fh.read()))
    return index


def check_span(span: str, index: set[str]) -> str | None:
    """Return a failure reason for one inline code span, or None."""
    s = span.strip().rstrip("=").removesuffix("()").strip()
    s = s.lstrip("-")  # CLI flags: --quick -> quick
    if not s:
        return None
    if _PATHLIKE.match(s) and "/" in s:
        path = s.rstrip("/")
        if path.startswith(GENERATED_DIRS):
            return None  # generated artifact; existence not required
        if path.startswith(PATH_DIRS):
            if any(c in path for c in "*[]"):
                return None  # glob: spot-check the literal prefix only
            if not os.path.exists(os.path.join(ROOT, path)):
                return f"path does not exist: {s!r}"
        return None
    s = s.rstrip("/")
    if _DOTTED.match(s):
        missing = [
            part
            for part in s.split(".")
            if len(part) >= 3 and part != "*" and part not in index
        ]
        if missing:
            return f"unknown symbol component(s) {missing} in {s!r}"
        return None
    if _IDENT.match(s):
        if s not in index:
            return f"unknown symbol: {s!r}"
        return None
    return None  # free-form span (command line, math, prose)


def check_doc(rel: str, index: set[str]) -> list[str]:
    with open(os.path.join(ROOT, rel), encoding="utf-8") as f:
        text = f.read()
    problems = []
    for m in _SPAN.finditer(text):
        reason = check_span(m.group(1), index)
        if reason:
            line = text.count("\n", 0, m.start()) + 1
            problems.append(f"{rel}:{line}: {reason}")
    for lineno, line in enumerate(text.splitlines(), 1):
        for dead in TOMBSTONES:
            if dead in line and not any(
                w in line.lower() for w in _HISTORY_WORDS
            ):
                problems.append(
                    f"{rel}:{lineno}: references removed symbol "
                    f"{dead!r} as if current"
                )
    return problems


def main() -> int:
    index = build_index()
    problems: list[str] = []
    for rel in DOC_FILES:
        problems.extend(check_doc(rel, index))
    if problems:
        print("stale documentation references:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(
        f"docs fresh: {len(DOC_FILES)} file(s) checked against "
        f"{len(index)} live symbols"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
