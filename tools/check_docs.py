#!/usr/bin/env python
"""Docs-freshness gate: fail CI when README/docs reference code that no
longer exists.

The check is deliberately grep-shaped (no repo imports, stdlib only) so
it runs before dependencies are installed:

1. Build a **live-symbol index** from every Python file under ``src/``,
   ``benchmarks/``, ``examples/``, ``tests/`` and ``tools/``: all
   identifiers that appear in *code* (names, attributes, def/class
   names, args, keywords, import aliases) plus words inside non-
   docstring string literals. Comments and docstrings are excluded on
   purpose — a removed symbol that survives only in prose ("the old
   ``virtual_period_scale`` quantization") must not count as alive.
   File/directory names and ``pyproject.toml``/workflow words join the
   index so module paths and CLI flags resolve.
2. Scan ``README.md`` and ``docs/*.md``. Every inline code span that
   *looks like code* (bare identifier, dotted path, repo path) must
   resolve: repo paths must exist on disk, identifiers and dotted
   components must be in the live index. Free-form spans (shell
   one-liners, math, prose) are skipped — this is a freshness check,
   not a linter.
3. A small **tombstone list** of symbols past PRs removed is checked
   against the full doc text: referencing one of them at all (outside
   an explicit "removed"/"old"/"retired" context sentence) fails.

Run: ``python tools/check_docs.py`` (from the repo root; CI does).
Exit 0 = fresh; exit 1 prints every stale reference with its file.
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in (
        os.listdir(os.path.join(ROOT, "docs"))
        if os.path.isdir(os.path.join(ROOT, "docs"))
        else []
    )
    if f.endswith(".md")
)

#: path prefixes whose references must exist on disk
PATH_DIRS = (
    "src",
    "docs",
    "benchmarks",
    "examples",
    "tests",
    "tools",
    ".github",
)
#: generated-output prefixes: referenced paths need not exist in-tree
GENERATED_DIRS = ("experiments",)

#: symbols deliberately removed from the codebase: docs must not present
#: them as current API (mentioning them next to removed/old/retired is
#: fine — that is documentation of history)
TOMBSTONES = ("virtual_period_scale",)
_HISTORY_WORDS = ("removed", "old", "retired", "replaced", "gone", "era")

#: words that legitimately appear in backticks without being repo
#: symbols (tooling, ecosystems, spec words)
ALLOW = {
    "pip",
    "python",
    "bash",
    "git",
    "mermaid",
    "toml",
    "yaml",
    "yml",
    "json",
    "jax",
    "jnp",
    "numpy",
    "pallas",
    "pytest",
    "hypothesis",
    "ubuntu",
    "github",
    "tpu",
    "gemm",
    "fifo",
    "edf",
    "wcet",
    "wcets",
    "des",
    "dse",
    "srt",
    "llm",
    "rtos",
}

_IDENT = re.compile(r"[A-Za-z_]\w{2,}$")
_DOTTED = re.compile(r"[A-Za-z_][\w]*(\.[A-Za-z_*][\w]*)+$")
_PATHLIKE = re.compile(r"[\w.\[\]*-]+(/[\w.\[\]*-]+)+/?$")
_SPAN = re.compile(r"`([^`\n]+)`")
_WORD = re.compile(r"[A-Za-z_]\w*")


def _index_python(path: str, index: set[str]) -> None:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src)
    except SyntaxError:
        return
    docstrings: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(
            node,
            (ast.Module, ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef),
        ):
            body = node.body
            if (
                body
                and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)
            ):
                docstrings.add(id(body[0].value))
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            index.add(node.id)
        elif isinstance(node, ast.Attribute):
            index.add(node.attr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            index.add(node.name)
        elif isinstance(node, ast.arg):
            index.add(node.arg)
        elif isinstance(node, ast.keyword) and node.arg:
            index.add(node.arg)
        elif isinstance(node, ast.alias):
            for part in (node.name or "").split("."):
                index.add(part)
            if node.asname:
                index.add(node.asname)
        elif (
            isinstance(node, ast.Constant)
            and isinstance(node.value, str)
            and id(node) not in docstrings
        ):
            index.update(_WORD.findall(node.value))


def build_index() -> set[str]:
    index: set[str] = set(ALLOW)
    for top in CODE_DIRS:
        for dirpath, _dirs, files in os.walk(os.path.join(ROOT, top)):
            for f in files:
                full = os.path.join(dirpath, f)
                rel_parts = os.path.relpath(full, ROOT).split(os.sep)
                for part in rel_parts:
                    index.add(part)
                    index.add(part.rsplit(".", 1)[0])
                if f.endswith(".py"):
                    _index_python(full, index)
    # top-level files + misc config words (flags, extras, job names)
    for f in os.listdir(ROOT):
        index.add(f)
        index.add(f.rsplit(".", 1)[0])
    for extra in ("pyproject.toml", os.path.join(".github", "workflows")):
        full = os.path.join(ROOT, extra)
        paths = (
            [os.path.join(full, f) for f in os.listdir(full)]
            if os.path.isdir(full)
            else [full]
        )
        for p in paths:
            if os.path.isfile(p):
                with open(p, encoding="utf-8") as fh:
                    index.update(_WORD.findall(fh.read()))
    return index


def check_span(span: str, index: set[str]) -> str | None:
    """Return a failure reason for one inline code span, or None."""
    s = span.strip().rstrip("=").removesuffix("()").strip()
    s = s.lstrip("-")  # CLI flags: --quick -> quick
    if not s:
        return None
    if _PATHLIKE.match(s) and "/" in s:
        path = s.rstrip("/")
        if path.startswith(GENERATED_DIRS):
            return None  # generated artifact; existence not required
        if path.startswith(PATH_DIRS):
            if any(c in path for c in "*[]"):
                return None  # glob: spot-check the literal prefix only
            if not os.path.exists(os.path.join(ROOT, path)):
                return f"path does not exist: {s!r}"
        return None
    s = s.rstrip("/")
    if _DOTTED.match(s):
        missing = [
            part
            for part in s.split(".")
            if len(part) >= 3 and part != "*" and part not in index
        ]
        if missing:
            return f"unknown symbol component(s) {missing} in {s!r}"
        return None
    if _IDENT.match(s):
        if s not in index:
            return f"unknown symbol: {s!r}"
        return None
    return None  # free-form span (command line, math, prose)


def check_doc(rel: str, index: set[str]) -> list[str]:
    with open(os.path.join(ROOT, rel), encoding="utf-8") as f:
        text = f.read()
    problems = []
    for m in _SPAN.finditer(text):
        reason = check_span(m.group(1), index)
        if reason:
            line = text.count("\n", 0, m.start()) + 1
            problems.append(f"{rel}:{line}: {reason}")
    for lineno, line in enumerate(text.splitlines(), 1):
        for dead in TOMBSTONES:
            if dead in line and not any(
                w in line.lower() for w in _HISTORY_WORDS
            ):
                problems.append(
                    f"{rel}:{lineno}: references removed symbol "
                    f"{dead!r} as if current"
                )
    return problems


def main() -> int:
    index = build_index()
    problems: list[str] = []
    for rel in DOC_FILES:
        problems.extend(check_doc(rel, index))
    if problems:
        print("stale documentation references:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(
        f"docs fresh: {len(DOC_FILES)} file(s) checked against "
        f"{len(index)} live symbols"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
