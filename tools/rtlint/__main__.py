"""Bootstrap so both ``python -m tools.rtlint`` (repo root on path)
and ``python tools/rtlint/__main__.py`` (it is not) resolve the
``tools.*`` package imports."""
import os
import sys

_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.rtlint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
