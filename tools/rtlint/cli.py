"""Command-line entry point: ``python -m tools.rtlint``."""
from __future__ import annotations

import argparse
import json
import os
import sys

from tools.pylib import iter_files, repo_root
from tools.rtlint import RULES, lint_paths
from tools.rtlint.config import load_config
import tools.rtlint.rules  # noqa: F401  (populate the registry)

#: directories scanned when neither config nor CLI names paths
DEFAULT_SCAN = ("src", "benchmarks", "examples", "tools")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="rtlint",
        description=(
            "Real-time-invariant static analysis (stdlib-only; runs "
            "before dependency install). See docs/static-analysis.md."
        ),
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories to lint (default: the configured scan "
        "roots; cross-file checks are skipped for explicit paths)",
    )
    ap.add_argument(
        "--format",
        choices=("human", "json", "github"),
        default="human",
        help="human lines, GitHub-annotation JSON, or GitHub workflow "
        "commands",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="warnings also fail (default: only error severity fails)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    ap.add_argument(
        "--root", default=None, help="repo root (default: autodetected)"
    )
    ap.add_argument(
        "--no-config",
        action="store_true",
        help="ignore the [tool.rtlint] pyproject block (rule defaults "
        "only)",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            r = RULES[name]
            print(f"{name:16s} [{r.severity}] {r.description}")
        return 0

    root = os.path.abspath(args.root) if args.root else repo_root()
    config = {} if args.no_config else load_config(root)

    partial = bool(args.paths)
    if partial:
        paths: list[str] = []
        for p in args.paths:
            full = p if os.path.isabs(p) else os.path.join(root, p)
            if os.path.isdir(full):
                paths.extend(iter_files((full,), root=root))
            else:
                paths.append(full)
    else:
        tops = tuple(config.get("include", DEFAULT_SCAN))
        paths = list(iter_files(tops, root=root))

    findings = lint_paths(paths, root, config=config, partial=partial)

    errors = [f for f in findings if f.severity == "error"]
    warnings = [f for f in findings if f.severity != "error"]

    if args.format == "json":
        print(json.dumps([f.json_obj() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.github() if args.format == "github" else f.human())

    failed = bool(errors) or (args.strict and bool(warnings))
    if args.format != "json":
        if failed:
            print(
                f"rtlint: {len(errors)} error(s), {len(warnings)} "
                f"warning(s) across {len(paths)} file(s)",
                file=sys.stderr,
            )
        else:
            extra = (
                f", {len(warnings)} warning(s)" if warnings else ""
            )
            print(
                f"rtlint clean: {len(paths)} file(s) against "
                f"{len(RULES)} rule(s){extra}"
            )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
