"""obs-contract: tracing handles are resolved once, never per event.

`repro.obs.trace.TraceRecorder`'s zero-overhead-when-disabled
guarantee (CI-enforced by ``benchmarks/obs_bench.py``) rests on the
resolve-once idiom: each instrumented run evaluates
``tr = trace if trace is not None and trace.enabled else None`` (or
``trace.sink()``) *once*, then guards emissions with ``if tr is not
None``. Re-resolving inside a loop — a per-event ``recorder.enabled``
read, a ``getattr(trace, "enabled", ...)``, or worse a fresh
``.sink()`` — re-introduces per-event overhead for disabled tracing
and, for ``sink()``, re-snapshots sticky annotations mid-run.

Flagged inside ``for``/``while`` bodies and comprehensions, on
trace-ish receivers only:

- ``.sink(...)`` calls — hoist the handle above the loop;
- ``.enabled`` attribute reads and ``getattr(x, "enabled", ...)`` —
  resolve once to a nullable handle instead.
"""
from __future__ import annotations

import ast

from tools.pylib import PyFile
from tools.rtlint import Finding, LintContext, Rule, register
from tools.rtlint.astutil import LoopAwareVisitor, dotted, last_ident

_TRACEISH = ("tr", "_tr", "trace", "recorder", "rec")


def _traceish(node: ast.AST) -> bool:
    name = (last_ident(node) or "").lower()
    return (
        name in _TRACEISH
        or "trace" in name
        or "recorder" in name
        or name.endswith("_tr")
    )


@register
class ObsContractRule(Rule):
    name = "obs-contract"
    description = (
        "per-event trace-handle resolution (.enabled reads / .sink() "
        "calls) inside loops breaks the resolve-once zero-overhead "
        "contract"
    )
    severity = "error"
    include = (
        "src/repro/scheduler/**",
        "src/repro/pipeline/**",
        "src/repro/traffic/**",
        "src/repro/conformance/**",
    )

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        assert pf.tree is not None
        rule = self
        out: list[Finding] = []

        class V(LoopAwareVisitor):
            def visit_Call(self, node: ast.Call) -> None:
                if self.in_loop:
                    fn = node.func
                    if (
                        isinstance(fn, ast.Attribute)
                        and fn.attr == "sink"
                        and _traceish(fn.value)
                    ):
                        out.append(
                            rule.finding(
                                pf,
                                node,
                                ".sink() resolved inside a loop: hoist "
                                "the handle above the loop (resolve-"
                                "once contract, repro.obs.trace)",
                                ctx,
                            )
                        )
                    elif (
                        isinstance(fn, ast.Name)
                        and fn.id == "getattr"
                        and len(node.args) >= 2
                        and isinstance(node.args[1], ast.Constant)
                        and node.args[1].value == "enabled"
                        and _traceish(node.args[0])
                    ):
                        out.append(
                            rule.finding(
                                pf,
                                node,
                                'per-event getattr(..., "enabled") '
                                "inside a loop: resolve the trace "
                                "handle once before the loop",
                                ctx,
                            )
                        )
                self.generic_visit(node)

            def visit_Attribute(self, node: ast.Attribute) -> None:
                if (
                    self.in_loop
                    and node.attr == "enabled"
                    and _traceish(node.value)
                ):
                    out.append(
                        rule.finding(
                            pf,
                            node,
                            "per-event .enabled read inside a loop: "
                            "resolve the trace handle once before the "
                            "loop (tr = trace if trace is not None "
                            "and trace.enabled else None)",
                            ctx,
                        )
                    )
                self.generic_visit(node)

        V().visit(pf.tree)
        return out
