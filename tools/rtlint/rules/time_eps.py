"""time-eps: no exact float equality between time-typed expressions.

Model time in this repo is accumulated floating point (event
timestamps, response bounds, Eq. 3 slacks). Two independently-derived
time values that are *mathematically* equal are not *bitwise* equal
after different accumulation orders, so ``a == b`` / ``a != b``
between time-typed expressions is a latent boundary bug — the Eq. 3
boundary uses the module EPS idiom instead
(`repro.core.rt.schedulability.EPS`: clamp or compare within the
band).

Exact comparisons against literals, ``math.inf`` / ``float("inf")``
and ``None`` stay legal (sentinels and saturation checks are exact by
construction), and any line that already mentions an EPS/tolerance
token is trusted.
"""
from __future__ import annotations

import ast

from tools.pylib import PyFile
from tools.rtlint import Finding, LintContext, Rule, register
from tools.rtlint.astutil import dotted, last_ident

#: identifiers treated as time-typed, exactly ...
_TIME_NAMES = frozenset(
    {
        "t",
        "t0",
        "t1",
        "dt",
        "now",
        "rel",
        "release",
        "deadline",
        "abs_deadline",
        "horizon",
        "period",
        "phase",
        "slack",
        "wcet",
        "arrival",
        "jitter",
        "tardiness",
        "response",
        "busy_until",
        "block_until",
        "run_start",
    }
)
#: ... or by substring
_TIME_SUBSTR = (
    "time",
    "deadline",
    "release",
    "horizon",
    "period",
    "arrival",
    "wcet",
    "slack",
    "latency",
)
#: tokens on the source line that signal an explicit tolerance idiom
_EPS_TOKENS = ("EPS", "eps", "tol", "1e-")


def _is_time_ident(name: str | None) -> bool:
    if not name:
        return False
    low = name.lower()
    return (
        low in _TIME_NAMES
        or low.endswith("_t")
        or low.endswith("_s")
        or any(s in low for s in _TIME_SUBSTR)
    )


def _is_time_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.BinOp):
        return _is_time_expr(node.left) or _is_time_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_time_expr(node.operand)
    return _is_time_ident(last_ident(node))


def _is_exact_operand(node: ast.AST) -> bool:
    """Literals, +-inf and None compare exactly by construction."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp):
        return _is_exact_operand(node.operand)
    if (dotted(node) or "") in ("math.inf", "math.nan", "np.inf", "numpy.inf"):
        return True
    if isinstance(node, ast.Call):
        fn = dotted(node.func) or ""
        if fn == "float" and node.args:
            return _is_exact_operand(node.args[0]) or (
                isinstance(node.args[0], ast.Constant)
            )
        if fn in ("math.isinf", "math.isnan"):
            return True
    return False


@register
class TimeEpsRule(Rule):
    name = "time-eps"
    description = (
        "exact ==/!= between float time-typed expressions; use the "
        "module EPS idiom"
    )
    severity = "error"
    include = ("src/repro/core/rt/**", "src/repro/scheduler/**")

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        assert pf.tree is not None
        out: list[Finding] = []
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Compare):
                continue
            line_text = pf.line(node.lineno)
            if any(tok in line_text for tok in _EPS_TOKENS):
                continue  # explicit tolerance idiom on this line
            operands = [node.left] + list(node.comparators)
            for op, lhs, rhs in zip(node.ops, operands, operands[1:]):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                if _is_exact_operand(lhs) or _is_exact_operand(rhs):
                    continue
                if _is_time_expr(lhs) and _is_time_expr(rhs):
                    sym = "==" if isinstance(op, ast.Eq) else "!="
                    out.append(
                        self.finding(
                            pf,
                            node,
                            f"exact float `{sym}` between time-typed "
                            "expressions: accumulated model time is "
                            "not bitwise-stable — compare within the "
                            "module EPS band "
                            "(repro.core.rt.schedulability.EPS)",
                            ctx,
                        )
                    )
        return out
