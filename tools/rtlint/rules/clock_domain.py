"""clock-domain: no wall-clock reads inside model-timebase code.

Every schedulability claim in this repo assumes one deterministic
timebase: the DES's event clock, the runtime's injected
``Clock``/``sleep`` callables, the gateway's shared ``clk``. A stray
``time.time()`` / ``time.perf_counter()`` / ``time.sleep()`` /
``datetime.now()`` in those paths silently mixes wall time into model
time — runs stop being reproducible and the analysis <-> DES <->
runtime conformance contract stops meaning anything.

The rule flags any *reference* (call or bare attribute — wall clocks
leak in as default arguments too) to a wall-clock symbol. Allowed
homes are configured per directory in ``pyproject.toml``
(``[tool.rtlint.rules.clock-domain]``): the `WallClock` implementation
itself, the wall-clock benches, training-launch timing, and DSE
search-statistics; anything else needs an inline suppression with a
rationale.
"""
from __future__ import annotations

import ast

from tools.pylib import PyFile
from tools.rtlint import Finding, LintContext, Rule, register
from tools.rtlint.astutil import dotted

#: wall-clock reads/sleeps by dotted name (module-qualified and the
#: common ``from datetime import datetime`` spelling)
WALL_CLOCK_SYMBOLS = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.process_time",
        "time.process_time_ns",
        "time.sleep",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.now",
        "datetime.utcnow",
        "datetime.today",
        "datetime.date.today",
        "date.today",
    }
)


@register
class ClockDomainRule(Rule):
    name = "clock-domain"
    description = (
        "wall-clock reads (time.*, datetime.now) are forbidden in "
        "model-timebase code; use the injected Clock"
    )
    severity = "error"
    include = ("src/**",)
    exclude = ("src/repro/traffic/clock.py",)

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        out: list[Finding] = []
        assert pf.tree is not None
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Attribute):
                continue
            name = dotted(node)
            if name in WALL_CLOCK_SYMBOLS:
                out.append(
                    self.finding(
                        pf,
                        node,
                        f"wall-clock reference `{name}` in model-"
                        "timebase code: inject a Clock "
                        "(repro.traffic.clock) or scope this "
                        "directory out in [tool.rtlint.rules.clock-domain]",
                        ctx,
                    )
                )
        return out
