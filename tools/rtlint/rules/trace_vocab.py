"""trace-vocab: every trace event kind is canonical, every kind emitted.

The 13-kind event vocabulary in ``src/repro/obs/trace.py``
(``EVENT_KINDS``) is the cross-layer schedule contract: the DES, the
runtime, the gateway and every consumer (metrics, diff, Chrome export)
agree on it. A typo'd kind string silently drops events from metrics
and diffs — no exception, just wrong numbers.

Checked, per file:

- ``<recorder>.emit("<kind>", ...)`` calls (first positional or
  ``kind=``) on trace-ish receivers;
- compact sink-row calls ``tr((t, "<kind>", ...))`` where ``tr`` was
  bound from a ``.sink()`` resolve;
- ``<recorder>.stream(kind="<kind>")`` filters;
- comparisons against ``<event>.kind`` where the receiver is an
  event-ish name (``e`` / ``ev`` / ``event``; other ``.kind``
  attributes — arrival specs, launch cases, dtypes — are unrelated
  vocabularies and are left alone);
- tuple/list/set literals assigned to ``*KINDS`` names whose name ties
  them to the trace vocabulary (contains ``EVENT``/``TRACE``/``DIFF``,
  e.g. ``DEFAULT_DIFF_KINDS``); other ``*_KINDS`` constants (e.g.
  ``_ARRIVAL_KINDS``) are different vocabularies.

Cross-file (`finalize`): every declared kind must have at least one
emitter in the scanned tree, so the vocabulary cannot grow dead
entries.
"""
from __future__ import annotations

import ast
import os

from tools.pylib import PyFile, load
from tools.rtlint import Finding, LintContext, Rule, register
from tools.rtlint.astutil import dotted, last_ident, str_consts

#: the vocabulary's home, relative to the repo root
VOCAB_FILE = "src/repro/obs/trace.py"

_TRACEISH = ("tr", "_tr", "trace", "recorder", "rec")


def _traceish(receiver: ast.AST) -> bool:
    name = (last_ident(receiver) or "").lower()
    return (
        name in _TRACEISH
        or "trace" in name
        or "recorder" in name
        or name.endswith("_tr")
    )


#: receivers whose ``.kind`` is a trace event's kind (vs. arrival
#: specs, launch cases, numpy dtypes, violations, ... which also have
#: a ``.kind`` but a different vocabulary)
_EVENTISH = ("e", "ev", "evt", "event")


def _eventish(receiver: ast.AST) -> bool:
    name = (last_ident(receiver) or "").lower()
    return name in _EVENTISH or "event" in name


def _vocab_tied(const_name: str) -> bool:
    up = const_name.upper()
    return "EVENT" in up or "TRACE" in up or "DIFF" in up


def _sink_bound_names(tree: ast.AST) -> set[str]:
    """Names assigned from an expression containing a ``.sink()`` call
    (e.g. ``tr = cfg.trace.sink() if ... else None``)."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        has_sink = any(
            isinstance(sub, ast.Call)
            and isinstance(sub.func, ast.Attribute)
            and sub.func.attr == "sink"
            for sub in ast.walk(node.value)
        )
        if has_sink:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


def _load_vocab(ctx: LintContext):
    """(vocab frozenset, decl file rel, decl line) or None when
    unavailable — from config override or the canonical trace module."""
    if "trace_vocab" in ctx.shared:
        return ctx.shared["trace_vocab"]
    cfg_vocab = ctx.rule_config("trace-vocab").get("vocab")
    result = None
    if cfg_vocab:
        result = (frozenset(cfg_vocab), VOCAB_FILE, 1)
    elif ctx.root:
        path = os.path.join(ctx.root, VOCAB_FILE)
        if os.path.isfile(path):
            pf = load(path, root=ctx.root)
            if pf.tree is not None:
                for node in ast.walk(pf.tree):
                    if (
                        isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == "EVENT_KINDS"
                    ):
                        kinds = [v for _n, v in str_consts(node.value)]
                        result = (
                            frozenset(kinds), VOCAB_FILE, node.lineno
                        )
                        break
    ctx.shared["trace_vocab"] = result
    return result


@register
class TraceVocabRule(Rule):
    name = "trace-vocab"
    description = (
        "trace event-kind strings must be members of the canonical "
        "EVENT_KINDS vocabulary, and every kind must have an emitter"
    )
    severity = "error"
    include = ("src/**", "benchmarks/**", "examples/**")

    def _flag(self, pf, node, kind, ctx, how: str) -> Finding:
        return self.finding(
            pf,
            node,
            f"event kind {kind!r} ({how}) is not in the canonical "
            f"trace vocabulary (EVENT_KINDS in {VOCAB_FILE}) — fix "
            "the string or extend the vocabulary",
            ctx,
        )

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        loaded = _load_vocab(ctx)
        if loaded is None:
            return []
        vocab, _, _ = loaded
        assert pf.tree is not None
        out: list[Finding] = []
        emitted: set[str] = ctx.shared.setdefault("trace_emitted", set())
        sink_names = _sink_bound_names(pf.tree)
        in_vocab_module = pf.rel == VOCAB_FILE

        def check_kind(node, kind, how, *, is_emitter=False):
            if is_emitter:
                emitted.add(kind)
            if kind not in vocab:
                out.append(self._flag(pf, node, kind, ctx, how))

        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Call):
                fn = node.func
                if isinstance(fn, ast.Attribute) and fn.attr in (
                    "emit",
                    "stream",
                ):
                    if not _traceish(fn.value):
                        continue
                    arg = None
                    if fn.attr == "emit" and node.args:
                        arg = node.args[0]
                    for kw in node.keywords:
                        if kw.arg == "kind":
                            arg = kw.value
                    if arg is not None:
                        for n, kind in str_consts(arg):
                            check_kind(
                                n,
                                kind,
                                f"passed to .{fn.attr}()",
                                is_emitter=(fn.attr == "emit"),
                            )
                elif (
                    isinstance(fn, ast.Name)
                    and fn.id in sink_names
                    and len(node.args) == 1
                    and isinstance(node.args[0], ast.Tuple)
                    and len(node.args[0].elts) >= 2
                ):
                    for n, kind in str_consts(node.args[0].elts[1]):
                        check_kind(
                            n, kind, "in a sink row", is_emitter=True
                        )
            elif isinstance(node, ast.Compare):
                sides = [node.left] + list(node.comparators)
                kind_side = any(
                    isinstance(s, ast.Attribute)
                    and s.attr == "kind"
                    and _eventish(s.value)
                    for s in sides
                )
                if not kind_side:
                    continue
                for s in sides:
                    for n, kind in str_consts(s):
                        check_kind(n, kind, "compared against .kind")
            elif (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id.endswith("KINDS")
                and _vocab_tied(node.targets[0].id)
                and not (
                    in_vocab_module
                    and node.targets[0].id == "EVENT_KINDS"
                )
            ):
                for n, kind in str_consts(node.value):
                    check_kind(
                        n,
                        kind,
                        f"in {node.targets[0].id}",
                    )
        return out

    def finalize(self, ctx: LintContext) -> list[Finding]:
        if ctx.shared.get("partial"):
            return []  # explicit-path run: emitters were not all scanned
        loaded = _load_vocab(ctx)
        if loaded is None:
            return []
        vocab, rel, lineno = loaded
        emitted = ctx.shared.get("trace_emitted", set())
        out: list[Finding] = []
        for kind in sorted(vocab - emitted):
            out.append(
                Finding(
                    rule=self.name,
                    rel=rel,
                    line=lineno,
                    col=1,
                    message=(
                        f"declared event kind {kind!r} has no emitter "
                        "anywhere in the tree — remove it from "
                        "EVENT_KINDS or instrument the layer that "
                        "should emit it"
                    ),
                    severity=self.effective_severity(ctx),
                )
            )
        return out
