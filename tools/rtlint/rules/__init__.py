"""Rule modules register themselves on import (see `tools.rtlint.register`)."""
from tools.rtlint.rules import (  # noqa: F401
    clock_domain,
    determinism,
    obs_contract,
    time_eps,
    trace_vocab,
)
