"""determinism: bit-stable ordering in scheduler/trace hot paths.

The DES's event heap, the gateway's release loop and the trace streams
promise *bit-identical* replays for identical seeds (the conformance
harness and `tests/test_determinism.py` hold them to it). Three code
shapes quietly break that promise:

- order-sensitive iteration over a ``set`` (hash order varies with
  PYTHONHASHSEED for str/object elements) or an *unsorted* dict view
  whose insertion order is not itself pinned;
- bare ``random.*`` / ``np.random.*`` module-level calls (global,
  unseeded state) instead of a seeded ``random.Random(seed)`` /
  ``np.random.default_rng(seed)`` generator;
- ``id()``-based keys or tie-breaking — CPython ids are allocation
  addresses, different every run.

Iteration feeding order-insensitive reducers (``any``/``all``/``sum``
of ints/``len``/membership) is not flagged; ``sorted(...)`` is always
fine.
"""
from __future__ import annotations

import ast

from tools.pylib import PyFile
from tools.rtlint import Finding, LintContext, Rule, register
from tools.rtlint.astutil import dotted

_DICT_VIEWS = ("keys", "values", "items")
#: calls whose argument order reaches the output
_ORDER_SENSITIVE_CALLS = ("list", "tuple", "enumerate", "reversed", "iter")
_SEEDED_RANDOM = ("Random", "SystemRandom")
_SEEDED_NP_RANDOM = ("default_rng", "SeedSequence", "Generator", "Philox", "PCG64")


def _is_set_expr(node: ast.AST, set_names: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        if node.func.id in ("set", "frozenset"):
            return True
    if isinstance(node, ast.Name) and node.id in set_names:
        return True
    return False


def _is_dict_view(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and not node.args
        and not node.keywords
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in _DICT_VIEWS
    )


def _set_typed_names(tree: ast.AST) -> set[str]:
    """Names assigned (or annotated) a set anywhere in the file — a
    deliberately simple, file-local inference."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            if _is_set_expr(node.value, set()):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
        elif isinstance(node, ast.AnnAssign):
            ann = ast.unparse(node.annotation) if node.annotation else ""
            if isinstance(node.target, ast.Name) and (
                ann.startswith("set") or ann.startswith("frozenset")
            ):
                names.add(node.target.id)
    return names


@register
class DeterminismRule(Rule):
    name = "determinism"
    description = (
        "hash-order iteration, unseeded randomness and id()-based "
        "keys are forbidden in deterministic scheduler paths"
    )
    severity = "error"
    include = (
        "src/repro/scheduler/**",
        "src/repro/traffic/**",
        "src/repro/obs/**",
    )

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        assert pf.tree is not None
        out: list[Finding] = []
        set_names = _set_typed_names(pf.tree)

        def flag_iter_expr(node: ast.AST) -> None:
            if _is_set_expr(node, set_names):
                out.append(
                    self.finding(
                        pf,
                        node,
                        "order-sensitive iteration over a set (hash "
                        "order): iterate sorted(...) or use a list/dict",
                        ctx,
                    )
                )
            elif _is_dict_view(node):
                view = node.func.attr  # type: ignore[union-attr]
                out.append(
                    self.finding(
                        pf,
                        node,
                        f"order-sensitive iteration over an unsorted "
                        f"dict .{view}() view: wrap in sorted(...) or "
                        "suppress with a rationale pinning the "
                        "insertion order",
                        ctx,
                    )
                )

        for node in ast.walk(pf.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                flag_iter_expr(node.iter)
            elif isinstance(
                node,
                (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp),
            ):
                for gen in node.generators:
                    flag_iter_expr(gen.iter)
            elif isinstance(node, ast.Call):
                fn = dotted(node.func) or ""
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in _ORDER_SENSITIVE_CALLS
                    and node.args
                ):
                    flag_iter_expr(node.args[0])
                # unseeded module-level randomness
                mod, _, leaf = fn.rpartition(".")
                if mod == "random" and leaf not in _SEEDED_RANDOM:
                    out.append(
                        self.finding(
                            pf,
                            node,
                            f"unseeded global randomness `{fn}()`: use "
                            "a seeded random.Random(seed) generator",
                            ctx,
                        )
                    )
                elif (
                    mod in ("np.random", "numpy.random")
                    and leaf not in _SEEDED_NP_RANDOM
                ):
                    out.append(
                        self.finding(
                            pf,
                            node,
                            f"unseeded global randomness `{fn}()`: use "
                            "np.random.default_rng(seed)",
                            ctx,
                        )
                    )
                elif fn == "id":
                    out.append(
                        self.finding(
                            pf,
                            node,
                            "id() is an allocation address — different "
                            "every run; never use it for ordering or "
                            "keys (suppress with a rationale if it is "
                            "pure identity membership)",
                            ctx,
                        )
                    )
        return out
