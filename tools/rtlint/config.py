"""Minimal TOML reader for the ``[tool.rtlint]`` config block.

Python 3.10 has no ``tomllib`` and the gate must not install
dependencies, so this module parses the *subset* of TOML the rtlint
config actually uses: ``[dotted.section]`` headers and
``key = value`` pairs where value is a string, bool, number, or a
(possibly multi-line) array of strings. Lines it cannot parse are
skipped — other pyproject sections may use arbitrary TOML; only the
``tool.rtlint`` subtree must stay within this subset (the self-test in
``tests/test_rtlint.py`` parses the real pyproject and checks the
block round-trips).

When ``tomllib`` is available it is preferred, so 3.11+ parses the
full language.
"""
from __future__ import annotations

import os
import re

_SECTION_RE = re.compile(r"^\s*\[([A-Za-z0-9_.\-\"' ]+)\]\s*(?:#.*)?$")
_KEY_RE = re.compile(r"^\s*([A-Za-z0-9_\-]+|\"[^\"]+\")\s*=\s*(.*)$")
_STR_RE = re.compile(r'"((?:[^"\\]|\\.)*)"|\'([^\']*)\'')


def _strip_comment(text: str) -> str:
    """Drop a trailing ``#`` comment that is not inside a string."""
    out = []
    in_str: str | None = None
    for ch in text:
        if in_str:
            if ch == in_str:
                in_str = None
        elif ch in "\"'":
            in_str = ch
        elif ch == "#":
            break
        out.append(ch)
    return "".join(out).strip()


def _parse_scalar(text: str):
    text = text.strip()
    m = _STR_RE.fullmatch(text)
    if m:
        raw = m.group(1) if m.group(1) is not None else m.group(2)
        return raw.encode().decode("unicode_escape") if "\\" in raw else raw
    if text in ("true", "false"):
        return text == "true"
    try:
        return int(text)
    except ValueError:
        pass
    try:
        return float(text)
    except ValueError:
        pass
    return None  # out of subset: ignore


def _parse_array(text: str) -> list:
    out = []
    for m in _STR_RE.finditer(text):
        raw = m.group(1) if m.group(1) is not None else m.group(2)
        out.append(
            raw.encode().decode("unicode_escape") if "\\" in raw else raw
        )
    return out


def parse_toml_subset(text: str) -> dict:
    """Parse the supported TOML subset into nested dicts."""
    root: dict = {}
    section = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i])
        i += 1
        if not line:
            continue
        m = _SECTION_RE.match(line)
        if m:
            section = root
            for part in m.group(1).split("."):
                part = part.strip().strip("\"'")
                section = section.setdefault(part, {})
                if not isinstance(section, dict):  # scalar collision
                    section = {}
            continue
        m = _KEY_RE.match(line)
        if not m:
            continue
        key = m.group(1).strip("\"'")
        value = m.group(2)
        # multi-line array: accumulate until brackets balance outside
        # of strings
        if value.lstrip().startswith("["):
            buf = _strip_comment(value)
            while buf.count("[") > buf.count("]") and i < len(lines):
                buf += " " + _strip_comment(lines[i])
                i += 1
            section[key] = _parse_array(buf)
            continue
        parsed = _parse_scalar(_strip_comment(value))
        if parsed is not None:
            section[key] = parsed
    return root


def load_config(root: str) -> dict:
    """The ``[tool.rtlint]`` table of ``<root>/pyproject.toml`` ({} when
    absent)."""
    path = os.path.join(root, "pyproject.toml")
    if not os.path.isfile(path):
        return {}
    with open(path, encoding="utf-8") as f:
        text = f.read()
    try:
        import tomllib  # Python 3.11+

        doc = tomllib.loads(text)
    except ModuleNotFoundError:
        doc = parse_toml_subset(text)
    except Exception:
        doc = parse_toml_subset(text)
    tool = doc.get("tool", {})
    cfg = tool.get("rtlint", {}) if isinstance(tool, dict) else {}
    return cfg if isinstance(cfg, dict) else {}
