"""Small AST helpers shared by the rtlint rules."""
from __future__ import annotations

import ast


def dotted(node: ast.AST) -> str | None:
    """Dotted name of a Name/Attribute chain (``time.perf_counter``,
    ``self._tr.emit``); None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_ident(node: ast.AST) -> str | None:
    """The trailing identifier of an expression: ``a.b.c`` -> ``c``,
    ``name`` -> ``name``, ``a[i]`` -> base's identifier."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Subscript):
        return last_ident(node.value)
    if isinstance(node, ast.Call):
        return last_ident(node.func)
    return None


def is_call_to(node: ast.AST, names: set[str]) -> bool:
    """Is ``node`` a Call whose dotted function name is in ``names``?"""
    return (
        isinstance(node, ast.Call)
        and (dotted(node.func) or "") in names
    )


def str_consts(node: ast.AST) -> list[tuple[ast.AST, str]]:
    """String constants reachable from ``node`` without descending into
    calls: handles a bare constant, an IfExp over constants, and
    tuple/list/set displays of constants — the shapes event-kind
    arguments take."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [(node, node.value)]
    if isinstance(node, ast.IfExp):
        return str_consts(node.body) + str_consts(node.orelse)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for elt in node.elts:
            out.extend(str_consts(elt))
        return out
    return []


class LoopAwareVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks whether the current node sits inside a
    ``for``/``while`` body or a comprehension — the "per-event hot
    loop" context several rules care about."""

    def __init__(self) -> None:
        self.loop_depth = 0

    @property
    def in_loop(self) -> bool:
        return self.loop_depth > 0

    def _visit_loop(self, node) -> None:
        self.loop_depth += 1
        try:
            self.generic_visit(node)
        finally:
            self.loop_depth -= 1

    visit_For = _visit_loop
    visit_AsyncFor = _visit_loop
    visit_While = _visit_loop
    visit_ListComp = _visit_loop
    visit_SetComp = _visit_loop
    visit_DictComp = _visit_loop
    visit_GeneratorExp = _visit_loop
