"""`rtlint` — real-time-invariant static analysis for this repo.

PHAROS's schedulability guarantees only hold if the DES, the serving
runtime and the analysis share a deterministic timebase and bit-stable
event ordering. Those invariants used to live in docstrings; `rtlint`
makes them machine-checked, stdlib-only, and runs in CI *before*
dependency install (like `tools/check_docs.py`, with which it shares
`tools.pylib`).

Framework pieces:

- `Rule` — an AST-visitor check with a name, severity and default
  path scope; concrete rules register via `@register` (see
  `tools.rtlint.rules`).
- `Finding` — one diagnostic (rule, file, line, col, message).
- inline suppressions — ``# rtlint: disable=<rule>[,<rule>...]`` on
  the offending line, or on a comment line directly above it; every
  suppression should carry a one-line rationale. Suppressions that
  never fire are themselves reported (``unused-suppression``,
  warning severity).
- config — the ``[tool.rtlint]`` block in ``pyproject.toml`` scopes
  rules per directory and overrides severities
  (`tools.rtlint.config`).

Run: ``python -m tools.rtlint`` (from the repo root; CI does).
Docs: ``docs/static-analysis.md`` (rule catalog, how to add a rule).
"""
from __future__ import annotations

import os
import re
import sys
from dataclasses import dataclass, field

_TOOLS_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ROOT = os.path.dirname(_TOOLS_DIR)
if _ROOT not in sys.path:  # `python tools/rtlint/...` direct invocation
    sys.path.insert(0, _ROOT)

from tools.pylib import PyFile, from_source, load  # noqa: E402

SEVERITIES = ("error", "warning")

#: ``# rtlint: disable=<rule>[,<rule>...]`` (optionally followed by a
#: free-form rationale after `` -- `` or in a trailing comment)
_SUPPRESS_RE = re.compile(
    r"#\s*rtlint:\s*disable=([\w\-]+(?:\s*,\s*[\w\-]+)*)"
)


@dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rel:line:col [severity] rule: message``."""

    rule: str
    rel: str
    line: int
    col: int
    message: str
    severity: str = "error"

    def human(self) -> str:
        return (
            f"{self.rel}:{self.line}:{self.col}: "
            f"[{self.severity}] {self.rule}: {self.message}"
        )

    def github(self) -> str:
        level = "error" if self.severity == "error" else "warning"
        # GitHub workflow-command annotation (rendered on the PR diff)
        msg = self.message.replace("%", "%25").replace("\n", "%0A")
        return (
            f"::{level} file={self.rel},line={self.line},"
            f"col={self.col},title=rtlint({self.rule})::{msg}"
        )

    def json_obj(self) -> dict:
        """GitHub checks-API annotation shape."""
        return {
            "path": self.rel,
            "start_line": self.line,
            "end_line": self.line,
            "start_column": self.col,
            "annotation_level": (
                "failure" if self.severity == "error" else "warning"
            ),
            "title": f"rtlint({self.rule})",
            "message": self.message,
        }


@dataclass
class LintContext:
    """Per-run shared state handed to every rule.

    ``root`` is the repo root ("" for in-memory corpus runs);
    ``config`` is the parsed ``[tool.rtlint]`` table; ``shared`` is a
    scratch dict for cross-file rule state (e.g. the trace-vocabulary
    rule accumulates emitted kinds here and reconciles in
    `Rule.finalize`).
    """

    root: str = ""
    config: dict = field(default_factory=dict)
    shared: dict = field(default_factory=dict)

    def rule_config(self, rule_name: str) -> dict:
        return self.config.get("rules", {}).get(rule_name, {})


class Rule:
    """Base class: subclass, set the class attributes, implement
    `check`; optionally implement `finalize` for whole-run checks."""

    name: str = ""
    description: str = ""
    severity: str = "error"
    #: default path scope (repo-relative posix globs); pyproject's
    #: ``[tool.rtlint.rules.<name>]`` include/exclude override these
    include: tuple[str, ...] = ("src/**",)
    exclude: tuple[str, ...] = ()

    def check(self, pf: PyFile, ctx: LintContext) -> list[Finding]:
        raise NotImplementedError

    def finalize(self, ctx: LintContext) -> list[Finding]:
        return []

    # -- scoping -------------------------------------------------------
    def effective_severity(self, ctx: LintContext) -> str:
        sev = self.rule_opt(ctx, "severity", self.severity)
        return sev if sev in SEVERITIES else self.severity

    def rule_opt(self, ctx: LintContext, key: str, default):
        return ctx.rule_config(self.name).get(key, default)

    def applies_to(self, rel: str, ctx: LintContext) -> bool:
        inc = tuple(self.rule_opt(ctx, "include", self.include))
        exc = tuple(self.rule_opt(ctx, "exclude", self.exclude))
        return match_any(rel, inc) and not match_any(rel, exc)

    def finding(
        self, pf: PyFile, node, message: str, ctx: LintContext
    ) -> Finding:
        return Finding(
            rule=self.name,
            rel=pf.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            message=message,
            severity=self.effective_severity(ctx),
        )


def match_any(rel: str, patterns) -> bool:
    """Match a repo-relative posix path against glob-ish patterns:
    ``dir/**`` (or a bare directory) prefix-matches, exact paths match
    literally, anything else goes through `fnmatch` (where ``*`` spans
    ``/``)."""
    from fnmatch import fnmatch

    for pat in patterns:
        pat = pat.rstrip("/")
        if pat.endswith("/**"):
            stem = pat[:-3]
            if rel == stem or rel.startswith(stem + "/"):
                return True
        elif rel == pat or rel.startswith(pat + "/"):
            return True
        elif fnmatch(rel, pat):
            return True
    return False


#: the rule registry: name -> Rule instance
RULES: dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate and register a `Rule`."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return cls


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
@dataclass
class Suppressions:
    """Inline ``# rtlint: disable=`` directives of one file.

    A directive on line L suppresses matching findings on L; a
    directive on a *comment-only* line suppresses the next
    non-comment line (directives stack). ``used`` tracks which
    directives actually absorbed a finding so stale ones can be
    reported."""

    by_line: dict[int, set[str]] = field(default_factory=dict)
    #: directive source line -> rule names it declares
    declared: dict[int, set[str]] = field(default_factory=dict)
    used: set[int] = field(default_factory=set)
    #: finding line -> directive line(s) feeding it
    _origin: dict[int, list[int]] = field(default_factory=dict)

    @classmethod
    def scan(cls, pf: PyFile) -> "Suppressions":
        sup = cls()
        pending: list[tuple[int, set[str]]] = []  # comment-line directives
        for lineno, text in enumerate(pf.lines, 1):
            m = _SUPPRESS_RE.search(text)
            names: set[str] | None = None
            if m:
                names = {
                    n.strip() for n in m.group(1).split(",") if n.strip()
                }
                sup.declared[lineno] = names
            comment_only = text.lstrip().startswith("#")
            if m and comment_only:
                pending.append((lineno, names))
                continue
            if comment_only or not text.strip():
                continue  # blank/plain comment: directives keep pending
            target = sup.by_line.setdefault(lineno, set())
            origin = sup._origin.setdefault(lineno, [])
            for src, nms in pending:
                target.update(nms)
                origin.append(src)
            pending.clear()
            if m:
                target.update(names)
                origin.append(lineno)
        return sup

    def suppresses(self, finding: Finding) -> bool:
        names = self.by_line.get(finding.line)
        if not names or (
            finding.rule not in names and "all" not in names
        ):
            return False
        for src in self._origin.get(finding.line, []):
            decl = self.declared.get(src, set())
            if finding.rule in decl or "all" in decl:
                self.used.add(src)
        return True

    def unused(self) -> list[tuple[int, set[str]]]:
        return [
            (lineno, names)
            for lineno, names in sorted(self.declared.items())
            if lineno not in self.used
        ]


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------
def lint_file(
    pf: PyFile,
    ctx: LintContext,
    rules=None,
    *,
    report_unused: bool = True,
) -> list[Finding]:
    """Run every in-scope rule over one parsed file."""
    rules = list(RULES.values()) if rules is None else list(rules)
    sup = Suppressions.scan(pf)
    out: list[Finding] = []
    for rule in rules:
        if not rule.applies_to(pf.rel, ctx):
            continue
        if pf.tree is None:
            continue
        for f in rule.check(pf, ctx):
            if not sup.suppresses(f):
                out.append(f)
    if report_unused:
        for lineno, names in sup.unused():
            out.append(
                Finding(
                    rule="unused-suppression",
                    rel=pf.rel,
                    line=lineno,
                    col=1,
                    message=(
                        "suppression never fired: "
                        f"disable={','.join(sorted(names))} — remove it "
                        "or fix the rule name"
                    ),
                    severity="warning",
                )
            )
    return out


def lint_source(
    source: str,
    rel: str,
    *,
    rules=None,
    config: dict | None = None,
    report_unused: bool = False,
) -> list[Finding]:
    """Lint an in-memory snippet as if it lived at ``rel`` — the test
    corpus entry point."""
    ctx = LintContext(root="", config=config or {})
    return lint_file(
        from_source(source, rel=rel),
        ctx,
        rules=rules,
        report_unused=report_unused,
    )


def lint_paths(
    paths,
    root: str,
    config: dict | None = None,
    rules=None,
    *,
    partial: bool = False,
) -> list[Finding]:
    """Lint files (absolute paths) against ``root``; runs per-file
    checks then every rule's cross-file `finalize`. ``partial`` marks
    an explicit-path run: rules whose finalize needs the whole tree
    (e.g. trace-vocab's every-kind-has-an-emitter) skip themselves."""
    import tools.rtlint.rules  # noqa: F401  (registers on import)

    rules = list(RULES.values()) if rules is None else list(rules)
    ctx = LintContext(root=root, config=config or {})
    ctx.shared["partial"] = partial
    findings: list[Finding] = []
    for path in paths:
        pf = load(path, root=root)
        findings.extend(lint_file(pf, ctx, rules=rules))
    for rule in rules:
        findings.extend(rule.finalize(ctx))
    findings.sort(key=lambda f: (f.rel, f.line, f.col, f.rule))
    return findings
