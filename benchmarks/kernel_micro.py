"""Kernel micro-benchmarks: wall time per call of the jnp reference path
(interpret-mode Pallas is not a timing proxy on CPU; this benchmarks the
oracle math + wrapper overheads, and verifies kernel/oracle agreement as
it goes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_csv
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.preemptible_matmul.ref import matmul_ref
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    a = jax.random.normal(key, (512, 512), jnp.bfloat16)
    b = jax.random.normal(key, (512, 512), jnp.bfloat16)
    rows.append(["matmul_ref_512", f"{_time(jax.jit(matmul_ref), a, b):.1f}"])

    q = jax.random.normal(key, (2, 256, 4, 64), jnp.float32)
    kk = jax.random.normal(key, (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 256, 2, 64), jnp.float32)
    rows.append(
        ["flash_ref_b2s256", f"{_time(jax.jit(attention_ref), q, kk, v):.1f}"]
    )

    dt = jax.nn.softplus(jax.random.normal(key, (2, 128, 64)))
    Bm = jax.random.normal(key, (2, 128, 16))
    x = jax.random.normal(key, (2, 128, 64))
    A = -jnp.abs(jax.random.normal(key, (64, 16)))
    rows.append(
        [
            "mamba_ref_s128",
            f"{_time(jax.jit(mamba_scan_ref), dt, Bm, Bm, x, A):.1f}",
        ]
    )

    r = jax.random.normal(key, (1, 128, 4, 32))
    w = jnp.exp(-jnp.exp(jnp.clip(jax.random.normal(key, (1, 128, 4, 32)), -8, -1)))
    u = jax.random.normal(key, (4, 32)) * 0.1
    rows.append(
        ["rwkv6_ref_s128", f"{_time(jax.jit(rwkv6_scan_ref), r, r, r, w, u):.1f}"]
    )
    write_csv("kernel_micro.csv", ["kernel", "us_per_call"], rows)
    return "; ".join(f"{n}={t}us" for n, t in rows)


if __name__ == "__main__":
    print(run())
