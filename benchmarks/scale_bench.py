"""Million-tenant hot-path benchmark -> BENCH_scale.json.

Four measurements, CI-enforced assertions on the first three:

1. **Batched admission core** — the same tenant cohort scored by a
   scalar `AdmissionController.check` loop vs one
   `score_many`/`check_many` array pass against the same cached Eq. 2
   state. CI asserts the batched core reaches **>= 5x** the scalar
   decisions/sec (the acceptance bar of this vectorization, mirroring
   `BENCH_dse.json`'s evaluator-core gate) and that `check_many`
   reproduces the scalar decision stream **bit-identically** (verdict,
   bottleneck, stage utils, reason string).
2. **Array-backed rate limiter** — one heavy-tailed release batch swept
   by a scalar `allow` loop vs one `allow_many` pass over a limiter
   with identical starting state. CI asserts verdict-for-verdict
   equality (duplicate tenants per batch included) plus equal final
   grant/deny totals.
3. **Vectorized placement** — `LeastLoaded`/`SlackAware` vs the
   pre-vectorization per-shard Python loops (kept inline here as the
   differential baseline). CI asserts identical shard assignments.
4. **Streaming soak** — a heavy-tailed (MMPP-modulated, Zipf-skewed)
   synthetic tenant population streamed through a sharded fleet of
   admission controllers + rate limiters in event batches, publishing
   sustained releases/sec and per-decision admission latency
   percentiles at 10^4 (``--quick``, the CI budget) to 10^6 tenants.

Run: ``PYTHONPATH=src python benchmarks/scale_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_scale.json``; exits non-zero if
a speedup or equality assertion fails so CI enforces the perf claim.
Everything is seeded (`np.random.default_rng(0)`) — reruns reproduce
the same tenant population, stream and decisions.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.traffic.admission import AdmissionController, TaskRequest
from repro.traffic.ratelimit import RateLimiter
from repro.traffic.shard import LeastLoaded, SlackAware
from repro.core.rt.schedulability import EPS
from repro.core.rt.task import LayerDesc, SegmentTable, Task, TaskSet, Workload
from repro.core.rt.schedulability import stage_slacks

RESULTS_DIR = os.path.join("experiments", "benchmarks")
N_STAGES = 4
#: the acceptance bar: batched admission core >= 5x scalar decisions/s
MIN_ADMISSION_SPEEDUP = 5.0


def _pct(samples, q: float) -> float:
    """Nearest-rank percentile (no interpolation surprises)."""
    if not len(samples):
        return 0.0
    s = sorted(samples)
    return s[min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1))))]


def synth_tenants(n: int, rng) -> tuple[np.ndarray, np.ndarray]:
    """Heavy-tailed synthetic population: per-tenant stage WCET rows
    ``[n, N_STAGES]`` and periods ``[n]``. Periods are lognormal (a
    few fast tenants, a long slow tail), per-stage demand is a small
    fraction of the period split unevenly across stages, and ~30% of
    tenants skip a stage (exercising the inactive-stage = exact-0.0
    path of the batch kernels)."""
    periods = np.exp(rng.normal(np.log(0.05), 1.0, size=n))
    shares = rng.dirichlet(np.ones(N_STAGES) * 0.7, size=n)
    demand = periods * rng.uniform(0.0005, 0.02, size=n)
    base = shares * demand[:, None]
    skip = rng.random((n, N_STAGES)) < 0.3
    # never skip every stage of a tenant
    skip[np.arange(n), rng.integers(0, N_STAGES, size=n)] = False
    base = np.where(skip, 0.0, base)
    return base, periods


def _requests(base: np.ndarray, periods: np.ndarray) -> list[TaskRequest]:
    return [
        TaskRequest(
            name=f"t{i:07d}",
            base=tuple(float(b) for b in base[i]),
            period=float(periods[i]),
            deadline=float(periods[i]),
        )
        for i in range(len(periods))
    ]


# ---------------------------------------------------------------------------
# 1. batched admission core
# ---------------------------------------------------------------------------
def bench_admission_core(quick: bool) -> dict:
    rng = np.random.default_rng(0)
    n = 10_000 if quick else 200_000
    n_scalar = 2_000 if quick else 10_000
    base, periods = synth_tenants(n, rng)
    ctl = AdmissionController([0.001] * N_STAGES, preemptive=True)
    # pre-admit a background population so checks run against a
    # realistically loaded Eq. 2 cache (and some checks reject)
    bg_base, bg_periods = synth_tenants(200, rng)
    for i, r in enumerate(_requests(bg_base * 40.0, bg_periods)):
        ctl.admit(r)
    reqs = _requests(base, periods)

    # scalar baseline: per-decision latency samples + throughput
    scalar_lat = []
    t0 = time.perf_counter()
    scalar_decisions = []
    for r in reqs[:n_scalar]:
        t1 = time.perf_counter()
        scalar_decisions.append(ctl.check(r))
        scalar_lat.append(time.perf_counter() - t1)
    scalar_s = time.perf_counter() - t0

    # batched core (score_many: the array pass the fleet runs per
    # planning round) over the full cohort
    t0 = time.perf_counter()
    after, bottleneck, ok = ctl.score_many(base, periods)
    core_s = time.perf_counter() - t0

    # batched decision front-end (check_many: full AdmissionDecision
    # construction) over the scalar subset, bit-equality asserted
    t0 = time.perf_counter()
    batched_decisions = ctl.check_many(reqs[:n_scalar])
    many_s = time.perf_counter() - t0
    mismatches = sum(
        1
        for a, b in zip(scalar_decisions, batched_decisions)
        if not (
            a.admitted == b.admitted
            and a.bottleneck == b.bottleneck
            and a.stage_utils == b.stage_utils
            and a.reason == b.reason
        )
    )

    out = {
        "tenants": n,
        "scalar_checks": n_scalar,
        "scalar_seconds": scalar_s,
        "scalar_decisions_per_sec": n_scalar / scalar_s,
        "batched_core_seconds": core_s,
        "batched_core_decisions_per_sec": n / core_s,
        "check_many_seconds": many_s,
        "check_many_decisions_per_sec": n_scalar / many_s,
        "admitted_fraction": float(ok.mean()),
        "speedup_core": (scalar_s / n_scalar) / (core_s / n),
        "speedup_check_many": (scalar_s / n_scalar) / (many_s / n_scalar),
        "decision_mismatches": mismatches,
        "scalar_latency_us": {
            "p50": _pct(scalar_lat, 50) * 1e6,
            "p95": _pct(scalar_lat, 95) * 1e6,
            "p99": _pct(scalar_lat, 99) * 1e6,
        },
        "batched_core_latency_us_per_decision": core_s / n * 1e6,
    }
    print(
        f"admission core: scalar {out['scalar_decisions_per_sec']:,.0f}/s, "
        f"batched {out['batched_core_decisions_per_sec']:,.0f}/s "
        f"({out['speedup_core']:.1f}x core, "
        f"{out['speedup_check_many']:.1f}x check_many), "
        f"{mismatches} mismatches"
    )
    return out


# ---------------------------------------------------------------------------
# 2. array-backed rate limiter
# ---------------------------------------------------------------------------
def bench_ratelimit(quick: bool) -> dict:
    rng = np.random.default_rng(1)
    n = 10_000 if quick else 1_000_000
    n_events = 50_000 if quick else 400_000
    rates = np.exp(rng.normal(np.log(20.0), 1.0, size=n))
    bursts = np.maximum(1.0, rng.integers(1, 5, size=n).astype(float))
    # Zipf-skewed tenant popularity: a hot head hammers its buckets
    # (many duplicate indices per batch — the occurrence-rank path),
    # a long tail trickles
    tenants = (rng.zipf(1.3, size=n_events) - 1) % n
    times = np.sort(rng.uniform(0.0, 5.0, size=n_events))

    rl_scalar = RateLimiter.from_arrays(rates, bursts)
    t0 = time.perf_counter()
    scalar_verdicts = [
        rl_scalar.allow(int(i), float(t)) for t, i in zip(times, tenants)
    ]
    scalar_s = time.perf_counter() - t0

    rl_batched = RateLimiter.from_arrays(rates, bursts)
    batch = 4096
    batched_verdicts = np.empty(n_events, dtype=bool)
    t0 = time.perf_counter()
    for lo in range(0, n_events, batch):
        hi = min(lo + batch, n_events)
        batched_verdicts[lo:hi] = rl_batched.allow_many(
            times[lo:hi], tenants[lo:hi]
        )
    batched_s = time.perf_counter() - t0

    equal = bool(
        np.array_equal(np.asarray(scalar_verdicts), batched_verdicts)
    ) and rl_scalar.totals() == rl_batched.totals()
    out = {
        "tenants": n,
        "events": n_events,
        "batch_size": batch,
        "scalar_seconds": scalar_s,
        "batched_seconds": batched_s,
        "scalar_events_per_sec": n_events / scalar_s,
        "batched_events_per_sec": n_events / batched_s,
        "speedup": scalar_s / batched_s,
        "granted": rl_batched.totals()[0],
        "denied": rl_batched.totals()[1],
        "verdicts_equal": equal,
    }
    print(
        f"rate limiter:   scalar {out['scalar_events_per_sec']:,.0f}/s, "
        f"batched {out['batched_events_per_sec']:,.0f}/s "
        f"({out['speedup']:.1f}x), equal={equal}"
    )
    return out


# ---------------------------------------------------------------------------
# 3. vectorized placement (scalar loops kept inline as the baseline)
# ---------------------------------------------------------------------------
def _scalar_least_loaded(requests, n_shards, overheads, preemptive):
    loads = [[0.0] * len(overheads) for _ in range(n_shards)]
    out = []
    for r in requests:
        du = r.utilization(tuple(overheads), preemptive)
        best = min(
            range(n_shards),
            key=lambda s: (max(u + d for u, d in zip(loads[s], du)), s),
        )
        out.append(best)
        loads[best] = [u + d for u, d in zip(loads[best], du)]
    return out


def _scalar_slack_aware(requests, n_shards, overheads, preemptive):
    def view(reqs):
        table = SegmentTable(
            base=[list(r.base) for r in reqs], overhead=list(overheads)
        )
        w = Workload("placement", (LayerDesc("seg", 1, 1, 1),))
        ts = TaskSet(
            tasks=tuple(
                Task(
                    workload=w,
                    period=r.period,
                    deadline=r.deadline,
                    name=r.name,
                )
                for r in reqs
            )
        )
        return table, ts

    placed = [[] for _ in range(n_shards)]
    out = []
    for r in requests:
        active = [k for k, b in enumerate(r.base) if b > 0.0]

        def score(s):
            table, ts = view(placed[s] + [r])
            slacks = stage_slacks(table, ts, preemptive)
            return (min(slacks[k] for k in active), -s)

        best = max(range(n_shards), key=score)
        out.append(best)
        placed[best].append(r)
    return out


def bench_placement(quick: bool) -> dict:
    rng = np.random.default_rng(2)
    n_shards = 16
    rows = []
    for policy, scalar_ref, n in (
        (LeastLoaded(), _scalar_least_loaded, 2_000 if quick else 20_000),
        (SlackAware(), _scalar_slack_aware, 300 if quick else 1_000),
    ):
        base, periods = synth_tenants(n, rng)
        reqs = _requests(base, periods)
        overheads = [0.0] * N_STAGES

        t0 = time.perf_counter()
        ref = scalar_ref(reqs, n_shards, overheads, True)
        scalar_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        vec = policy.place(
            reqs, n_shards, overheads=overheads, preemptive=True
        )
        vec_s = time.perf_counter() - t0
        rows.append(
            {
                "policy": policy.name,
                "tenants": n,
                "shards": n_shards,
                "scalar_seconds": scalar_s,
                "vectorized_seconds": vec_s,
                "speedup": scalar_s / vec_s,
                "assignments_equal": vec == ref,
            }
        )
        print(
            f"placement {policy.name:12s}: {scalar_s:.3f}s -> {vec_s:.3f}s "
            f"({rows[-1]['speedup']:.1f}x), equal={vec == ref}"
        )
    return {"runs": rows}


# ---------------------------------------------------------------------------
# 4. streaming soak: MMPP-modulated event batches through a fleet
# ---------------------------------------------------------------------------
def bench_soak(quick: bool) -> dict:
    """Shaped like a streaming-arrival env: a global 2-state MMPP
    (calm/bursty) modulates the event rate; each dwell emits one
    Zipf-skewed release batch that is routed to its shards, admission-
    scored (`score_many`) and rate-limited (`allow_many`) per shard."""
    rng = np.random.default_rng(3)
    n = 10_000 if quick else 1_000_000
    n_shards = 8
    target_events = 200_000 if quick else 2_000_000
    rate_lo, rate_hi = 20_000.0, 120_000.0  # events/s per MMPP state
    dwell_s = 0.05

    base, periods = synth_tenants(n, rng)
    rates = 1.0 / periods
    shard_of = np.arange(n) % n_shards
    ctls = [
        AdmissionController([0.001] * N_STAGES, preemptive=True)
        for _ in range(n_shards)
    ]
    limiters = [
        RateLimiter.from_arrays(
            rates[shard_of == k], np.full((shard_of == k).sum(), 4.0)
        )
        for k in range(n_shards)
    ]
    local_idx = np.empty(n, dtype=np.intp)
    for k in range(n_shards):
        members = np.flatnonzero(shard_of == k)
        local_idx[members] = np.arange(len(members))

    events = 0
    admitted = limited = 0
    batches = 0
    admission_lat = []  # per-decision seconds, one sample per batch
    t_virtual = 0.0
    state = 0
    wall0 = time.perf_counter()
    while events < target_events:
        rate = rate_hi if state == 1 else rate_lo
        n_ev = int(rng.poisson(rate * dwell_s))
        state = 1 - state if rng.random() < 0.3 else state
        if n_ev == 0:
            t_virtual += dwell_s
            continue
        tenants = (rng.zipf(1.2, size=n_ev) - 1) % n
        times = np.sort(rng.uniform(t_virtual, t_virtual + dwell_s, n_ev))
        t_virtual += dwell_s
        for k in range(n_shards):
            sel = np.flatnonzero(shard_of[tenants] == k)
            if not len(sel):
                continue
            cohort = tenants[sel]
            t1 = time.perf_counter()
            _after, _bneck, ok = ctls[k].score_many(
                base[cohort], periods[cohort]
            )
            admission_lat.append((time.perf_counter() - t1) / len(sel))
            admitted += int(ok.sum())
            allowed = limiters[k].allow_many(
                times[sel], local_idx[cohort]
            )
            limited += int((~allowed).sum())
        events += n_ev
        batches += 1
    wall_s = time.perf_counter() - wall0

    out = {
        "tenants": n,
        "shards": n_shards,
        "events": events,
        "batches": batches,
        "virtual_seconds": t_virtual,
        "wall_seconds": wall_s,
        "sustained_releases_per_sec": events / wall_s,
        "admission_ok": admitted,
        "rate_limited": limited,
        "admission_latency_us_per_decision": {
            "p50": _pct(admission_lat, 50) * 1e6,
            "p95": _pct(admission_lat, 95) * 1e6,
            "p99": _pct(admission_lat, 99) * 1e6,
        },
    }
    print(
        f"soak: {n:,} tenants / {n_shards} shards, "
        f"{events:,} events in {wall_s:.2f}s wall "
        f"({out['sustained_releases_per_sec']:,.0f} releases/s), "
        f"admission p99 "
        f"{out['admission_latency_us_per_decision']['p99']:.3f}us/decision"
    )
    return out


def main() -> None:
    quick = "--quick" in sys.argv
    admission = bench_admission_core(quick)
    ratelimit = bench_ratelimit(quick)
    placement = bench_placement(quick)
    soak = bench_soak(quick)
    payload = {
        "bench": "scale",
        "quick": quick,
        "min_admission_speedup": MIN_ADMISSION_SPEEDUP,
        "admission_core": admission,
        "ratelimit": ratelimit,
        "placement": placement,
        "soak": soak,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_scale.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path}")

    ok = True
    if admission["speedup_core"] < MIN_ADMISSION_SPEEDUP:
        print(
            f"FAIL: batched admission core only "
            f"{admission['speedup_core']:.1f}x the scalar loop "
            f"(need >= {MIN_ADMISSION_SPEEDUP}x)",
            file=sys.stderr,
        )
        ok = False
    if admission["decision_mismatches"]:
        print(
            f"FAIL: check_many diverged from scalar check on "
            f"{admission['decision_mismatches']} decisions",
            file=sys.stderr,
        )
        ok = False
    if not ratelimit["verdicts_equal"]:
        print(
            "FAIL: allow_many diverged from the scalar allow loop",
            file=sys.stderr,
        )
        ok = False
    if ratelimit["speedup"] <= 1.0:
        print(
            f"FAIL: allow_many slower than the scalar loop "
            f"({ratelimit['speedup']:.2f}x)",
            file=sys.stderr,
        )
        ok = False
    for row in placement["runs"]:
        if not row["assignments_equal"]:
            print(
                f"FAIL: vectorized {row['policy']} changed the "
                f"placement",
                file=sys.stderr,
            )
            ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
