"""Paper Fig. 8: FIFO vs EDF response-time statistics on SG designs,
with and without preemption overhead.

Paper findings reproduced as trends: (a) without overhead EDF usually
wins; (b) with overhead the EDF win-rate drops; (c) combinations
containing Point Transformer (the heavyweight task) stay EDF-better —
FIFO blocks the small task behind the big one.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BEAM,
    MAX_M,
    PLATFORM,
    combo_workloads,
    period_grid,
    taskset_for,
    write_csv,
)
from repro.core.dse.beam import beam_search
from repro.core.dse.space import evaluate_design
from repro.core.rt.response_time import end_to_end_bounds
from repro.core.workloads import PAPER_COMBOS
from repro.scheduler.des import StageOverhead, simulate_taskset


def run(grid_n: int = 4):
    rows = []
    summary = []
    for combo in PAPER_COMBOS:
        wls = combo_workloads(combo)
        edf_wins_no_ov, edf_wins_ov, n = 0, 0, 0
        for ratios in period_grid(grid_n, lo=0.3, hi=1.0):
            ts = taskset_for(combo, ratios)
            res = beam_search(wls, ts, PLATFORM, max_m=MAX_M, beam_width=BEAM)
            if res.best is None:
                continue
            table = evaluate_design(res.best.accs, res.best.splits, wls, ts)
            zero = [StageOverhead()] * table.n_stages
            real = [
                StageOverhead(o / 3, o / 3, o / 3) for o in table.overhead
            ]
            f = simulate_taskset(table, ts, "fifo")
            e0 = simulate_taskset(table, ts, "edf", overheads=zero)
            e1 = simulate_taskset(table, ts, "edf", overheads=real)
            mf = float(np.mean([m for m in f.mean_response if m > 0]))
            me0 = float(np.mean([m for m in e0.mean_response if m > 0]))
            me1 = float(np.mean([m for m in e1.mean_response if m > 0]))
            edf_wins_no_ov += me0 < mf
            edf_wins_ov += me1 < mf
            n += 1
            # analytic bounds must upper-bound the simulation
            bf = end_to_end_bounds(table, ts, "fifo")
            rows.append(
                [
                    "+".join(combo),
                    f"{ratios[0]:.2f}",
                    f"{ratios[1]:.2f}",
                    f"{1e6 * mf:.1f}",
                    f"{1e6 * me0:.1f}",
                    f"{1e6 * me1:.1f}",
                    f"{1e6 * max(f.max_response):.1f}",
                    f"{1e6 * max(b for b in bf if b != float('inf')):.1f}"
                    if any(b != float("inf") for b in bf)
                    else "inf",
                    e1.preemptions,
                ]
            )
        if n:
            summary.append(
                ("+".join(combo), 100 * edf_wins_no_ov / n, 100 * edf_wins_ov / n)
            )
    write_csv(
        "fig8_response_time.csv",
        [
            "combo", "r1", "r2", "fifo_mean_us", "edf_mean_us(no_ov)",
            "edf_mean_us(ov)", "fifo_max_us", "fifo_bound_us", "edf_preempts",
        ],
        rows,
    )
    parts = [
        f"{c}: EDF wins {a:.0f}%->{b:.0f}% w/ overhead" for c, a, b in summary
    ]
    derived = " | ".join(parts) + " (paper: PT groups stay 61-81% EDF-better)"
    return derived


if __name__ == "__main__":
    print(run())
