"""Paper Fig. 9: beam-search quality/time vs brute force (B = +inf).

On PointNet+DeiT-T (the paper's Fig. 9 combination): search time, time
to first feasible, and best max(util) for B in {1,2,4,8,16} vs BFS.
Paper: brute force 13.3x/117.2x slower to first/full vs B=8, for 2.3%
quality gain.

The brute force explodes with 16 chips; the paper regime is preserved
on a reduced slice (platform chips scaled down, same max_M).
"""
from __future__ import annotations

from benchmarks.common import MAX_M, combo_workloads, taskset_for, write_csv
from repro.core.dse.beam import beam_search
from repro.core.dse.brute import brute_force_search
from repro.core.perfmodel.hardware import paper_platform
from repro.core.workloads import make_taskset

COMBO = ("pointnet", "deit_t")


def run(chips: int = 8, ratios=(0.8, 0.8)):
    plat = paper_platform(chips)
    wls = combo_workloads(COMBO)
    ts = make_taskset(COMBO, ratios, plat)
    rows = []
    results = {}
    for width in (1, 2, 4, 8, 16):
        r = beam_search(wls, ts, plat, max_m=MAX_M, beam_width=width)
        results[f"B{width}"] = r
        rows.append(
            [
                f"B={width}",
                f"{r.stats.wall_time_s:.3f}",
                f"{r.stats.first_feasible_time_s:.4f}"
                if r.stats.first_feasible_time_s
                else "-",
                f"{r.best.max_util:.4f}" if r.best else "inf",
                r.stats.create_acc_calls,
                f"{r.stats.candidates_per_sec:.0f}",
                len(r.succ_pts),
            ]
        )
    bf = brute_force_search(wls, ts, plat, max_m=MAX_M)
    results["BF"] = bf
    rows.append(
        [
            "BF",
            f"{bf.stats.wall_time_s:.3f}",
            f"{bf.stats.first_feasible_time_s:.4f}"
            if bf.stats.first_feasible_time_s
            else "-",
            f"{bf.best.max_util:.4f}" if bf.best else "inf",
            bf.stats.create_acc_calls,
            f"{bf.stats.candidates_per_sec:.0f}",
            len(bf.succ_pts),
        ]
    )
    write_csv(
        "fig9_beam_quality.csv",
        [
            "search",
            "wall_s",
            "first_feasible_s",
            "best_util",
            "create_acc",
            "cands_per_sec",
            "feasible",
        ],
        rows,
    )
    b8, b16, brute = results["B8"], results["B16"], results["BF"]
    slow_full = brute.stats.wall_time_s / max(b8.stats.wall_time_s, 1e-9)

    def gap(r):
        if r.best and brute.best:
            return 100.0 * (r.best.max_util - brute.best.max_util) / brute.best.max_util
        return float("nan")

    derived = (
        f"BF {slow_full:.1f}x slower than B=8 (paper 117.2x); "
        f"quality gap B8 {gap(b8):.1f}% / B16 {gap(b16):.1f}% "
        f"(paper: 2.3% at B=8, closes at B=16/32)"
    )
    return derived


if __name__ == "__main__":
    print(run())
