"""Admission-control micro-benchmark -> BENCH_admission.json.

Two hot paths of the traffic subsystem:

1. **Admit-check latency** — `AdmissionController.check` is the per
   tenancy-change fast path; it must be O(stages), independent of how
   many tenants are resident. We time it across resident-set sizes and
   compare against the full re-analysis (rebuild `SegmentTable` +
   `srt_schedulable`), whose cost grows with the tenant count.
2. **Gateway release jitter** — how late the `TrafficGateway` releases
   jobs relative to their scheduled arrival times on a virtual-clock
   serving run (jitter is bounded by the serving quantum) and on a
   wall-clock run of the release loop.

Run: ``PYTHONPATH=src python benchmarks/admission_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_admission.json``.
"""
from __future__ import annotations

import json
import os
import random
import statistics
import sys
import time

from repro.core.rt.schedulability import srt_schedulable
from repro.core.rt.task import LayerDesc, SegmentTable, Task, TaskSet, Workload
from repro.traffic import (
    AdmissionController,
    PeriodicArrivals,
    PoissonArrivals,
    TaskRequest,
    TrafficGateway,
    VirtualClock,
)

RESULTS_DIR = os.path.join("experiments", "benchmarks")


def _percentiles(xs):
    xs = sorted(xs)

    def pct(p):
        return xs[min(len(xs) - 1, int(p * len(xs)))]

    return {
        "mean": statistics.fmean(xs),
        "p50": pct(0.50),
        "p99": pct(0.99),
        "max": xs[-1],
    }


def _mk_controller(n_tenants: int, n_stages: int, rng: random.Random):
    ctl = AdmissionController([0.001] * n_stages, preemptive=True)
    for j in range(n_tenants):
        base = tuple(
            rng.uniform(0.001, 0.5 / max(1, n_tenants)) for _ in range(n_stages)
        )
        ctl.admit(TaskRequest(f"t{j}", base, period=rng.uniform(0.5, 2.0)))
    return ctl


def bench_admit_check(quick: bool) -> dict:
    rng = random.Random(0)
    reps = 200 if quick else 2000
    out = {}
    for n_tenants in (4, 16, 64) if quick else (4, 16, 64, 256):
        n_stages = 4
        ctl = _mk_controller(n_tenants, n_stages, rng)
        probes = [
            TaskRequest(
                f"p{j}",
                tuple(rng.uniform(0.001, 0.05) for _ in range(n_stages)),
                period=rng.uniform(0.5, 2.0),
            )
            for j in range(64)
        ]
        # incremental O(stages) check
        inc_ns = []
        for i in range(reps):
            p = probes[i % len(probes)]
            t0 = time.perf_counter_ns()
            ctl.check(p)
            inc_ns.append(time.perf_counter_ns() - t0)
        # full re-analysis: rebuild table + taskset + Eq. 3
        w = Workload("w", (LayerDesc("l", 8, 8, 8),))
        full_ns = []
        for i in range(max(20, reps // 10)):
            p = probes[i % len(probes)]
            t0 = time.perf_counter_ns()
            reqs = list(ctl.admitted) + [p]
            table = SegmentTable(
                base=[list(r.base) for r in reqs],
                overhead=list(ctl.overheads),
            )
            ts = TaskSet(
                tasks=tuple(
                    Task(workload=w, period=r.period, name=r.name)
                    for r in reqs
                )
            )
            srt_schedulable(table, ts, preemptive=True)
            full_ns.append(time.perf_counter_ns() - t0)
        inc, full = _percentiles(inc_ns), _percentiles(full_ns)
        out[f"tenants_{n_tenants}"] = {
            "incremental_check_ns": inc,
            "full_reanalysis_ns": full,
            "speedup_mean": full["mean"] / inc["mean"],
        }
    return out


def bench_gateway_jitter(quick: bool) -> dict:
    """Release jitter on a virtual-clock serving run with real GEMMs."""
    import jax
    import jax.numpy as jnp

    from repro.pipeline.serve import PharosServer, ServeTask

    def weights(dims, key):
        k = jax.random.PRNGKey(key)
        ws = []
        for (K, N) in dims:
            k, s = jax.random.split(k)
            ws.append(
                jax.random.normal(s, (K, N), jnp.float32) / jnp.sqrt(K)
            )
        return tuple(ws)

    dt = 1e-3
    tasks = [
        ServeTask(
            "a", weights([(128, 128), (128, 128)], 0), (0, 1), period=0.01
        ),
        ServeTask(
            "b", weights([(128, 128), (128, 128)], 1), (0, 1), period=0.02
        ),
    ]
    reqs = [
        TaskRequest("a", (dt, dt), period=0.01),
        TaskRequest("b", (dt, dt), period=0.02),
    ]
    clk = VirtualClock()
    srv = PharosServer(tasks, 2, clock=clk.now, sleep=clk.sleep)
    gw = TrafficGateway(
        srv,
        AdmissionController([0.0, 0.0]),
        reqs,
        [PeriodicArrivals(period=0.01), PoissonArrivals(rate=40.0, seed=2)],
        clock=clk,
    )
    horizon = 0.5 if quick else 2.0
    t_wall = time.perf_counter()
    rep = gw.run(horizon, virtual_dt=dt)
    wall_s = time.perf_counter() - t_wall
    jitters = [j for t in rep.tenants for j in t.release_jitter]
    return {
        "virtual_dt_s": dt,
        "horizon_virtual_s": horizon,
        "wall_seconds": wall_s,
        "jobs_released": rep.total_released(),
        "release_jitter_s": _percentiles(jitters or [0.0]),
    }


def main() -> None:
    quick = "--quick" in sys.argv
    payload = {
        "bench": "admission",
        "quick": quick,
        "admit_check": bench_admit_check(quick),
        "gateway": bench_gateway_jitter(quick),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_admission.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
