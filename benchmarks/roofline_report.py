"""§Roofline: the 40-cell table from the dry-run artifacts.

Reads ``experiments/dryrun/*.json`` (written by `repro.launch.dryrun`),
derives the three roofline terms per (arch x shape) on the single-pod
mesh, identifies the dominant term, and emits the table EXPERIMENTS.md
§Roofline embeds.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import write_csv
from repro.launch.dryrun import ARCH_MODULES, load_config
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline
from repro.launch.shapes import SHAPES


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join("experiments", "dryrun", f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(mesh: str = "16x16"):
    arch_by_name = {}
    for m in ARCH_MODULES:
        cfg = load_config(m)
        arch_by_name[cfg.name] = cfg
    rows = []
    ok = skip = fail = 0
    worst = None
    most_coll = None
    for rec in load_records(mesh):
        if rec["status"] == "SKIP":
            skip += 1
            rows.append([rec["arch"], rec["shape"], "SKIP", "", "", "", "", "", ""])
            continue
        if rec["status"] != "OK":
            fail += 1
            rows.append([rec["arch"], rec["shape"], "FAIL", "", "", "", "", "", ""])
            continue
        ok += 1
        cfg = arch_by_name[rec["arch"]]
        case = SHAPES[rec["shape"]]
        coll = rec["collective_bytes"]["total"]
        rt = roofline(cfg, case, rec["chips"], coll)
        rows.append(
            [
                rec["arch"],
                rec["shape"],
                "OK",
                f"{rt.compute_s * 1e3:.3f}",
                f"{rt.memory_s * 1e3:.3f}",
                f"{rt.collective_s * 1e3:.3f}",
                rt.dominant,
                f"{rt.useful_ratio:.3f}",
                f"{rt.roofline_fraction:.3f}",
            ]
        )
        key = (rec["arch"], rec["shape"])
        if worst is None or rt.roofline_fraction < worst[1]:
            worst = (key, rt.roofline_fraction)
        if rt.dominant == "collective" and (
            most_coll is None or rt.collective_s > most_coll[1]
        ):
            most_coll = (key, rt.collective_s)
    write_csv(
        f"roofline_{mesh}.csv",
        [
            "arch", "shape", "status", "compute_ms", "memory_ms",
            "collective_ms", "dominant", "useful_ratio", "roofline_frac",
        ],
        rows,
    )
    derived = (
        f"cells ok={ok} skip={skip} fail={fail}; "
        f"worst-roofline={worst[0]} ({worst[1]:.2f}); "
        f"most-collective-bound={most_coll[0] if most_coll else 'none'}"
    )
    return derived


if __name__ == "__main__":
    print(run())
