"""Cross-layer conformance sweep -> BENCH_conformance.json.

Runs the `repro.conformance` harness over the registry's
contract-honouring scenarios x {fifo, edf} and records, per case and
per task, the three layers' responses (analytic bound, DES max,
virtual-runtime max), the verdict chain, and every ordering violation.
A clean run — the acceptance gate — has **zero** violations: the
analytic bound dominates the DES, the DES dominates the executing
runtime (within the tie-breaking tolerance), and no layer's
schedulability verdict inverts.

The sweep runs with ``record_traces`` on: every case row carries its
host ``wall_seconds`` and a ``trace_diff`` verdict (``identical`` or
the first divergent event) from the `repro.obs` schedule traces — the
bench asserts the verdict exists for every registry case.

Seven CI-enforced invariants ride on top of the sweep:

- **tightened tolerance** — the window-boundary DES must hold a
  DES-vs-runtime tolerance *strictly below* the PR-2 values that
  absorbed the idealized-DES deferral gap (asserted against
  `PR2_TOL_REL` / `PR2_QUANTUM_SLACK`), and — now that the DES adopts
  the runtime's simultaneous-event tie-breaking — strictly below the
  pre-alignment `PR3_QUANTUM_SLACK` too;
- **sharded cases** — `run_sharded_case` places ``sharded_city``
  across K pipeline shards (every placement policy) and holds every
  shard to the full three-layer contract plus a bit-exact per-shard
  admission verdict;
- **DSE case** — `run_dse_case` pushes the search's claimed-feasible
  designs through all three layers and serves the scenario on a
  DSE-provisioned 2-shard `ShardedGateway` (zero violations required);
- **shedding cases** — `run_shedding_case` drives overdriven
  scenarios with identical drop-shedding armed in DES and runtime and
  matches the surviving jobs by release time;
- **migration cases** — `run_migration_case` live-migrates
  ``sharded_city`` tenants between co-simulated elastic shards
  (slack-aware and explicit targets, both policies) and fails CI on
  any deadline violation during a handover, any DES/runtime
  survivor-set disagreement, or a re-home without a committed Eq. 3
  proof;
- **mode-switch cases** — `run_mode_switch_case` drives the
  mixed-criticality ``av_stack`` scenario with twin `ModeController`s
  armed in DES and runtime; CI fails on any HI-class guarantee miss
  across a transition, on survivor-set disagreement, and on a
  committed switch whose Eq. 3 re-proof failed;
- **wall-clock case** — `run_wallclock_case` drives the gateway on the
  real clock against the calibrated `CostModel` in calibrated-admission
  mode (tenancy admitted against measured WCETs; one retry absorbs a
  host throttle landing mid-run; two consecutive failures fail CI).

Also times a wall-clock WCET calibration pass (`CostModel.calibrate`)
on the ``steady_city`` serve bundle and reports measured-vs-modeled
segment WCET ratios — the "measured, not modeled" serve-path numbers
the ROADMAP asked for.

Run: ``PYTHONPATH=src python benchmarks/conformance_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_conformance.json``; exits
non-zero on any conformance violation so CI enforces the ordering.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

from repro.conformance import (
    DEFAULT_SCENARIOS,
    POLICIES,
    PR2_QUANTUM_SLACK,
    PR2_TOL_REL,
    PR3_QUANTUM_SLACK,
    ConformanceConfig,
    CostModel,
    run_conformance,
    run_dse_case,
    run_mode_switch_case,
    run_sharded_case,
    run_shedding_case,
    run_wallclock_case,
)
from repro.core.perfmodel.hardware import paper_platform

RESULTS_DIR = os.path.join("experiments", "benchmarks")


def _num(x: float):
    """inf-safe JSON scalar."""
    return None if not math.isfinite(x) else x


def bench_conformance(quick: bool, prebuilt: dict) -> tuple[dict, bool]:
    # record_traces: every case carries a DES-vs-runtime trace_diff —
    # the bench asserts a verdict (identical or first-divergence) is
    # present for every registry case, so a tripped tolerance always
    # arrives with its pinpointed divergent event
    cfg = ConformanceConfig(
        horizon_periods=24.0 if quick else 60.0, record_traces=True
    )
    # CI invariant: the window-boundary DES must run under a strictly
    # tighter DES-vs-runtime tolerance than the idealized-preemption
    # DES of PR 2 needed — loosening it back is a regression
    assert cfg.tol_rel < PR2_TOL_REL, (
        f"tol_rel {cfg.tol_rel} regressed to >= PR-2's {PR2_TOL_REL}"
    )
    assert cfg.quantum_slack < PR2_QUANTUM_SLACK, (
        f"quantum_slack {cfg.quantum_slack} regressed to >= "
        f"PR-2's {PR2_QUANTUM_SLACK}"
    )
    # ...and, since the DES adopted the runtime's simultaneous-event
    # tie-breaking, strictly tighter than the pre-alignment slack too
    assert cfg.quantum_slack < PR3_QUANTUM_SLACK, (
        f"quantum_slack {cfg.quantum_slack} regressed to >= "
        f"the pre-tie-break-alignment {PR3_QUANTUM_SLACK}"
    )
    t0 = time.perf_counter()
    report = run_conformance(
        DEFAULT_SCENARIOS,
        POLICIES,
        platform=paper_platform(16),
        cfg=cfg,
        prebuilt=prebuilt,
    )
    elapsed = time.perf_counter() - t0
    cases = []
    for c in report.cases:
        assert c.trace_diff is not None, (
            f"{c.scenario}/{c.policy}: record_traces produced no "
            "trace_diff verdict"
        )
        cases.append(
            {
                "scenario": c.scenario,
                "policy": c.policy,
                "analysis_schedulable": c.analysis_schedulable,
                "des_schedulable": c.des_schedulable,
                "server_bounded": c.server_bounded,
                "wall_seconds": c.wall_seconds,
                "trace_diff": c.trace_diff.summary(),
                "tasks": [
                    {
                        "task": t.task,
                        "analytic_bound_s": _num(t.analytic_bound),
                        "des_max_s": t.des_max,
                        "des_jobs": t.des_jobs,
                        "server_max_s": t.server_max,
                        "server_jobs": t.server_jobs,
                        "in_flight": t.in_flight,
                        "des_over_bound": _num(
                            t.des_max / t.analytic_bound
                            if t.analytic_bound > 0
                            and math.isfinite(t.analytic_bound)
                            else float("inf")
                        ),
                        "server_over_des": (
                            t.server_max / t.des_max
                            if t.des_max > 0
                            else None
                        ),
                    }
                    for t in c.tasks
                ],
                "violations": [str(v) for v in c.violations],
            }
        )
    payload = {
        "horizon_periods": cfg.horizon_periods,
        "wall_seconds": elapsed,
        "cases": cases,
        "total_violations": len(report.violations),
    }
    print(report.summary())
    return payload, report.ok


def bench_sharded(quick: bool, built) -> tuple[dict, bool]:
    """The sharded conformance cases: `sharded_city` placed across K
    pipeline shards, every shard held to the full three-layer contract
    plus the bit-exact per-shard admission check. K=1 anchors the
    equivalence (it *is* `run_case` plus the admission check)."""
    cfg = ConformanceConfig(horizon_periods=24.0 if quick else 40.0)
    placements = (
        ("least_loaded",)
        if quick
        else ("hash_by_tenant", "least_loaded", "slack_aware")
    )
    cases = []
    ok = True
    for policy in POLICIES:
        for shards, placement in [(1, "least_loaded")] + [
            (2, p) for p in placements
        ]:
            res = run_sharded_case(
                built, policy, shards=shards, placement=placement, cfg=cfg
            )
            ok = ok and res.ok
            cases.append(
                {
                    "scenario": res.scenario,
                    "policy": res.policy,
                    "shards": res.n_shards,
                    "placement": res.placement,
                    "assignment": list(res.assignment),
                    "shard_cases": [
                        {
                            "shard_scenario": c.scenario,
                            "analysis_schedulable": c.analysis_schedulable,
                            "des_schedulable": c.des_schedulable,
                            "server_bounded": c.server_bounded,
                            "violations": [str(v) for v in c.violations],
                        }
                        for c in res.cases
                    ],
                    "violations": [str(v) for v in res.violations],
                }
            )
            print(
                f"sharded {res.scenario:12s} {res.policy:4s} "
                f"K={res.n_shards} {res.placement:14s} "
                f"assign={res.assignment} viol={len(res.violations)}"
            )
    return {"cases": cases}, ok


def bench_dse(quick: bool) -> tuple[dict, bool]:
    """The DSE conformance case: the search's claimed-feasible designs
    pushed through analysis/DES/runtime, and the best design
    provisioned into a 2-shard `ShardedGateway` that must serve the
    scenario's traffic with zero violations — the acceptance gate of
    the DSE -> serving bridge."""
    cfg = ConformanceConfig(horizon_periods=16.0 if quick else 24.0)
    res = run_dse_case(
        "sharded_city",
        "edf",
        shards=2,
        check_top=1 if quick else 2,
        cfg=cfg,
    )
    print(
        f"dse {res.scenario:12s} {res.policy:4s} claimed={res.n_claimed} "
        f"checked={[round(u, 4) for u in res.checked_utils]} "
        f"K={res.n_shards} {res.placement} admitted={res.admitted} "
        f"released={res.released} viol={len(res.violations)}"
    )
    payload = {
        "scenario": res.scenario,
        "policy": res.policy,
        "method": res.method,
        "claimed_feasible": res.n_claimed,
        "checked_utils": list(res.checked_utils),
        "shards": res.n_shards,
        "placement": res.placement,
        "assignment": list(res.assignment),
        "admitted": res.admitted,
        "released": res.released,
        "cases": [
            {
                "analysis_schedulable": c.analysis_schedulable,
                "des_schedulable": c.des_schedulable,
                "server_bounded": c.server_bounded,
                "violations": [str(v) for v in c.violations],
            }
            for c in res.cases
        ],
        "violations": [str(v) for v in res.violations],
    }
    return payload, res.ok


def bench_shedding(quick: bool, prebuilt: dict) -> tuple[dict, bool]:
    """Overload conformance: overdriven scenarios with the same (drop)
    shedding machinery armed in DES and runtime — surviving jobs
    matched by release, verdict chain enforced."""
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import build, get_scenario

    cfg = ConformanceConfig(horizon_periods=24.0 if quick else 60.0)
    scenarios = ("overload_2x", "noisy_neighbor")
    policies = ("reject_newest",) if quick else (
        "reject_newest",
        "shed_by_value",
    )
    cases = []
    ok = True
    for name in scenarios:
        built = prebuilt.get(name) or build(
            get_scenario(name), paper_platform(16), beam_width=4
        )
        prebuilt[name] = built
        for shed_policy in policies:
            res = run_shedding_case(
                built, "edf", shed_policy=shed_policy, cfg=cfg
            )
            ok = ok and res.ok
            des_shed, srv_shed = res.total_shed()
            cases.append(
                {
                    "scenario": res.scenario,
                    "policy": res.policy,
                    "shed_policy": res.shed_policy,
                    "analysis_schedulable": res.analysis_schedulable,
                    "des_overloaded": res.des_overloaded,
                    "server_bounded": res.server_bounded,
                    "des_shed": des_shed,
                    "server_shed": srv_shed,
                    "tasks": [
                        {
                            "task": t.task,
                            "des_completed": t.des_completed,
                            "des_shed": t.des_shed,
                            "server_completed": t.server_completed,
                            "server_shed": t.server_shed,
                            "matched_jobs": t.matched_jobs,
                            "des_max_s": t.des_max,
                            "server_max_s": t.server_max,
                            "in_flight": t.in_flight,
                        }
                        for t in res.tasks
                    ],
                    "violations": [str(v) for v in res.violations],
                }
            )
            print(
                f"shedding {res.scenario:14s} {shed_policy:16s} "
                f"shed des/srv={des_shed}/{srv_shed} "
                f"viol={len(res.violations)}"
            )
    return {"cases": cases}, ok


def bench_migration(quick: bool, built) -> tuple[dict, bool]:
    """Live-migration conformance: `run_migration_case` re-homes
    ``sharded_city`` tenants between co-simulated elastic shards and
    holds the run to zero deadline violations during any handover,
    exact DES/runtime survivor-set agreement on every tenant, and a
    committed Eq. 3 proof behind every re-home. One slack-aware and one
    explicit-target migration per policy."""
    from repro.conformance import run_migration_case
    from repro.traffic.migration import MigrationPlan

    cfg = ConformanceConfig(horizon_periods=20.0 if quick else 40.0)
    cases = []
    ok = True
    policies = ("edf",) if quick else POLICIES
    for policy in policies:
        for label, plans in (
            ("slack_aware", None),
            (
                "explicit",
                [
                    MigrationPlan(
                        tenant=built.requests[0].name,
                        at=0.25 * cfg.horizon_periods
                        * max(r.period for r in built.requests),
                        target=1,
                    )
                ],
            ),
        ):
            res = run_migration_case(
                built, policy, shards=2, plans=plans, cfg=cfg
            )
            ok = ok and res.ok
            cases.append(
                {
                    "scenario": res.scenario,
                    "policy": res.policy,
                    "plan": label,
                    "shards": res.n_shards,
                    "commits": res.commits,
                    "aborts": res.aborts,
                    "final_assignment": [
                        list(x) for x in res.final_assignment
                    ],
                    "tenants": [
                        {
                            "tenant": t.tenant,
                            "migrated": t.migrated,
                            "donor": t.donor,
                            "target": t.target,
                            "committed": t.committed,
                            "held": t.held,
                            "runtime_survivors": t.runtime_survivors,
                            "des_survivors": t.des_survivors,
                            "runtime_misses": t.runtime_misses,
                            "des_misses": t.des_misses,
                        }
                        for t in res.tenants
                    ],
                    "violations": [str(v) for v in res.violations],
                }
            )
            print(
                f"migration {res.scenario:12s} {res.policy:4s} "
                f"{label:12s} commits={res.commits} aborts={res.aborts} "
                f"viol={len(res.violations)}"
            )
    return {"cases": cases}, ok


def bench_mode_switch(quick: bool, prebuilt: dict) -> tuple[dict, bool]:
    """Mixed-criticality mode-switch conformance: the ``av_stack``
    scenario (overdriven LO infotainment next to HI perception) with
    twin `ModeController`s armed in DES and runtime. The CI gate fails
    on *any* HI-class guarantee miss across a transition — a HI job
    exceeding the survivor set's Eq. 3 bound plus the transition
    allowance — and on survivor-set disagreement between the layers."""
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import build, get_scenario

    cfg = ConformanceConfig(horizon_periods=24.0 if quick else 60.0)
    configs = (
        (("degrade", "edf"), ("drop", "edf"))
        if quick
        else (
            ("degrade", "edf"),
            ("degrade", "fifo"),
            ("drop", "edf"),
            ("drop", "fifo"),
        )
    )
    built = prebuilt.get("av_stack") or build(
        get_scenario("av_stack"), paper_platform(16), beam_width=4
    )
    prebuilt["av_stack"] = built
    cases = []
    ok = True
    for action, policy in configs:
        res = run_mode_switch_case(built, policy, action=action, cfg=cfg)
        des_miss, srv_miss = res.hi_miss_totals()
        ok = ok and res.ok
        cases.append(
            {
                "scenario": res.scenario,
                "policy": res.policy,
                "action": res.action,
                "analysis_schedulable": res.analysis_schedulable,
                "hi_proof_schedulable": res.hi_proof_schedulable,
                "survivors": list(res.survivors),
                "des_switches": len(res.des_switches),
                "server_switches": len(res.server_switches),
                "hi_misses_des": des_miss,
                "hi_misses_server": srv_miss,
                "tasks": [
                    {
                        "task": t.task,
                        "criticality": t.criticality,
                        "des_completed": t.des_completed,
                        "des_shed": t.des_shed,
                        "des_degraded": t.des_degraded,
                        "des_misses": t.des_misses,
                        "server_completed": t.server_completed,
                        "server_shed": t.server_shed,
                        "server_degraded": t.server_degraded,
                        "server_misses": t.server_misses,
                        "matched_jobs": t.matched_jobs,
                        "des_max_s": t.des_max,
                        "server_max_s": t.server_max,
                    }
                    for t in res.tasks
                ],
                "violations": [str(v) for v in res.violations],
            }
        )
        print(
            f"mode {res.scenario:10s} {action:8s}/{policy:4s} "
            f"switches des/srv={len(res.des_switches)}/"
            f"{len(res.server_switches)} "
            f"hi_miss={des_miss}/{srv_miss} "
            f"survivors={list(res.survivors)} viol={len(res.violations)}"
        )
    return {"cases": cases}, ok


def bench_calibration(quick: bool, built) -> dict:
    """Wall-clock WCET calibration on the steady_city serve bundle."""
    from repro.pipeline.serve import PharosServer
    from repro.traffic.clock import VirtualClock

    serve_tasks, _reqs, _arr = built.serve_bundle(period_scale=1.0)
    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        built.design.n_stages,
        clock=clk.now,
        sleep=clk.sleep,
    )
    t0 = time.perf_counter()
    measured = CostModel.calibrate(srv, reps=2 if quick else 5)
    calib_s = time.perf_counter() - t0
    modeled = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    rows = []
    for i, t in enumerate(serve_tasks):
        for k in range(built.design.n_stages):
            b_meas = measured.segment_cost(i, k)
            b_model = modeled.segment_cost(i, k)
            if b_model > 0:
                rows.append(
                    {
                        "task": t.name,
                        "stage": k,
                        "measured_s": b_meas,
                        "modeled_s": b_model,
                        "ratio": b_meas / b_model,
                    }
                )
    return {
        "calibration_wall_seconds": calib_s,
        "segments": rows,
        "note": (
            "measured = host wall-clock window probes (jnp backend); "
            "modeled = TPU exec-model latency — the ratio is the "
            "host/TPU speed gap, stable within a run"
        ),
    }


def bench_wallclock(quick: bool, built) -> tuple[dict, bool]:
    """The calibrated wall-clock case (gateway on the real clock vs the
    measured `CostModel`), with one retry: a CPU-quota throttle or load
    spike landing mid-run inflates every wall number at once, which is
    host noise, not a model defect. Two failures in a row count.

    One `TraceRecorder` is shared across both attempts with
    ``annotate(attempt=n)``, so a throttle-discarded first attempt's
    schedule events stay in the trace (per-attempt event counts land in
    the payload) instead of vanishing with the retry."""
    from repro.obs import TraceRecorder

    cfg = ConformanceConfig(
        wall_horizon_periods=8.0 if quick else 12.0,
        wall_reps=2 if quick else 3,
        # ROADMAP's calibrated-admission mode: tenancy admission runs
        # against the measured WCET contracts on this host
        calibrated_admission=True,
    )
    recorder = TraceRecorder()
    attempts = []
    ok = False
    for attempt in range(2):
        recorder.annotate(attempt=attempt)
        events_before = len(recorder.events)
        t0 = time.perf_counter()
        case = run_wallclock_case(built, "edf", cfg=cfg, trace=recorder)
        attempts.append(
            {
                "attempt": attempt,
                "trace_events": len(recorder.events) - events_before,
                "policy": case.policy,
                "admission_mode": case.admission_mode,
                "period_scale": case.period_scale,
                "horizon_s": case.horizon_s,
                "margin": case.margin,
                "wall_seconds": time.perf_counter() - t0,
                "tasks": [
                    {
                        "task": t.task,
                        "measured_median_s": t.measured_median,
                        "measured_max_s": t.measured_max,
                        "jobs": t.jobs,
                        "predicted_des_max_s": t.predicted_des_max,
                        "predicted_bound_s": _num(t.predicted_bound),
                        "in_flight": t.in_flight,
                    }
                    for t in case.tasks
                ],
                "violations": [str(v) for v in case.violations],
            }
        )
        for row in case.tasks:
            print(
                f"wall[{attempt}] {row.task:16s} "
                f"median={1e3 * row.measured_median:7.3f}ms "
                f"max={1e3 * row.measured_max:7.3f}ms "
                f"bound={1e3 * row.predicted_bound:7.3f}ms "
                f"jobs={row.jobs}"
            )
        if case.ok:
            ok = True
            break
        if attempt == 0:
            print("wall-clock case violated; retrying once", file=sys.stderr)
        else:
            print("wall-clock case violated twice; giving up", file=sys.stderr)
    return {"attempts": attempts, "ok": ok}, ok


def main() -> None:
    from repro.traffic.scenarios import build, get_scenario

    quick = "--quick" in sys.argv
    # steady_city's DSE result is shared by the sweep, calibration and
    # the wall-clock case; sharded_city backs the sharded cases
    steady = build(
        get_scenario("steady_city"), paper_platform(16), beam_width=4
    )
    sharded_city = build(
        get_scenario("sharded_city"), paper_platform(16), beam_width=4
    )
    conf, ok = bench_conformance(quick, {"steady_city": steady})
    sharded, sharded_ok = bench_sharded(quick, sharded_city)
    dse, dse_ok = bench_dse(quick)
    shedding, shedding_ok = bench_shedding(quick, {})
    migration, migration_ok = bench_migration(quick, sharded_city)
    modes, modes_ok = bench_mode_switch(quick, {})
    wall, wall_ok = bench_wallclock(quick, steady)
    payload = {
        "bench": "conformance",
        "quick": quick,
        "conformance": conf,
        "sharded": sharded,
        "dse": dse,
        "shedding": shedding,
        "migration": migration,
        "mode_switch": modes,
        "wallclock": wall,
        "calibration": bench_calibration(quick, steady),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_conformance.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path}")
    if (
        not ok
        or not sharded_ok
        or not dse_ok
        or not shedding_ok
        or not migration_ok
        or not modes_ok
        or not wall_ok
    ):
        print("CONFORMANCE VIOLATIONS DETECTED", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
