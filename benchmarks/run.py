"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,seconds,derived`` CSV lines; per-benchmark CSV detail
lands in ``experiments/benchmarks/``. ``--quick`` shrinks grids for CI.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller grids")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()

    from benchmarks import (
        fig1_schedulability,
        fig6_sg_vs_tg,
        fig7_utilization,
        fig8_response_time,
        fig9_beam_quality,
        kernel_micro,
        roofline_report,
    )

    benches = {
        "fig1_schedulability": lambda: fig1_schedulability.run(
            5 if args.quick else 7
        ),
        "fig6_sg_vs_tg": lambda: fig6_sg_vs_tg.run(3 if args.quick else 5),
        "fig7_utilization": lambda: fig7_utilization.run(3 if args.quick else 4),
        "fig8_response_time": lambda: fig8_response_time.run(
            3 if args.quick else 4
        ),
        "fig9_beam_quality": lambda: fig9_beam_quality.run(
            6 if args.quick else 8
        ),
        "kernel_micro": kernel_micro.run,
        "roofline_16x16": lambda: roofline_report.run("16x16"),
        "roofline_2x16x16": lambda: roofline_report.run("2x16x16"),
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    print("name,seconds,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            derived = fn()
        except Exception as e:  # pragma: no cover
            derived = f"ERROR {type(e).__name__}: {e}"
            failures += 1
        dt = time.perf_counter() - t0
        print(f"{name},{dt:.2f},{derived}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
