"""Multi-gateway sharding benchmark -> BENCH_shard.json.

Scales the ``multi_tenant_rush`` scenario past one pipeline's Eq. 3
budget by replicating its tenant set (distinct names, re-seeded
traffic) and serves it on K = 1, 2, 4 `ShardedGateway` shards under
each placement policy, with the per-tenant token buckets armed and
disarmed, reporting per (K, placement, ratelimit):

- **admit rate**  — admitted tenants / total tenants: the replicated
  mix overcommits a single pipeline, so per-shard admission must turn
  tenants away at small K and admits more as capacity is added;
- **miss rate**   — deadline misses / completed jobs across shards;
- **shed fraction** — shedding-policy drops / scheduled releases (the
  scenario's MMPP camera and Poisson segmentation tenants are
  overdriven 3x, so backlog-triggered shedding engages on the shards
  that host them — unless the rate limiter trims them first);
- **response percentiles** — per-tenant p50/p95/p99 response times via
  the shared `ServerReport.response_percentiles` helper;
- **rate-limited fraction** — releases refused by the per-tenant token
  buckets (value-weighted, armed in front of every shard's admission).
  The armed rows show the tentpole division of labour: the bucket
  absorbs the contract violation up front, shedding drops to ~0 and
  the miss rate falls with it.

A second, elastic section ramps the tenant population up and back down
(25% -> 50% -> 100% -> 50% -> 25%) and compares the `Autoscaler` (K
free to grow/shrink inside [1, max K], emptiest shard drained before
removal) against static fleets at each K over the identical phases;
the bench gates on the autoscaled admit rate matching or beating every
static K.

Each shard runs deterministically (cost-model `PharosServer` on a
`VirtualClock`), so every number here is bit-reproducible.

Run: ``PYTHONPATH=src python benchmarks/shard_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_shard.json``.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core.perfmodel.hardware import paper_platform
from repro.traffic import RateLimiter, ShardedGateway
from repro.traffic.autoscale import Autoscaler, RampPhase
from repro.traffic.scenarios import (
    BuiltScenario,
    build,
    get_scenario,
    replicate,
)
from repro.traffic.shedding import get_policy

RESULTS_DIR = os.path.join("experiments", "benchmarks")

SCENARIO = "multi_tenant_rush"
PLACEMENTS = ("hash_by_tenant", "least_loaded", "slack_aware")


def ramp_phases(
    population: BuiltScenario, quick: bool
) -> tuple[RampPhase, ...]:
    """Tenant-count ramp over the replicated population: 25% -> 50% ->
    100% -> 50% -> 25% of the tenants arrive/depart across epochs (the
    quick sweep trims to the up-leg).  Each epoch runs long enough for
    the per-shard backlog dynamics to engage."""
    n = len(population.requests)
    duration = 8.0 * max(r.period for r in population.requests)
    fracs = (0.25, 0.5, 1.0) if quick else (0.25, 0.5, 1.0, 0.5, 0.25)
    phases = []
    for frac in fracs:
        count = max(1, round(frac * n))
        phases.append(
            RampPhase(duration=duration, active=tuple(range(count)))
        )
    return tuple(phases)


def run_ramp_point(
    population: BuiltScenario,
    phases: tuple[RampPhase, ...],
    min_shards: int,
    max_shards: int,
) -> dict:
    t0 = time.perf_counter()
    scaler = Autoscaler(
        population, min_shards=min_shards, max_shards=max_shards
    )
    report = scaler.run_ramp(phases)
    elapsed = time.perf_counter() - t0
    return {
        "min_shards": min_shards,
        "max_shards": max_shards,
        "admit_rate": report.admit_rate(),
        "max_shards_used": report.max_shards_used(),
        "shard_counts": report.shard_counts(),
        "final_assignment": {
            str(k): v for k, v in report.final_assignment().items()
        },
        "epochs": [
            {
                "t_start": ep.t_start,
                "n_shards": ep.n_shards,
                "active": ep.tenant_count(),
                "admitted": ep.admitted_count(),
                "rehomed": ep.rehomed,
                "grew": ep.grew,
                "shrank": ep.shrank,
            }
            for ep in report.epochs
        ],
        "wall_seconds": elapsed,
    }


def run_point(
    built: BuiltScenario,
    shards: int,
    placement: str,
    horizon_periods: float,
    ratelimit: bool,
) -> dict:
    gw = ShardedGateway.from_built(
        built,
        shards=shards,
        placement=placement,
        shedding=get_policy("reject_newest"),
        make_ratelimit=(
            (
                lambda reqs: RateLimiter.for_requests(
                    reqs, burst_periods=3.0, value_weighted=True
                )
            )
            if ratelimit
            else None
        ),
    )
    horizon = horizon_periods * max(r.period for r in built.requests)
    t0 = time.perf_counter()
    report = gw.run(horizon)
    elapsed = time.perf_counter() - t0
    assert gw.verify(), "a shard's cached Eq. 3 verdict diverged"

    tenants = report.tenants
    admitted = report.admitted_count()
    scheduled = sum(t.scheduled for t in tenants)
    shed = report.total_shed()
    rate_limited = report.total_rate_limited()
    completed = 0
    misses = 0
    # per-tenant response-time percentiles via the shared
    # `ServerReport.response_percentiles` helper (nearest-rank, the
    # same summary `repro.obs.MetricsRegistry` reports)
    response_pctl: dict[str, dict[str, float]] = {}
    for rep in report.reports:
        if rep is None:
            continue
        sr = rep.server_report
        completed += sr.jobs_completed
        misses += sum(sr.deadline_misses.values())
        for name, times in sr.response_times.items():
            if times:
                response_pctl[name] = sr.response_percentiles(name)
    return {
        "shards": shards,
        "placement": placement,
        "ratelimit": ratelimit,
        "assignment": list(report.plan.assignment),
        "tenants": len(tenants),
        "admitted": admitted,
        "admit_rate": admitted / len(tenants),
        "scheduled_releases": scheduled,
        "completed": completed,
        "deadline_misses": misses,
        "miss_rate": (misses / completed) if completed else None,
        "response_percentiles_s": response_pctl,
        "shed": shed,
        "shed_fraction": (shed / scheduled) if scheduled else None,
        "rate_limited": rate_limited,
        "rate_limited_fraction": (
            rate_limited / scheduled if scheduled else None
        ),
        # per-replica remaining capacity (the shard-aware headroom
        # report): slacks + the worst admitted tenant's rate multiplier
        "headroom": [
            None
            if hr is None
            else {
                "shard": hr.shard,
                "tenants": list(hr.tenants),
                "stage_slacks": list(hr.stage_slacks),
                "bottleneck": hr.bottleneck,
                "min_tenant_rate_multiplier": (
                    min(hr.tenant_rate_multipliers.values())
                    if hr.tenant_rate_multipliers
                    else None
                ),
            }
            for hr in report.headrooms
        ],
        "wall_seconds": elapsed,
    }


def main() -> None:
    quick = "--quick" in sys.argv
    copies = 2
    ks = (1, 2) if quick else (1, 2, 4)
    # backlog needs ~25+ periods to trip the shedding monitor even at
    # 3x overdrive, so quick mode keeps the full horizon and economizes
    # on the K sweep instead
    horizon_periods = 40.0

    built = build(
        get_scenario(SCENARIO), paper_platform(16), beam_width=4
    )
    population = replicate(built, copies)
    points = []
    for k in ks:
        for placement in PLACEMENTS:
            for ratelimit in (False, True):
                pt = run_point(
                    population, k, placement, horizon_periods, ratelimit
                )
                points.append(pt)
                nan = float("nan")

                def _f(x):
                    return nan if x is None else x

                print(
                    f"K={pt['shards']} {pt['placement']:14s} "
                    f"rl={'on ' if ratelimit else 'off'} "
                    f"admit={pt['admit_rate']:.2f} "
                    f"miss={_f(pt['miss_rate']):.3f} "
                    f"shed={_f(pt['shed_fraction']):.3f} "
                    f"ratelimited={_f(pt['rate_limited_fraction']):.3f}"
                )

    # scale sanity: adding shards must never admit fewer tenants under
    # the load-aware placements (hash placement is load-blind and gets
    # no monotonicity promise)
    for placement in ("least_loaded", "slack_aware"):
        for ratelimit in (False, True):
            rates = [
                p["admit_rate"]
                for p in points
                if p["placement"] == placement
                and p["ratelimit"] == ratelimit
            ]
            assert all(
                b >= a - 1e-12 for a, b in zip(rates, rates[1:])
            ), f"admit rate regressed with K under {placement}: {rates}"

    # elastic ramp gate: the autoscaler (K free to move in
    # [1, max(ks)]) must admit at least as many tenant-phases as every
    # static fleet run over the same ramp with the same epoch
    # machinery.  It can: any placement a static K proves, the
    # autoscaler can reach by growing to that K, and shrink only fires
    # when every evicted tenant re-proves elsewhere.
    phases = ramp_phases(population, quick)
    auto_pt = run_ramp_point(population, phases, 1, max(ks))
    print(
        f"ramp auto     K<={max(ks)} admit={auto_pt['admit_rate']:.2f} "
        f"shards={auto_pt['shard_counts']}"
    )
    static_pts = []
    for k in ks:
        pt = run_ramp_point(population, phases, k, k)
        static_pts.append(pt)
        print(
            f"ramp static   K={k}  admit={pt['admit_rate']:.2f} "
            f"shards={pt['shard_counts']}"
        )
        assert auto_pt["admit_rate"] >= pt["admit_rate"] - 1e-12, (
            f"autoscaled admit rate {auto_pt['admit_rate']} fell below "
            f"static K={k} ({pt['admit_rate']})"
        )

    payload = {
        "bench": "shard",
        "quick": quick,
        "scenario": SCENARIO,
        "copies": copies,
        "horizon_periods": horizon_periods,
        "points": points,
        "ramp": {
            "phases": [
                {"duration_s": p.duration, "active": len(p.active)}
                for p in phases
            ],
            "autoscaled": auto_pt,
            "static": static_pts,
        },
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_shard.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
