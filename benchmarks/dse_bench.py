"""Vectorized-DSE benchmark -> BENCH_dse.json.

Three measurements, two CI-enforced assertions:

1. **Evaluator core** — the same candidate batch priced by the scalar
   per-candidate `create_acc` loop vs one `BatchedDesignEvaluator`
   call, on warmed caches (both paths share the `LatencyCache`, so
   this isolates evaluation throughput, not model pricing). CI asserts
   the batched evaluator reaches **>= 5x** the scalar
   candidates/sec — the acceptance bar of the vectorization refactor.
2. **End-to-end search** — `beam_search` on the Fig. 9 problem with
   ``evaluator="scalar"`` vs ``"batched"``: same winner (asserted
   exactly), and the batched search must be wall-clock faster.
3. **SRT vs TG feasible counts** — `explore` with its two
   configurations over a ratio grid per task-set combo: the SRT beam's
   feasible-design counts vs the TG design's Eq. 2 gate and DES
   verdict (TG backtracks, so the DES stays its oracle) — the paper's
   headline comparison, now driven through one entry point.

Run: ``PYTHONPATH=src python benchmarks/dse_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_dse.json``; exits non-zero if a
speedup assertion fails so CI enforces the refactor's perf claim.
"""
from __future__ import annotations

import json
import os
import random
import sys
import time

import numpy as np

from repro.core.dse.batch_eval import BatchedDesignEvaluator
from repro.core.dse.beam import beam_search
from repro.core.dse.create_acc import LatencyCache, create_acc
from repro.core.dse.explore import explore
from repro.core.dse.throughput import tg_simtasks
from repro.core.perfmodel.hardware import paper_platform
from repro.core.workloads import PAPER_WORKLOADS, make_taskset
from repro.scheduler.des import SimConfig, simulate

RESULTS_DIR = os.path.join("experiments", "benchmarks")
#: the paper regime (matches `benchmarks.common.MAX_M`; self-contained
#: so CI can run this file directly)
MAX_M = 4

#: the Fig. 9 problem (search bench) and the feasibility-grid combos
FIG9_COMBO = ("pointnet", "deit_t")
GRID_COMBOS = (
    ("pointnet", "deit_t"),
    ("pointnet", "mlp_mixer"),
    ("resmlp", "deit_t"),
)
#: the acceptance bar: batched evaluator >= 5x scalar candidates/sec
MIN_EVAL_SPEEDUP = 5.0


def _problem(chips: int, ratios=(0.8, 0.8)):
    plat = paper_platform(chips)
    wls = [PAPER_WORKLOADS[c] for c in FIG9_COMBO]
    ts = make_taskset(FIG9_COMBO, ratios, plat)
    return plat, wls, ts


def bench_evaluator_core(quick: bool) -> dict:
    """Same candidates, scalar loop vs one batched call."""
    _plat, wls, ts = _problem(16)
    n_cand = 4_000 if quick else 20_000
    rng = random.Random(0)
    spans, chips = [], []
    for _ in range(n_cand):
        row = []
        for w in wls:
            a = rng.randint(0, w.num_layers)
            row.append((a, rng.randint(a, w.num_layers)))
        spans.append(row)
        chips.append(rng.randint(1, 16))
    cache = LatencyCache(wls)
    ev = BatchedDesignEvaluator(wls, ts, cache=cache)
    # warm both paths' latency tables (pricing is shared; the bench
    # measures evaluation throughput)
    ev.evaluate(np.array(spans[:64]), np.array(chips[:64]))
    for sp, ch in zip(spans[:64], chips[:64]):
        create_acc(tuple(sp), ch, ts, cache)

    t0 = time.perf_counter()
    for sp, ch in zip(spans, chips):
        create_acc(tuple(sp), ch, ts, cache)
    scalar_s = time.perf_counter() - t0

    sp_arr, ch_arr = np.array(spans), np.array(chips)
    t0 = time.perf_counter()
    ev.evaluate(sp_arr, ch_arr)
    batched_s = time.perf_counter() - t0

    out = {
        "candidates": n_cand,
        "scalar_seconds": scalar_s,
        "batched_seconds": batched_s,
        "scalar_cands_per_sec": n_cand / scalar_s,
        "batched_cands_per_sec": n_cand / batched_s,
        "speedup": scalar_s / batched_s,
    }
    print(
        f"evaluator core: scalar {out['scalar_cands_per_sec']:,.0f}/s, "
        f"batched {out['batched_cands_per_sec']:,.0f}/s "
        f"({out['speedup']:.1f}x)"
    )
    return out


def bench_search(quick: bool) -> dict:
    """End-to-end beam/brute search, scalar vs batched evaluator."""
    runs = []
    cases = [("beam_B8", 8, 8, 4), ("beam_B16", 8, 16, 4)]
    if not quick:
        cases.append(("brute_6chip", 6, None, 3))
    for label, n_chips, width, max_m in cases:
        plat, wls, ts = _problem(n_chips)
        row = {"search": label}
        results = {}
        for evk in ("scalar", "batched"):
            res = beam_search(
                wls, ts, plat, max_m=max_m, beam_width=width, evaluator=evk
            )
            results[evk] = res
            row[evk] = {
                "wall_s": res.stats.wall_time_s,
                "eval_s": res.stats.eval_seconds,
                "candidates": res.stats.create_acc_calls,
                "cands_per_sec": res.stats.candidates_per_sec,
                "feasible_found": res.stats.feasible_found,
                "best_util": (
                    res.best.max_util if res.best is not None else None
                ),
            }
        sb, bb = results["scalar"].best, results["batched"].best
        assert (sb is None) == (bb is None)
        if sb is not None:
            # the whole point of bit-compatibility: same winner
            assert sb.max_util == bb.max_util and sb.splits == bb.splits, (
                f"{label}: batched evaluator changed the winner"
            )
        row["speedup"] = (
            row["scalar"]["wall_s"] / row["batched"]["wall_s"]
        )
        runs.append(row)
        print(
            f"{label:12s}: scalar {row['scalar']['wall_s']:.3f}s, "
            f"batched {row['batched']['wall_s']:.3f}s "
            f"({row['speedup']:.2f}x), same winner"
        )
    return {"runs": runs}


def bench_srt_vs_tg(quick: bool) -> dict:
    """Feasible-found counts per task set: the SRT beam configuration
    vs the TG configuration of `explore`."""
    plat = paper_platform(16)
    grid_n = 2 if quick else 3
    lo, hi = 0.4, 1.2
    vals = [
        lo + i * (hi - lo) / (grid_n - 1) if grid_n > 1 else lo
        for i in range(grid_n)
    ]
    rows = []
    combos = GRID_COMBOS[: 2 if quick else len(GRID_COMBOS)]
    for combo in combos:
        wls = [PAPER_WORKLOADS[c] for c in combo]
        srt_found = tg_eq2 = tg_des = 0
        points = 0
        srt_rate = []
        for ra in vals:
            for rb in vals:
                points += 1
                ts = make_taskset(combo, (ra, rb), plat)
                srt = explore(
                    wls, ts, plat, method="beam", max_m=MAX_M, beam_width=8
                )
                srt_found += srt.best is not None
                srt_rate.append(srt.stats.candidates_per_sec)
                tg = explore(wls, ts, plat, method="tg", n_accs=MAX_M)
                tg_eq2 += tg.tg_eq2_feasible
                sims = tg_simtasks(tg.tg, ts)
                des = simulate(sims, SimConfig(policy="edf"))
                tg_des += des.schedulable
        rows.append(
            {
                "combo": "+".join(combo),
                "grid_points": points,
                "srt_feasible": srt_found,
                "tg_eq2_feasible": tg_eq2,
                "tg_des_schedulable": tg_des,
                "srt_cands_per_sec_mean": sum(srt_rate) / len(srt_rate),
            }
        )
        print(
            f"{'+'.join(combo):22s}: SRT {srt_found}/{points} feasible, "
            f"TG eq2 {tg_eq2}/{points}, TG DES {tg_des}/{points}"
        )
    return {"grid": vals, "combos": rows}


def main() -> None:
    quick = "--quick" in sys.argv
    core = bench_evaluator_core(quick)
    search = bench_search(quick)
    srt_tg = bench_srt_vs_tg(quick)
    payload = {
        "bench": "dse",
        "quick": quick,
        "min_eval_speedup": MIN_EVAL_SPEEDUP,
        "evaluator_core": core,
        "search": search,
        "srt_vs_tg": srt_tg,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_dse.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path}")

    ok = True
    if core["speedup"] < MIN_EVAL_SPEEDUP:
        print(
            f"FAIL: batched evaluator only {core['speedup']:.1f}x the "
            f"scalar core (need >= {MIN_EVAL_SPEEDUP}x)",
            file=sys.stderr,
        )
        ok = False
    for row in search["runs"]:
        if row["speedup"] <= 1.0:
            print(
                f"FAIL: batched search slower than scalar on "
                f"{row['search']} ({row['speedup']:.2f}x)",
                file=sys.stderr,
            )
            ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
