"""Render the §Dry-run and §Roofline markdown tables from the records."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun import ARCH_MODULES, load_config
from repro.launch.roofline import roofline
from repro.launch.shapes import SHAPES


def records(mesh):
    out = []
    for p in sorted(glob.glob(f"experiments/dryrun/*__{mesh}.json")):
        if "kvint8" in p:
            continue
        with open(p) as f:
            out.append(json.load(f))
    return out


def dryrun_table(mesh):
    lines = [
        "| arch | shape | status | compile s | coll bytes/dev | args+temp GB/dev |",
        "|---|---|---|---|---|---|",
    ]
    for r in records(mesh):
        if r["status"] != "OK":
            reason = "sub-quadratic-only shape" if r["status"] == "SKIP" else r.get("error", "")[:40]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | {reason} |")
            continue
        gb = (r["memory"]["argument_size_bytes"] + r["memory"]["temp_size_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | OK | {r['compile_s']} | "
            f"{r['collective_bytes']['total']:.2e} | {gb:.1f} |"
        )
    return "\n".join(lines)


def roofline_table(mesh):
    arch_by_name = {load_config(m).name: load_config(m) for m in ARCH_MODULES}
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in records(mesh):
        if r["status"] != "OK":
            continue
        rt = roofline(
            arch_by_name[r["arch"]],
            SHAPES[r["shape"]],
            r["chips"],
            r["collective_bytes"]["total"],
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rt.compute_s:.3f} | "
            f"{rt.memory_s:.3f} | {rt.collective_s:.3f} | {rt.dominant} | "
            f"{rt.useful_ratio:.2f} | {rt.roofline_fraction:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    os.makedirs("experiments/rendered", exist_ok=True)
    for mesh in ("16x16", "2x16x16"):
        with open(f"experiments/rendered/dryrun_{mesh}.md", "w") as f:
            f.write(dryrun_table(mesh) + "\n")
        with open(f"experiments/rendered/roofline_{mesh}.md", "w") as f:
            f.write(roofline_table(mesh) + "\n")
    print("rendered")
