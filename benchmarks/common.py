"""Shared benchmark plumbing: platform, combos, grids, CSV output."""
from __future__ import annotations

import csv
import os
import time
from dataclasses import dataclass

from repro.core.perfmodel.hardware import paper_platform
from repro.core.workloads import PAPER_COMBOS, PAPER_WORKLOADS, make_taskset

#: 16-chip slice, max_M=4 — the VCK5000-regime platform (DESIGN.md §2)
PLATFORM = paper_platform(16)
MAX_M = 4
BEAM = 8

RESULTS_DIR = os.path.join("experiments", "benchmarks")


def period_grid(n: int, lo: float = 0.3, hi: float = 1.8):
    """(P'/P1, P'/P2) ratio grid; larger ratio = heavier (paper Figs 1/6/7)."""
    step = (hi - lo) / (n - 1) if n > 1 else 0.0
    vals = [lo + i * step for i in range(n)]
    return [(a, b) for a in vals for b in vals]


def combo_workloads(combo):
    return [PAPER_WORKLOADS[c] for c in combo]


def taskset_for(combo, ratios):
    return make_taskset(combo, ratios, PLATFORM)


def write_csv(name: str, header: list[str], rows: list[list]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
