"""Paper Fig. 6: SRT-schedulable taskset counts, SG vs TG under each
scheduling policy, across the six application combinations.

Policies (paper §5.2):
- SG+FIFO      — guaranteed by Eq. 3 (verified by DES anyway),
- SG+EDF       — Eq. 3 on overhead-inflated WCETs + DES,
- TG+FIFO w/o polling, TG+FIFO w/ polling, TG+EDF — DES only (TG
  designs backtrack; the guideline theory does not apply).

Also reproduces the preemption-frequency claim: SG+EDF preempts ~10x
less than TG+EDF (pipelined topology keeps at most one ready job per
task per stage).
"""
from __future__ import annotations

from benchmarks.common import (
    BEAM,
    MAX_M,
    PLATFORM,
    combo_workloads,
    period_grid,
    taskset_for,
    write_csv,
)
from repro.core.dse.explore import explore
from repro.core.dse.space import evaluate_design
from repro.core.dse.throughput import tg_simtasks
from repro.core.workloads import PAPER_COMBOS
from repro.scheduler.des import SimConfig, StageOverhead, simulate, simulate_taskset

POLICIES = ("sg_fifo", "sg_edf", "tg_fifo_nopoll", "tg_fifo_poll", "tg_edf")


def run(grid_n: int = 5):
    rows = []
    agg = {p: 0 for p in POLICIES}
    preempt = {"sg_edf": 0, "tg_edf": 0}
    for combo in PAPER_COMBOS:
        wls = combo_workloads(combo)
        counts = {p: 0 for p in POLICIES}
        for ratios in period_grid(grid_n):
            ts = taskset_for(combo, ratios)
            # SG and TG are the two configurations of the one driver
            sg = explore(
                wls, ts, PLATFORM, method="beam", max_m=MAX_M,
                beam_width=BEAM,
            )
            if sg.best is not None:
                table = evaluate_design(sg.best.accs, sg.best.splits, wls, ts)
                counts["sg_fifo"] += 1  # Eq.3 guarantee (FIFO, no overhead)
                edf = simulate_taskset(table, ts, "edf")
                counts["sg_edf"] += edf.schedulable
                preempt["sg_edf"] += edf.preemptions
            tg = explore(wls, ts, PLATFORM, method="tg", n_accs=MAX_M).tg
            sims = tg_simtasks(tg, ts)
            ovs = [
                StageOverhead(o / 3, o / 3, o / 3) for o in tg.table.overhead
            ]
            r_np = simulate(sims, SimConfig(policy="fifo_no_polling"))
            r_p = simulate(sims, SimConfig(policy="fifo"))
            r_e = simulate(sims, SimConfig(policy="edf", overheads=ovs))
            counts["tg_fifo_nopoll"] += r_np.schedulable
            counts["tg_fifo_poll"] += r_p.schedulable
            counts["tg_edf"] += r_e.schedulable
            preempt["tg_edf"] += r_e.preemptions
        rows.append(["+".join(combo)] + [counts[p] for p in POLICIES])
        for p in POLICIES:
            agg[p] += counts[p]
    write_csv("fig6_sg_vs_tg.csv", ["combo", *POLICIES], rows)
    best_tg = max(agg["tg_fifo_nopoll"], agg["tg_fifo_poll"], agg["tg_edf"])
    gain = agg["sg_fifo"] / max(best_tg, 1)
    pre_ratio = preempt["tg_edf"] / max(preempt["sg_edf"], 1)
    derived = (
        f"sg_fifo={agg['sg_fifo']} sg_edf={agg['sg_edf']} "
        f"tg_nopoll={agg['tg_fifo_nopoll']} tg_poll={agg['tg_fifo_poll']} "
        f"tg_edf={agg['tg_edf']} | sg/bestTG={gain:.2f}x "
        f"(paper: 1.44-2.28x) | preempt TG/SG={pre_ratio:.1f}x (paper ~10x)"
    )
    return derived


if __name__ == "__main__":
    print(run())
