"""Paper Fig. 7: max-utilization quality, SG vs TG, and beam width B.

For every feasible cell of the grid, compare max(util) of the SG design
vs the TG design, per combination; then show the B=16 beam recovering
the cells where B=8 is suboptimal (paper: SG avg 3.7/4.6/-2.4/6.2/3.9/
5.1% better; -2.4% case flips positive at B=16/32).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    MAX_M,
    PLATFORM,
    combo_workloads,
    period_grid,
    taskset_for,
    write_csv,
)
from repro.core.dse.beam import beam_search
from repro.core.dse.throughput import throughput_guided_design
from repro.core.workloads import PAPER_COMBOS


def run(grid_n: int = 4):
    rows = []
    summary = []
    for combo in PAPER_COMBOS:
        wls = combo_workloads(combo)
        diffs8, diffs16 = [], []
        for ratios in period_grid(grid_n, lo=0.3, hi=1.0):
            ts = taskset_for(combo, ratios)
            tg = throughput_guided_design(wls, ts, PLATFORM, MAX_M)
            b8 = beam_search(wls, ts, PLATFORM, max_m=MAX_M, beam_width=8)
            b16 = beam_search(wls, ts, PLATFORM, max_m=MAX_M, beam_width=16)
            if b8.best is None or b16.best is None:
                continue
            diffs8.append((tg.max_util - b8.best.max_util) / tg.max_util)
            diffs16.append((tg.max_util - b16.best.max_util) / tg.max_util)
            rows.append(
                [
                    "+".join(combo),
                    f"{ratios[0]:.2f}",
                    f"{ratios[1]:.2f}",
                    f"{tg.max_util:.4f}",
                    f"{b8.best.max_util:.4f}",
                    f"{b16.best.max_util:.4f}",
                ]
            )
        if diffs8:
            summary.append(
                (
                    "+".join(combo),
                    100 * float(np.mean(diffs8)),
                    100 * float(np.mean(diffs16)),
                )
            )
    write_csv(
        "fig7_utilization.csv",
        ["combo", "r1", "r2", "tg_util", "sg_b8_util", "sg_b16_util"],
        rows,
    )
    parts = [f"{c}: B8 {a:+.1f}% B16 {b:+.1f}%" for c, a, b in summary]
    derived = " | ".join(parts) + " (positive = SG better; paper avg +3.5%)"
    return derived


if __name__ == "__main__":
    print(run())
