"""Observability benchmark -> BENCH_obs.json.

CI-enforces the two tracing contracts of `repro.obs`:

- **zero emission when off** — tracing is opt-in everywhere; a DES run
  and a full virtual `TrafficGateway` run handed a *disabled*
  `TraceRecorder` must emit **exactly zero** events (every layer
  resolves the handle once and never calls a disabled recorder);
- **<5% DES slowdown when on** — the tentpole's overhead budget:
  paired, interleaved DES timings (tracing off vs on, the median
  of per-rep paired ratios, GC isolated so allocator pauses don't land on one arm) on
  the ``sensor_fusion`` window-preemption case must stay within
  ``MAX_OVERHEAD_FRAC``. One retry absorbs a host load spike landing
  mid-measurement (the same policy the wall-clock conformance case
  uses); two consecutive failures fail CI.

On top of the gates, the bench exercises the whole observability
surface once so the artifact doubles as a worked example:

- `MetricsRegistry.from_trace` snapshot (tardiness / response
  percentiles, preemption + xi counters, backlog gauges) with the
  Eq. 3 per-stage slack gauges filled from the admitted tenant set
  (`AdmissionController.headroom_report`);
- the Chrome-trace exporter (`write_chrome_trace`) on the DES stream —
  the written file loads in Perfetto / ``chrome://tracing``;
- a `trace_diff` self-check: a stream diffed against itself must be
  ``identical``; the same stream with one completion nudged past the
  tolerance must report exactly that event as the first divergence.

Run: ``PYTHONPATH=src python benchmarks/obs_bench.py [--quick]``
Writes ``experiments/benchmarks/BENCH_obs.json`` (and the demo trace
``experiments/benchmarks/TRACE_obs_demo.json``); exits non-zero if
either tracing contract is violated.
"""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import replace as dc_replace
from statistics import median

from repro.conformance import CostModel
from repro.core.perfmodel.hardware import paper_platform
from repro.core.rt.task import SegmentTable
from repro.obs import (
    MetricsRegistry,
    TraceRecorder,
    trace_diff,
    write_chrome_trace,
)
from repro.scheduler.des import simulate_taskset
from repro.traffic.scenarios import build, get_scenario

RESULTS_DIR = os.path.join("experiments", "benchmarks")

#: the tentpole's enabled-tracing overhead budget on the DES
MAX_OVERHEAD_FRAC = 0.05


def _des_inputs(built, horizon_periods: float):
    """The window-preemption DES inputs the conformance case runs."""
    serve_tasks, requests, _arr = built.serve_bundle(
        period_scale=1.0, seed=0, max_dim=512
    )
    cm = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    table = SegmentTable(
        base=cm.segment_table().base, overhead=[0.0] * cm.n_stages
    )
    horizon = horizon_periods * max(t.period for t in built.taskset.tasks)
    traces = built.des_arrivals(horizon)
    return table, cm, horizon, traces, requests


def _run_des(built, table, cm, horizon, traces, trace):
    return simulate_taskset(
        table,
        built.taskset,
        "edf",
        horizon=horizon,
        arrivals=traces,
        chunk_schedules=cm.chunk_schedule(),
        preemption="window",
        trace=trace,
    )


def bench_overhead(quick: bool) -> tuple[dict, bool]:
    """Paired DES timings, tracing off vs on, interleaved so host
    drift hits both arms equally; the reported overhead is the
    median of the per-rep paired ratios (each rep's pair runs
    back-to-back, so host speed drift cancels within the pair —
    per-arm aggregates don't have that property). Runs the
    ``sensor_fusion`` case — the registry's heaviest DES (most
    scheduling decisions per run), so the ratio is measured where
    per-event cost matters most and the per-rep run is long enough
    that timer noise does not swamp a percent-level budget. GC is
    collected and paused around each timed run: a generational pass
    triggered by the event buffer would otherwise bill an arbitrary
    arm for unrelated garbage. A measurement exceeding the budget is
    retried once (host load spikes are noise, not instrumentation
    cost); two consecutive failures count. Timings use CPU time
    (`time.process_time`): the instrumentation budget is CPU cost, and
    wall clock on a contended host charges scheduler preemptions to
    whichever arm they land on."""
    import gc

    built = build(
        get_scenario("sensor_fusion"), paper_platform(16), beam_width=4
    )
    horizon_periods = 30.0 if quick else 60.0
    reps = 11 if quick else 15
    table, cm, horizon, traces, _req = _des_inputs(built, horizon_periods)
    # warm both paths (JIT-free, but first-touch allocations matter)
    _run_des(built, table, cm, horizon, traces, None)
    _run_des(built, table, cm, horizon, traces, TraceRecorder())

    def measure() -> tuple[float, float, float, int]:
        t_off, t_on, ratios, n_events = [], [], [], 0
        for _ in range(reps):
            gc.collect()
            gc.disable()
            try:
                t0 = time.process_time()
                _run_des(built, table, cm, horizon, traces, None)
                off = time.process_time() - t0
                rec = TraceRecorder()
                t0 = time.process_time()
                _run_des(built, table, cm, horizon, traces, rec)
                on = time.process_time() - t0
            finally:
                gc.enable()
            t_off.append(off)
            t_on.append(on)
            ratios.append((on - off) / off)
            n_events = len(rec.events)
        # the estimator is the MEDIAN OF PAIRED PER-REP RATIOS: each
        # rep's off/on pair runs back-to-back, so the host's
        # seconds-scale speed drift (frequency scaling, neighbors)
        # cancels within the pair; independent per-arm minima/medians
        # can land in different machine states and swing points in
        # either direction (measured on this very case)
        return median(ratios), median(t_off), median(t_on), n_events

    attempts = []
    ok = False
    for attempt in range(2):
        overhead, off_s, on_s, n_events = measure()
        ok = overhead < MAX_OVERHEAD_FRAC
        attempts.append(
            {
                "attempt": attempt,
                "des_off_s": off_s,
                "des_on_s": on_s,
                "overhead_frac": overhead,
            }
        )
        print(
            f"overhead[{attempt}]: des off={1e3 * off_s:.2f}ms "
            f"on={1e3 * on_s:.2f}ms ({n_events} events) -> "
            f"{100 * overhead:+.2f}% "
            f"(budget {100 * MAX_OVERHEAD_FRAC:.0f}%) "
            f"{'OK' if ok else 'VIOLATED'}"
        )
        if ok:
            break
        print(
            f"tracing overhead {100 * overhead:.2f}% exceeds the "
            f"{100 * MAX_OVERHEAD_FRAC:.0f}% budget"
            + ("; retrying once" if attempt == 0 else "; giving up"),
            file=sys.stderr,
        )
    return {
        "scenario": "sensor_fusion",
        "reps": reps,
        "horizon_periods": horizon_periods,
        "events_per_run": n_events,
        "attempts": attempts,
        "overhead_frac": attempts[-1]["overhead_frac"],
        "budget_frac": MAX_OVERHEAD_FRAC,
        "ok": ok,
    }, ok


def bench_zero_emission(built, quick: bool) -> tuple[dict, bool]:
    """A disabled recorder through the DES *and* a full virtual gateway
    run (admission, rate limiting, shedding paths armed) must stay
    empty."""
    from repro.traffic import RateLimiter
    from repro.traffic.clock import VirtualClock
    from repro.traffic.gateway import TrafficGateway
    from repro.traffic.shedding import get_policy
    from repro.pipeline.serve import PharosServer

    table, cm, horizon, traces, requests = _des_inputs(
        built, 20.0 if quick else 40.0
    )
    off = TraceRecorder(enabled=False)
    _run_des(built, table, cm, horizon, traces, off)
    des_events = len(off.events)

    serve_tasks, requests, arrivals = built.serve_bundle(
        period_scale=1.0, seed=0, max_dim=512
    )
    from repro.traffic.admission import AdmissionController

    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        built.design.n_stages,
        policy="edf",
        cost_model=cm,
        clock=clk.now,
        sleep=clk.sleep,
        trace=off,
    )
    gw = TrafficGateway(
        srv,
        AdmissionController(
            [0.0] * built.design.n_stages, preemptive=True
        ),
        requests,
        arrivals,
        shedding=get_policy("reject_newest"),
        ratelimit=RateLimiter.for_requests(requests, burst_periods=3.0),
        clock=clk,
        trace=off,
    )
    gw.run(horizon)
    total = len(off.events)
    ok = total == 0 and des_events == 0
    print(
        f"zero-emission: disabled recorder collected {total} events "
        f"{'OK' if ok else 'VIOLATED'}"
    )
    if not ok:
        print(
            f"disabled tracing emitted {total} events (must be 0)",
            file=sys.stderr,
        )
    return {"events_while_disabled": total, "ok": ok}, ok


def bench_surface(built, quick: bool) -> dict:
    """One worked pass over metrics, export and diff."""
    from repro.traffic.admission import AdmissionController

    table, cm, horizon, traces, requests = _des_inputs(
        built, 20.0 if quick else 40.0
    )
    rec = TraceRecorder()
    _run_des(built, table, cm, horizon, traces, rec)

    # metrics: trace-derived registry + Eq. 3 slack gauges from the
    # admitted tenant set
    reg = MetricsRegistry.from_trace(rec.events)
    admission = AdmissionController(
        [0.0] * built.design.n_stages, preemptive=True
    )
    for r in requests:
        admission.admit(r)
    hr = admission.headroom_report()
    reg.set_eq3_slacks([s.slack for s in hr.stages])
    snapshot = reg.snapshot()

    # Chrome export (Perfetto-loadable)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(RESULTS_DIR, "TRACE_obs_demo.json")
    doc = write_chrome_trace(rec.events, trace_path)

    # diff self-check: identical against itself ...
    same = trace_diff(rec, rec)
    assert same.identical, f"self-diff not identical: {same.summary()}"
    # ... and a single nudged completion is *the* reported divergence
    completes = [e for e in rec.events if e.kind == "complete"]
    victim = completes[len(completes) // 2]
    perturbed = [
        dc_replace(e, t=e.t + 1.0) if e is victim else e
        for e in rec.events
    ]
    skewed = trace_diff(rec.events, perturbed, time_tol=1e-6)
    assert not skewed.identical, "perturbed diff claims identical"
    assert skewed.divergence is not None
    assert skewed.divergence.task == victim.task, (
        f"divergence blamed {skewed.divergence.task}, "
        f"nudged {victim.task}"
    )
    print(f"diff self-check: {same.summary()} / {skewed.summary()}")

    return {
        "metrics_snapshot": snapshot,
        "eq3_stage_slacks": [s.slack for s in hr.stages],
        "chrome_trace_path": trace_path,
        "chrome_trace_events": len(doc["traceEvents"]),
        "diff_identical": same.summary(),
        "diff_perturbed": skewed.summary(),
    }


def main() -> None:
    quick = "--quick" in sys.argv
    built = build(
        get_scenario("steady_city"), paper_platform(16), beam_width=4
    )
    zero, zero_ok = bench_zero_emission(built, quick)
    over, over_ok = bench_overhead(quick)
    payload = {
        "bench": "obs",
        "quick": quick,
        "zero_emission": zero,
        "overhead": over,
        "surface": bench_surface(built, quick),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nwrote {path}")
    if not (zero_ok and over_ok):
        print("OBSERVABILITY CONTRACT VIOLATED", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
