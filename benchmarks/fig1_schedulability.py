"""Paper Fig. 1: SRT-schedulability of fixed vs TG-DSE vs SG-DSE.

One application combination, a grid of tasksets (period ratios); count
how many tasksets each methodology can make SRT-schedulable. Paper
headline: SG covers 49 vs 13 (TG) vs 3 (fixed) -> 3.76x over TG.

(The paper pairs PointNet with a Bert-S block; Bert-S is not among our
extracted workloads, so the transformer-block stand-in is DeiT-T —
same layer structure: qkv/attn/proj/ffn.)
"""
from __future__ import annotations

from benchmarks.common import (
    BEAM,
    MAX_M,
    PLATFORM,
    combo_workloads,
    period_grid,
    taskset_for,
    write_csv,
)
from repro.core.dse.beam import beam_search
from repro.core.dse.space import fixed_design
from repro.core.dse.throughput import throughput_guided_design, tg_simtasks
from repro.scheduler.des import SimConfig, simulate

COMBO = ("pointnet", "deit_t")


def run(grid_n: int = 7):
    wls = combo_workloads(COMBO)
    rows = []
    counts = {"fixed": 0, "tg": 0, "sg": 0}
    for ratios in period_grid(grid_n):
        ts = taskset_for(COMBO, ratios)
        fx = fixed_design(wls, ts, PLATFORM)
        fixed_ok = fx.max_util <= 1.0
        tg = throughput_guided_design(wls, ts, PLATFORM, MAX_M)
        tg_ok = simulate(
            tg_simtasks(tg, ts), SimConfig(policy="fifo")
        ).schedulable
        sg = beam_search(wls, ts, PLATFORM, max_m=MAX_M, beam_width=BEAM)
        sg_ok = sg.best is not None
        counts["fixed"] += fixed_ok
        counts["tg"] += tg_ok
        counts["sg"] += sg_ok
        rows.append(
            [
                f"{ratios[0]:.2f}",
                f"{ratios[1]:.2f}",
                int(fixed_ok),
                int(tg_ok),
                int(sg_ok),
                f"{fx.max_util:.3f}",
                f"{tg.max_util:.3f}",
                f"{sg.best.max_util:.3f}" if sg.best else "inf",
            ]
        )
    write_csv(
        "fig1_schedulability.csv",
        ["r1", "r2", "fixed_ok", "tg_ok", "sg_ok", "fixed_util", "tg_util", "sg_util"],
        rows,
    )
    total = grid_n * grid_n
    ratio = counts["sg"] / max(counts["tg"], 1)
    derived = (
        f"grid={total} fixed={counts['fixed']} tg={counts['tg']} "
        f"sg={counts['sg']} sg/tg={ratio:.2f}x (paper: 3.76x)"
    )
    return derived


if __name__ == "__main__":
    print(run())
