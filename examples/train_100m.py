"""End-to-end training driver: a ~100M-param model for a few hundred
steps on CPU, with checkpoints, auto-resume, and fault tolerance.

The model is a scaled-down stablelm-family config (~100M params, the
largest that trains in reasonable CPU time); the data pipeline is the
deterministic synthetic corpus; checkpoints commit atomically every 50
steps so killing and relaunching this script resumes (try it!).

Run: ``PYTHONPATH=src python examples/train_100m.py [--steps 300]``
"""
import argparse
import dataclasses

from repro.launch.dryrun import load_config
from repro.launch.train import train_loop
from repro.models.module import param_count
from repro.models import lm
import jax


def build_100m():
    base = load_config("stablelm_1_6b")
    return dataclasses.replace(
        base,
        name="stablelm-100m",
        n_layers=6,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=2048,
        vocab=32768,
        max_seq=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/pharos_train_100m")
    args = ap.parse_args()

    cfg = build_100m()
    n = param_count(lm.init_params(jax.random.PRNGKey(0), cfg))
    print(f"[train_100m] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    losses = train_loop(
        cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=6e-4,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=20,
        schedule_steps=args.steps,
    )
    k = max(1, len(losses) // 10)
    print(f"[train_100m] loss {sum(losses[:k])/k:.4f} -> "
          f"{sum(losses[-k:])/k:.4f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
