"""Admission-controlled serving under bursty traffic.

Runs the ``rush_hour`` scenario (sporadic LiDAR PointNet + a bursty
MMPP DeiT camera stream) end-to-end through the traffic subsystem:

1. the scenario is resolved against the paper platform — the DSE picks
   the pipelined design, producing the `SegmentTable` the admission
   controller reasons over;
2. every tenant passes online admission (O(stages) incremental Eq. 3)
   and the controller prints its headroom report — how much more
   traffic each stage/tenant could take;
3. the `TrafficGateway` releases the MMPP/sporadic traffic into a
   `PharosServer` on a deterministic `VirtualClock` (real GEMM windows,
   virtual time driven per-window by the conformance `CostModel` — the
   same WCETs the analysis uses), with reject-newest shedding armed;
4. the same pipeline is then hammered with the ``overload_2x`` scenario
   — traffic at twice its provisioned rate — to show the backlog
   monitor engaging shedding when reality contradicts the analysis.

5. finally the multi-tenant scale layer: the ``multi_tenant_rush``
   scenario is served on a `ShardedGateway` — K replicas of one
   pipeline with slack-aware tenant placement, per-shard Eq. 3
   admission, and value-weighted per-tenant token buckets trimming the
   overdriven tenants back to their contracts.

Run: ``PYTHONPATH=src python examples/serve_gateway.py``

``--trace out.json`` records every run (gateway, runtime and sharded)
into one `repro.obs.TraceRecorder` — each scenario pass tagged via
``annotate(scenario=...)`` — and writes the combined Chrome-trace
JSON, loadable in Perfetto or chrome://tracing.
"""
import argparse

import numpy as np

from repro.core.perfmodel.hardware import paper_platform
from repro.obs import TraceRecorder, percentile, write_chrome_trace
from repro.pipeline.serve import PharosServer
from repro.traffic import (
    AdmissionController,
    RateLimiter,
    ShardedGateway,
    TrafficGateway,
    VirtualClock,
    build,
    get_scenario,
)
from repro.traffic.shedding import get_policy


def run_scenario(
    name: str, horizon_periods: float = 60.0, trace=None
) -> None:
    plat = paper_platform(16)
    scenario = get_scenario(name)
    built = build(scenario, plat)
    print(f"\n=== scenario {name!r}: {scenario.description}")
    print(
        f"  design: {built.design.n_stages} stages, "
        f"max analytic util {built.design.max_util:.3f}"
    )

    # serve directly on the analysis timebase: the CostModel charges
    # every executed tile window its modeled per-layer WCET, so the
    # virtual run needs no period rescaling or quantization knob
    tasks, requests, arrivals = built.serve_bundle(period_scale=1.0)
    cost_model = built.conformance_cost_model(tasks)
    clk = VirtualClock()
    server = PharosServer(
        tasks,
        built.design.n_stages,
        policy=scenario.policy,
        cost_model=cost_model,
        clock=clk.now,
        sleep=clk.sleep,
        trace=trace,
    )
    admission = AdmissionController(
        list(built.table.overhead),
        preemptive=scenario.policy == "edf",
    )
    gateway = TrafficGateway(
        server,
        admission,
        requests,
        arrivals,
        shedding=get_policy("reject_newest"),
        clock=clk,
        trace=trace,
    )

    for dec in gateway.open():
        print(
            f"  admission {dec.request.name:14s} -> "
            f"{'ADMIT' if dec.admitted else 'REJECT':6s} ({dec.reason})"
        )
    probe = requests[0].base
    hr = admission.headroom_report(probe=probe)
    print(
        f"  headroom: bottleneck stage {hr.bottleneck}, "
        f"probe({requests[0].name}) max rate "
        f"{hr.probe_max_rate:.1f} jobs/s"
    )
    for tenant, mult in hr.tenant_rate_multipliers.items():
        print(f"    {tenant:14s} admits up to {mult:.2f}x its rate")

    horizon = horizon_periods * max(r.period for r in requests)
    report = gateway.run(horizon)

    sr = report.server_report
    for t in report.tenants:
        rts = sr.response_times.get(t.name, [])
        arr = np.asarray(rts) if rts else np.zeros(1)
        # p99 via the shared nearest-rank helper — the same number
        # `MetricsRegistry.from_trace` would report for this tenant
        p99 = percentile(rts, 99) if rts else 0.0
        print(
            f"  {t.name:14s} sched={t.scheduled:4d} released={t.released:4d} "
            f"shed={t.shed:4d} degraded={t.degraded:4d} | "
            f"rt mean={1e3 * arr.mean():6.2f}ms "
            f"p99={1e3 * p99:6.2f}ms "
            f"misses={sr.deadline_misses.get(t.name, 0)}"
        )
    print(
        f"  totals: completed={sr.jobs_completed} "
        f"preemptions={sr.preemptions} shed={report.total_shed()}"
    )
    # incremental admission verdicts must agree with the full analysis
    assert admission.verify(), "cached utilization diverged from Eq. 3"


def run_sharded(
    name: str, shards: int, horizon_periods: float = 40.0, trace=None
):
    plat = paper_platform(16)
    built = build(get_scenario(name), plat)
    print(
        f"\n=== scenario {name!r} on {shards} shards "
        f"(slack-aware placement, value-weighted rate limiting)"
    )
    gateway = ShardedGateway.from_built(
        built,
        shards=shards,
        placement="slack_aware",
        shedding=get_policy("reject_newest"),
        make_ratelimit=lambda reqs: RateLimiter.for_requests(
            reqs, burst_periods=3.0, value_weighted=True
        ),
        trace=trace,
    )
    horizon = horizon_periods * max(r.period for r in built.requests)
    report = gateway.run(horizon)
    assert gateway.verify(), "a shard's Eq. 3 cache diverged"
    print(f"  placement: {report.plan.assignment}")
    for t in report.tenants:
        print(
            f"  shard {report.shard_of(t.name)} {t.name:12s} "
            f"sched={t.scheduled:4d} released={t.released:4d} "
            f"ratelimited={t.rate_limited:4d} shed={t.shed:4d}"
        )
    print(
        f"  totals: released={report.total_released()} "
        f"ratelimited={report.total_rate_limited()} "
        f"shed={report.total_shed()}"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--trace",
        metavar="OUT.json",
        help="record all runs and write a Chrome/Perfetto trace here",
    )
    args = ap.parse_args()
    rec = TraceRecorder() if args.trace else None

    if rec is not None:
        rec.annotate(scenario="rush_hour")
    run_scenario("rush_hour", trace=rec)
    if rec is not None:
        rec.annotate(scenario="overload_2x")
    run_scenario("overload_2x", trace=rec)
    if rec is not None:
        rec.annotate(scenario="multi_tenant_rush")
    run_sharded("multi_tenant_rush", shards=2, trace=rec)

    if rec is not None:
        write_chrome_trace(rec.events, args.trace)
        print(
            f"\nwrote {len(rec.events)} schedule events to "
            f"{args.trace} (load in Perfetto / chrome://tracing)"
        )


if __name__ == "__main__":
    main()
