"""DSE -> SPMD pipeline: PHAROS partitioning for an assigned LM arch.

1. Extract minitron-4b's layer chain (the PHAROS task view of an LM),
2. run the SRT-guided DSE for a 2-task serving mix (prefill task +
   decode task with different periods) on a 16-chip slice via the
   unified `explore` driver (batched evaluator; the TG configuration
   is shown alongside for contrast),
3. show the chosen stage partition + per-stage utilizations,
4. provision a registry scenario straight from the DSE (`provision`:
   design -> shard plan -> per-shard Eq. 3 contracts + headroom),
5. run the *equal-stage* variant on the SPMD pipeline executor
   (4 fake CPU devices, ppermute streams) and validate it against the
   sequential backbone.

Run: ``PYTHONPATH=src python examples/dse_pipeline.py``
(sets XLA_FLAGS itself — run in a fresh interpreter)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dse import DSEConfig, explore, provision
from repro.core.dse.space import evaluate_design
from repro.core.perfmodel.hardware import paper_platform
from repro.core.rt.schedulability import stage_utilizations
from repro.core.rt.task import Task, TaskSet
from repro.launch.dryrun import load_config
from repro.models import lm
from repro.models.extract import arch_workload
from repro.pipeline.executor import (
    make_stage_mesh,
    pipeline_backbone,
    reference_backbone,
    use_mesh,
)


def main():
    cfg = load_config("minitron_4b")
    platform = paper_platform(16)

    # -- PHAROS task view of the LM: prefill + decode tenants ---------
    wl_prefill = arch_workload(cfg, batch=1, seq=2048, mode="prefill")
    wl_decode = arch_workload(cfg, batch=32, seq=2048, mode="decode")
    print(f"{cfg.name}: prefill chain {wl_prefill.num_layers} layers, "
          f"decode chain {wl_decode.num_layers} layers")

    # periods: prefill every 60ms, decode step budget 15ms
    ts = TaskSet(tasks=(
        Task(workload=wl_prefill, period=0.060, name="prefill"),
        Task(workload=wl_decode, period=0.015, name="decode"),
    ))
    # two ~160-layer flattened chains: a layer-granular split grid has
    # ~26k slice pairs per chip budget, so coarsen the boundaries to
    # every 8 layers (the DSE still prices every layer exactly)
    res = explore([wl_prefill, wl_decode], ts, platform,
                  method="beam", max_m=4, beam_width=8, split_stride=8)
    if res.best is None:
        print("no feasible design at these periods; relax and retry")
        return
    best = res.best
    table = evaluate_design(best.accs, best.splits,
                            [wl_prefill, wl_decode], ts)
    print(f"best: {best.n_stages} stages chips={[a.chips for a in best.accs]} "
          f"max_util={best.max_util:.3f} "
          f"({res.stats.candidates_per_sec:,.0f} candidates/s batched)")
    print("stage utilizations:",
          [f"{u:.3f}" for u in stage_utilizations(table, ts, False)])
    print("layer split (prefill):",
          [best.splits[k][0] for k in range(best.n_stages)])
    tg = explore([wl_prefill, wl_decode], ts, platform, method="tg")
    print(f"TG baseline (same driver, throughput objective): "
          f"max_util={tg.tg.max_util:.3f} eq2_feasible={tg.tg_eq2_feasible}")

    # -- DSE -> serving: provision a registry scenario ----------------
    plan = provision("steady_city", platform,
                     cfg=DSEConfig(method="beam", max_m=3, beam_width=4),
                     shards=2, placement="least_loaded")
    gw = plan.sharded_gateway()
    gw.open()
    print(f"\nprovisioned steady_city across K={plan.n_shards} shards "
          f"({plan.placement}): assignment={plan.plan.assignment}, "
          f"admission verified={gw.verify()}")
    for hr in gw.headroom():
        print(f"  shard {hr.shard}: tenants={list(hr.tenants)} "
              f"slacks={[f'{s:.2f}' for s in hr.stage_slacks]}")

    # -- equal-stage SPMD executor ------------------------------------
    small = dataclasses.replace(
        cfg, name="minitron-pipe", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=1024,
    )
    params = lm.init_params(jax.random.PRNGKey(0), small)
    mesh = make_stage_mesh(4)
    micro = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 32, 128),
                              jnp.bfloat16)
    with use_mesh(mesh):
        out = pipeline_backbone(small, mesh, 4)(params["blocks"], micro)
    ref = reference_backbone(small, params, micro)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    print(f"\nSPMD pipeline (4 stages x 8 microbatches over ppermute): "
          f"max err vs sequential = {err:.2e}")


if __name__ == "__main__":
    main()
