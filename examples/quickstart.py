"""Quickstart: the PHAROS flow end to end in ~a minute on CPU.

1. Build a task set (two DNN workloads with periods),
2. run the SRT-guided beam search (paper Alg. 1),
3. check Eq. 3 schedulability + analytic response bounds,
4. simulate FIFO vs EDF on the chosen design (DES),
5. serve the design for real with the EDF runtime (tile-preemptible
   GEMM windows).

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""
from repro.core.dse.beam import beam_search
from repro.core.dse.space import evaluate_design, fixed_design
from repro.core.perfmodel.hardware import paper_platform
from repro.core.rt.response_time import end_to_end_bounds
from repro.core.rt.schedulability import srt_schedulable, stage_utilizations
from repro.core.workloads import PAPER_WORKLOADS, make_taskset
from repro.pipeline import PharosServer, design_to_segments
from repro.scheduler.des import simulate_taskset


def main():
    platform = paper_platform(16)
    combo = ("pointnet", "mlp_mixer")
    workloads = [PAPER_WORKLOADS[c] for c in combo]
    taskset = make_taskset(combo, ratios=(0.8, 0.8), platform=platform)
    print(f"tasks: {[t.name for t in taskset.tasks]}")
    print(f"periods: {[f'{t.period*1e6:.1f}us' for t in taskset.tasks]}")

    # -- 1. one big accelerator is NOT schedulable --------------------
    fx = fixed_design(workloads, taskset, platform)
    print(f"\nfixed single accelerator: max_util={fx.max_util:.3f} "
          f"(needs <= 1)")

    # -- 2. SRT-guided DSE (Algorithm 1) ------------------------------
    res = beam_search(workloads, taskset, platform, max_m=4, beam_width=8)
    best = res.best
    print(f"beam search: {len(res.succ_pts)} feasible designs in "
          f"{res.stats.wall_time_s:.2f}s")
    print(f"best design: {best.n_stages} stages, chips="
          f"{[a.chips for a in best.accs]}, max_util={best.max_util:.3f}")

    # -- 3. schedulability + response bounds --------------------------
    table = evaluate_design(best.accs, best.splits, workloads, taskset)
    print(f"Eq.3 SRT-schedulable: {srt_schedulable(table, taskset, False)}")
    print(f"stage utilizations: "
          f"{[f'{u:.3f}' for u in stage_utilizations(table, taskset, False)]}")
    for pol in ("fifo", "edf"):
        b = end_to_end_bounds(table, taskset, pol)
        print(f"{pol} analytic response bounds: "
              f"{[f'{x*1e6:.1f}us' for x in b]}")

    # -- 4. discrete-event simulation ---------------------------------
    for pol in ("fifo", "edf"):
        sim = simulate_taskset(table, taskset, pol)
        print(f"DES {pol}: schedulable={sim.schedulable} "
              f"max_response={[f'{r*1e6:.1f}us' for r in sim.max_response]} "
              f"preemptions={sim.preemptions}")

    # -- 5. serve it for real (host runtime, wall-clock ms scale) -----
    tasks = design_to_segments(best, workloads, taskset, period_scale=2e3)
    srv = PharosServer(tasks, best.n_stages, policy="edf", window_tiles=4)
    rep = srv.run(horizon_s=1.5)
    print("\nlive EDF serving (1.5s):")
    for t in tasks:
        r = rep.response_times[t.name]
        if r:
            print(f"  {t.name:16s} jobs={len(r):4d} "
                  f"mean={1e3*sum(r)/len(r):6.2f}ms max={1e3*max(r):6.2f}ms "
                  f"misses={rep.deadline_misses[t.name]}")
    print(f"  preemptions={rep.preemptions} windows={rep.windows_executed}")


if __name__ == "__main__":
    main()
