"""Deadline-compliant serving: FIFO vs EDF on a mixed-criticality mix.

Two tenants share a 2-stage PHAROS pipeline:
- ``perception`` — heavyweight inference, relaxed deadline,
- ``safety``     — lightweight inference, tight deadline (the paper's
  smart-transportation safety monitor).

Under FIFO the safety task queues behind perception layers; under EDF
the scheduler preempts perception *inside a layer* at a tile-window
boundary (the preemptible-matmul mechanism), spilling the fp32 partial
accumulator and resuming later — deadline misses drop accordingly.

Run: ``PYTHONPATH=src python examples/serve_edf.py``
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.pipeline.serve import PharosServer, ServeTask


def mk_weights(dims, seed):
    key = jax.random.PRNGKey(seed)
    out = []
    for (k_dim, n_dim) in dims:
        key, sub = jax.random.split(key)
        out.append(
            jax.random.normal(sub, (k_dim, n_dim), jnp.float32)
            / jnp.sqrt(k_dim)
        )
    return tuple(out)


def main():
    perception = ServeTask(
        "perception",
        mk_weights([(512, 1024), (1024, 1024), (1024, 512)], 0),
        stage_of_layer=(0, 0, 1),
        period=0.08,
        input_rows=1024,
    )
    safety = ServeTask(
        "safety",
        mk_weights([(128, 256), (256, 128)], 1),
        stage_of_layer=(0, 1),
        period=0.02,
        deadline=0.012,
        input_rows=128,
    )

    for policy in ("fifo", "edf"):
        srv = PharosServer(
            [perception, safety], n_stages=2, policy=policy, window_tiles=2
        )
        rep = srv.run(horizon_s=2.0)
        print(f"\n== {policy.upper()} ==")
        for name in ("perception", "safety"):
            r = rep.response_times[name]
            if not r:
                continue
            arr = np.asarray(r)
            misses = rep.deadline_misses[name]
            print(
                f"  {name:11s} jobs={len(r):4d} "
                f"mean={1e3*arr.mean():7.2f}ms p99={1e3*np.quantile(arr,0.99):7.2f}ms "
                f"max={1e3*arr.max():7.2f}ms deadline_misses={misses}"
            )
        print(f"  preemptions={rep.preemptions} "
              f"windows={rep.windows_executed}")


if __name__ == "__main__":
    main()
