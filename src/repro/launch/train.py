"""Training driver: config -> mesh -> data -> step loop -> checkpoints.

Production shape (multi-host) and dev shape (this CPU container) share
the code path; only the mesh and config size differ::

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_1_6b \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt

``--smoke`` trains the reduced same-family config; full configs are for
real TPU slices (the dry-run proves they lower/compile at scale).
Fault tolerance: auto-resume from the newest committed checkpoint; the
`runtime.ft` watchdog wraps the loop (simulated-failure hooks in tests).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, smoke_config
from repro.data import DataConfig, SyntheticTokenDataset
from repro.launch.dryrun import ARCH_MODULES, load_config
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def train_loop(
    cfg: ArchConfig,
    *,
    steps: int = 100,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
    on_step=None,
    schedule_steps: int = 0,
):
    """Single-host training loop; returns the loss history.

    ``schedule_steps`` fixes the LR-schedule horizon independently of
    ``steps`` so a shorter run + resume follows the identical schedule
    (checkpoint/restart determinism).
    """
    horizon = schedule_steps or steps
    opt_cfg = AdamWConfig(lr_peak=lr, warmup_steps=max(10, horizon // 20),
                          total_steps=horizon)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg)
    opt_state = adamw_init(params)

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    ds = SyntheticTokenDataset(data_cfg)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    start = 0
    mgr = None
    state = {"params": params, "opt": opt_state}
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, every=ckpt_every)
        start, state = mgr.restore_latest(state)
        params, opt_state = state["params"], state["opt"]
        if start:
            print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        raw = ds.batch(step)
        batch = {
            "tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"]),
            "mask": jnp.asarray(raw["mask"]),
        }
        if cfg.frontend != "none":
            # stub frontends consume precomputed embeddings; derive a
            # deterministic embedding from the token ids for the demo
            emb = jax.nn.one_hot(
                batch["tokens"] % cfg.frontend_dim, cfg.frontend_dim,
                dtype=jnp.bfloat16,
            )
            batch = {"embeds": emb, "labels": batch["labels"],
                     "mask": batch["mask"]}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if on_step is not None:
            on_step(step, loss)
        if mgr is not None:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(
                f"[train] step {step:5d} loss {loss:7.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)",
                flush=True,
            )
    if mgr is not None:
        mgr.maybe_save(steps, {"params": params, "opt": opt_state})
    return losses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_MODULES, default="stablelm_1_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = load_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    print(f"[train] arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model}")
    losses = train_loop(
        cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        lr=args.lr,
    )
    first = np.mean(losses[: max(1, len(losses) // 10)])
    last = np.mean(losses[-max(1, len(losses) // 10):])
    print(f"[train] loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
