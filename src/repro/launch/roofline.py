"""Roofline analysis: loop-aware HLO collective accounting + analytic
compute/memory terms.

Why not raw ``cost_analysis()``: XLA's cost analysis (and a flat text
scan) counts a ``while`` body ONCE, but our models execute the repeats
scan ``n_repeats`` times, microbatch loops ``u`` times, attention chunk
loops ``S/chunk`` times. Two complementary sources fix this:

1. **Collective term** — parsed from the compiled HLO with loop
   multiplication: each ``while`` body's collective bytes are scaled by
   the trip bound recovered from its condition computation (scan loops
   compare an induction variable against a constant). This is exact for
   lax.scan-shaped loops, which is all this codebase emits.

2. **Compute/memory terms** — analytic per-(arch x shape) models built
   from the same layer chains the DSE prices (`models.extract`),
   documented formula-by-formula below, validated against
   ``cost_analysis()`` on unrolled smoke configs (tests).

Hardware constants per the assignment: 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.launch.shapes import ShapeCase
from repro.models.extract import arch_workload

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}
_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|s4|u4)"
    r"\[([0-9,]*)\]"
)
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\bcall\(.*?\), to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line)
        if m and "{" in line:
            name = "ENTRY" if m.group(1) else m.group(2)
            current = name
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def _direct_collectives(lines: list[str]) -> dict[str, float]:
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in lines:
        if "=" not in line:
            continue
        _, rhs = line.split("=", 1)
        for kind in _COLLECTIVES:
            idx = rhs.find(kind + "(")
            if idx < 0:
                idx = rhs.find(kind + "-start(")
            if idx < 0:
                continue
            head = rhs[:idx]
            if "fusion(" in head or "custom-call(" in head:
                continue
            out[kind] += _shape_bytes(head)
            out["count"] += 1
            break
    return out


def _trip_bound(cond_lines: list[str]) -> int:
    """Max s32 constant in the condition — the scan trip count."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes_hlo(hlo: str) -> dict[str, float]:
    """Loop-aware per-device collective bytes by kind (see module doc)."""
    comps = _split_computations(hlo)
    conds: dict[str, int] = {
        name: _trip_bound(lines) for name, lines in comps.items()
    }
    memo: dict[str, dict[str, float]] = {}

    def total(name: str, stack=()) -> dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {k: 0.0 for k in (*_COLLECTIVES, "count")}
        lines = comps[name]
        acc = _direct_collectives(lines)
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = conds.get(cond, 1)
                sub = total(body, stack + (name,))
                for k in acc:
                    acc[k] += trips * sub[k]
                continue
            cm = _CALL_RE.search(line)
            if cm:
                sub = total(cm.group(1), stack + (name,))
                for k in acc:
                    acc[k] += sub[k]
        memo[name] = acc
        return acc

    entry = "ENTRY" if "ENTRY" in comps else next(iter(comps), None)
    if entry is None:
        return {k: 0.0 for k in (*_COLLECTIVES, "count", "total")}
    acc = dict(total(entry))
    acc["total"] = sum(acc[k] for k in _COLLECTIVES)
    return acc


def collective_breakdown(hlo: str, top: int = 12) -> list[dict]:
    """Top collective contributors: (kind, result shape, trips, bytes).

    Same loop-trip accounting as `collective_bytes_hlo`, itemized — the
    §Perf hypothesis tool ("which collective do I attack first?").
    """
    comps = _split_computations(hlo)
    conds = {name: _trip_bound(lines) for name, lines in comps.items()}
    items: list[dict] = []

    def walk(name: str, mult: int, stack=()):
        if name in stack or name not in comps:
            return
        for line in comps[name]:
            if "=" in line:
                _, rhs = line.split("=", 1)
                for kind in _COLLECTIVES:
                    idx = rhs.find(kind + "(")
                    if idx < 0:
                        idx = rhs.find(kind + "-start(")
                    if idx < 0:
                        continue
                    head = rhs[:idx]
                    if "fusion(" in head or "custom-call(" in head:
                        continue
                    b = _shape_bytes(head)
                    shape = head.strip().split()[-1] if head.strip() else "?"
                    items.append(
                        {
                            "kind": kind,
                            "shape": shape[:60],
                            "trips": mult,
                            "bytes": b * mult,
                            "comp": name[:40],
                        }
                    )
                    break
                wm = _WHILE_RE.search(line)
                if wm:
                    walk(wm.group(2), mult * conds.get(wm.group(1), 1),
                         stack + (name,))
                    continue
                cm = _CALL_RE.search(line)
                if cm:
                    walk(cm.group(1), mult, stack + (name,))

    entry = "ENTRY" if "ENTRY" in comps else next(iter(comps), None)
    if entry:
        walk(entry, 1)
    items.sort(key=lambda d: -d["bytes"])
    return items[:top]


# ---------------------------------------------------------------------------
# analytic compute / memory
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CostModel:
    """Per-STEP global costs (divide by chips for per-device)."""

    flops: float  # executed FLOPs incl. backward + remat recompute
    hbm_bytes: float  # HBM traffic
    model_flops: float  # 6 N D (dense) / 6 N_active D (MoE)

    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0


def analytic_cost(cfg: ArchConfig, case: ShapeCase) -> CostModel:
    """Formulas (documented in EXPERIMENTS.md §Roofline):

    - fwd FLOPs F = sum of layer-chain GEMM/attention/scan FLOPs
      (`models.extract`, mode-matched) + LM head.
    - train: blocks cost ``4F`` (fwd + 2x bwd + full-remat recompute,
      `nothing_saveable`), head/CE ``3F_head`` (+1 remat) -> we charge
      ``4F`` uniformly (slight over-estimate on the head, <2%).
    - prefill: ``F``; decode: ``F`` with decode-mode chains (one token
      against the case's cache).
    - HBM bytes: weight streams (every pass reads all weights once:
      3 passes train with microbatching re-reads, 1 pass inference) +
      layer-chain activation/cache traffic from the same extractor +
      optimizer read/write (16 B/param: fp32 m,v read+write) + param
      read/write (2+2 B) on train.
    - MODEL_FLOPS: 6 N D with N(_active) from `ArchConfig.param_counts`
      and D = tokens processed (train/prefill: B*S; decode: B).
    """
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[
        case.kind
    ]
    wl = arch_workload(cfg, case.global_batch, case.seq_len, mode=mode)
    chain_flops = wl.total_flops()
    chain_bytes = wl.total_bytes()
    counts = cfg.param_counts()
    n_params, n_active = counts["total"], counts["active"]

    if case.kind == "train":
        # extract's train mode already multiplies by 3 (fwd+bwd);
        # remat recompute adds one more forward -> 4/3 of that.
        flops = chain_flops * (4.0 / 3.0)
        weight_stream = 2.0 * n_params * 3.0  # bf16, fwd+bwd+remat passes
        opt_traffic = n_params * (16.0 + 4.0)  # m,v fp32 rw + param rw bf16
        hbm = chain_bytes * (4.0 / 3.0) + weight_stream + opt_traffic
        tokens = case.global_batch * case.seq_len
    elif case.kind == "prefill":
        flops = chain_flops
        hbm = chain_bytes + 2.0 * n_active
        tokens = case.global_batch * case.seq_len
    else:  # decode
        flops = chain_flops
        hbm = chain_bytes + 2.0 * n_active
        tokens = case.global_batch
    # 6 N D counts fwd+bwd (2+4); inference runs the forward only -> 2 N D
    factor = 6.0 if case.kind == "train" else 2.0
    model_flops = factor * n_active * tokens
    return CostModel(flops=flops, hbm_bytes=hbm, model_flops=model_flops)


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    useful_ratio: float
    roofline_fraction: float  # compute_s / max(all terms)

    def as_dict(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(
    cfg: ArchConfig,
    case: ShapeCase,
    chips: int,
    collective_bytes_per_device: float,
) -> RooflineTerms:
    cost = analytic_cost(cfg, case)
    compute_s = cost.flops / (chips * PEAK_FLOPS)
    memory_s = cost.hbm_bytes / (chips * HBM_BW)
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = compute_s / bound if bound > 0 else 0.0
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        useful_ratio=cost.useful_ratio(),
        roofline_fraction=frac,
    )
