import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module —
jax locks the device count on first init, and the production meshes
need 512 placeholder CPU devices.

Per cell this script:

1. builds the production mesh (16x16, or 2x16x16 with ``--multi-pod``),
2. builds the jitted step with explicit in/out shardings (launch.steps),
3. ``.lower(**input_specs)`` + ``.compile()`` — any sharding mismatch,
   unsupported collective, or spec bug fails here,
4. prints ``compiled.memory_analysis()`` (proves the per-device fit)
   and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
5. parses the post-SPMD HLO for collective ops and sums their operand
   bytes (the §Roofline collective term),
6. appends a JSON record to ``experiments/dryrun/``.

Usage::

    python -m repro.launch.dryrun --arch qwen1_5_32b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""
import argparse
import json
import re
import sys
import time
import traceback

ARCH_MODULES = [
    "jamba_v0_1_52b",
    "granite_moe_3b_a800m",
    "dbrx_132b",
    "rwkv6_7b",
    "internvl2_76b",
    "qwen1_5_32b",
    "minitron_4b",
    "mistral_nemo_12b",
    "stablelm_1_6b",
    "musicgen_medium",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|s4|u4)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> float:
    """Total bytes of every typed shape literal in ``text``."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op, by op kind.

    HLO line form: ``%name = TYPE[shape] op-kind(args), ...`` — the
    result shape sits between '=' and the op keyword. Async pairs count
    the ``-start`` only (``-done`` repeats the same buffer).

    Accounting: an op's *result* shape bounds the data it moves per
    participating device (all-gather results count the full gathered
    size; all-reduce counts the reduced tensor once — on a ring each
    device sends/receives ~2x the shard, so results are a consistent
    per-device upper bound for ring algorithms).
    """
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        _, rhs = line.split("=", 1)
        for kind in _COLLECTIVES:
            idx = rhs.find(kind + "(")
            if idx < 0:
                idx = rhs.find(kind + "-start(")
            if idx < 0:
                continue
            # guard against substring hits inside metadata/fusion names
            head = rhs[:idx]
            if "fusion(" in head or "custom-call(" in head:
                continue
            out[kind] += _shape_bytes(head)
            out["count"] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def load_config(arch: str):
    import importlib

    return importlib.import_module(f"repro.configs.{arch}").CONFIG


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             kv_int8: bool = False) -> dict:
    """Lower+compile one cell; returns the §Dry-run / §Roofline record."""
    import jax

    from repro.launch.mesh import make_production_mesh, n_chips
    from repro.launch.shapes import SHAPES, applicable_shapes
    from repro.launch.steps import lowerable

    cfg = load_config(arch)
    case = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        return {
            "arch": cfg.name,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "SKIP",
            "reason": "full-attention arch: 500k dense decode excluded "
            "(sub-quadratic shapes run on jamba/rwkv6 only)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args = lowerable(cfg, case, mesh, kv_quant=kv_int8)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    from repro.launch.roofline import analytic_cost, collective_bytes_hlo

    coll_flat = collective_bytes(hlo)
    coll_loop = collective_bytes_hlo(hlo)
    acost = analytic_cost(cfg, case)
    chips = n_chips(mesh)
    record = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw artifacts (XLA counts while bodies once — see roofline.py)
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_flat": coll_flat,
        # loop-corrected per-device collective bytes (roofline input)
        "collective_bytes": coll_loop,
        # analytic per-step global costs (roofline compute/memory terms)
        "analytic": {
            "flops": acost.flops,
            "hbm_bytes": acost.hbm_bytes,
            "model_flops": acost.model_flops,
        },
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0
            ),
        },
    }
    return record


def result_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    root = os.path.join("experiments", "dryrun")
    os.makedirs(root, exist_ok=True)
    return os.path.join(root, f"{arch}__{shape}__{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_MODULES)
    ap.add_argument("--shape", choices=list("train_4k prefill_32k decode_32k long_500k".split()))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--kv-int8", action="store_true",
                    help="decode variant: int8 KV cache (results get a "
                         "'__kvint8' suffix)")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a in ARCH_MODULES
                 for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        path = result_path(arch, shape, args.multi_pod)
        if args.kv_int8:
            path = path.replace(".json", "__kvint8.json")
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                rec = json.load(f)
            print(f"[cached] {arch} {shape}: {rec['status']}")
            continue
        print(f"[run] {arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'})",
              flush=True)
        try:
            rec = run_cell(arch, shape, args.multi_pod, kv_int8=args.kv_int8)
        except Exception as e:  # a failed cell is a bug in the system
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "status": "FAIL", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "OK":
            per_chip = (
                rec["memory"]["argument_size_bytes"]
                + rec["memory"]["temp_size_bytes"]
            ) / 1e9
            print(
                f"  OK: compile {rec['compile_s']}s, "
                f"flops {rec['flops']:.3e}, "
                f"coll {rec['collective_bytes']['total']:.3e} B, "
                f"args+temp {per_chip:.2f} GB/device"
            )
        else:
            print(f"  {rec['status']}: {rec.get('reason', rec.get('error', ''))}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
