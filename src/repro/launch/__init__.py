"""Launch layer: production meshes, sharding rules, step builders,
multi-pod dry-run, and the train/serve drivers."""
