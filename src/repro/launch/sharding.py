"""Sharding rules: parameter / optimizer / batch / cache partition specs.

Scheme (single pod: mesh ``(data=16, model=16)``; multi-pod adds a
leading ``pod`` axis used for cross-pod DP):

- **FSDP on ``data``**: every weight matrix shards its *input* feature
  dim over ``data``; XLA all-gathers per layer inside the scan body.
- **TP on ``model``** (Megatron column/row): projections in
  (``wq/wk/wv/w_in/w_gate``) shard the output dim on ``model``;
  projections out (``wo/w_out/out_proj``) shard the input dim on
  ``model`` so the pair needs one reduce per block.
- **EP on ``model``** for MoE expert banks (expert dim sharded; GSPMD
  pads non-divisible expert counts, tracked as a §Perf lever).
- vectors / norms / small tensors are replicated.
- Stacked block params carry a leading ``n_repeats`` scan axis that is
  never sharded.

Rules are name-based over the param-tree paths so the same function
covers all 10 architectures (attn, mamba, rwkv, moe leaves).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# param names that are row-parallel (input dim on `model`)
_ROW_PARALLEL = {"wo", "w_out", "out_proj"}
# param names that are column-parallel (output dim on `model`)
_COL_PARALLEL = {
    "wq", "wk", "wv", "wg", "wr", "w_in", "w_gate", "in_proj",
    "frontend_proj", "lm_head",
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_spec(path, leaf, model_size: int | None = None) -> P:
    """PartitionSpec for one parameter leaf (see module docstring).

    ``model_size`` enables divisibility-aware choices (explicit
    in_shardings reject padding): expert banks use EP when the expert
    count divides the model axis, else tensor-parallel over d_ff
    (granite's 40 experts on a 16-way axis).
    """
    name = _leaf_name(path)
    in_block = any(
        hasattr(e, "key") and str(e.key) == "blocks" for e in path
    )
    nd = leaf.ndim

    if name == "embed":  # (vocab, d): d on model, vocab replicated.
        # Vocab-sharding the table forces GSPMD's replicated-scatter
        # fallback on the gather gradient (a full fp32 (V, d) buffer +
        # all-reduce per microbatch); d-sharding keeps both the lookup
        # and its scatter-add grad shard-local at ~V*d/model bytes.
        return P(None, "model")

    if name == "router":  # (rep, d, E): replicate E (tiny, fp32)
        return P(None, "data", None)

    if in_block and nd == 4:  # MoE expert bank (rep, E, d_in, d_out)
        n_experts = leaf.shape[1]
        ep_ok = model_size is None or n_experts % model_size == 0
        if name in _ROW_PARALLEL:
            return P(None, "model", None, "data") if ep_ok else P(
                None, None, "model", "data"
            )
        return P(None, "model", "data", None) if ep_ok else P(
            None, None, "data", "model"
        )

    if in_block and nd == 3:  # stacked matrix (rep, in, out)
        if name in _ROW_PARALLEL:
            return P(None, "model", "data")
        if name in _COL_PARALLEL:
            return P(None, "data", "model")
        return P(None, None, None)  # conv_w, lora, A_log, u, ...

    if not in_block and nd == 2:  # top-level matrix (in, out)
        if name in _COL_PARALLEL:
            return P("data", "model")
        return P(None, None)

    return P(*([None] * nd))  # vectors, scalars, biases, norms


def shardings_for_tree(mesh, tree):
    """NamedSharding pytree matching ``tree`` via `param_spec` rules."""
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model")
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, model_size)
        ),
        tree,
    )


def opt_state_shardings(mesh, param_shardings):
    """AdamW state: moments mirror the params; step is replicated."""
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(mesh, batch_tree):
    """Batch dict: leading dim over the batch axes, rest replicated."""
    ba = batch_axes(mesh)

    def spec(leaf):
        return NamedSharding(mesh, P(ba, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(spec, batch_tree)


def _cache_leaf_spec(mesh, name: str, leaf, *, seq_sharded: bool) -> P:
    """Decode-cache leaf specs. Leaves carry a leading repeats axis.

    KV caches shard the *sequence* dim (flash-decode style): explicit
    in/out shardings must divide exactly (no GSPMD padding), and kv-head
    counts (8/24/40) do not divide model=16 while every cache length
    does. The decode softmax/readout over the sharded S axis becomes a
    small partial-stat all-reduce.

    ``seq_sharded=True`` (long_500k, batch=1): the batch axes are
    unusable, so S shards over the whole (data x model) product and
    channel-state dims over all divisible axes.
    """
    ba = batch_axes(mesh)
    nd = leaf.ndim
    all_ax = tuple(mesh.axis_names)  # e.g. ("pod","data","model")
    if name in ("k", "v"):  # (rep, B, kv, S, hd)
        if seq_sharded:
            return P(None, None, None, all_ax, None)
        return P(None, ba, None, "model", None)
    if name in ("k_scale", "v_scale"):  # (rep, B, kv, S)
        if seq_sharded:
            return P(None, None, None, all_ax)
        return P(None, ba, None, "model")
    if name == "ssm":  # (rep, B, di, ns)
        if seq_sharded:
            return P(None, None, all_ax, None)
        return P(None, ba, "model", None)
    if name == "conv":  # (rep, B, dc-1, di)
        if seq_sharded:
            return P(None, None, None, all_ax)
        return P(None, ba, None, "model")
    if name == "S":  # rwkv state (rep, B, H, hd, hd)
        if seq_sharded:
            return P(None, None, "model", None, None)
        return P(None, ba, "model", None, None)
    if name in ("tmix_last", "cmix_last"):  # (rep, B, d)
        if seq_sharded:
            return P(None, None, "model")
        return P(None, ba, None)
    return P(*([None] * nd))


def cache_shardings(mesh, cache_tree, *, seq_sharded: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            _cache_leaf_spec(mesh, _leaf_name(path), leaf, seq_sharded=seq_sharded),
        ),
        cache_tree,
    )


def replicated(mesh):
    return NamedSharding(mesh, P())
