"""Production meshes (single pod 16x16, multi-pod 2x16x16).

Functions, not module-level constants: importing this module never
touches jax device state, so smoke tests see the real single CPU device
while `dryrun.py` (which sets ``xla_force_host_platform_device_count``
before any jax import) sees 512 placeholders.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """One v5e pod (16x16) or two pods (2x16x16).

    Axes: ``data`` carries batch DP + FSDP parameter sharding, ``model``
    carries tensor/expert parallelism, ``pod`` is cross-pod data
    parallelism (gradient all-reduce crosses the inter-pod links).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    return mesh.devices.size
