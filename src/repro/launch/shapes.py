"""Assigned input-shape sets and `input_specs` (ShapeDtypeStruct stand-ins).

Per the assignment brief, every LM architecture is exercised on:

- ``train_4k``     seq 4,096   x global batch 256   (training)
- ``prefill_32k``  seq 32,768  x global batch 32    (inference prefill)
- ``decode_32k``   seq 32,768  x global batch 128   (decode: 1 new token
                   against a 32k KV cache / state)
- ``long_500k``    seq 524,288 x global batch 1     (long-context decode;
                   sub-quadratic archs only: jamba, rwkv6)

`input_specs` returns weak-type-correct ShapeDtypeStructs only — no
device allocation — so the 512-device dry-run lowers full-size configs
on a CPU container.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm


@dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Shape cases this arch runs; long_500k only for sub-quadratic."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.run_long_context:
        names.append("long_500k")
    return names


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, case: ShapeCase, kv_quant: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this case."""
    B, S = case.global_batch, case.seq_len
    if case.kind == "train":
        if cfg.frontend == "none":
            batch = {"tokens": _sds((B, S), jnp.int32)}
        else:
            batch = {"embeds": _sds((B, S, cfg.frontend_dim), jnp.bfloat16)}
        batch["labels"] = _sds((B, S), jnp.int32)
        batch["mask"] = _sds((B, S), jnp.float32)
        return {"batch": batch}
    if case.kind == "prefill":
        if cfg.frontend == "none":
            batch = {"tokens": _sds((B, S), jnp.int32)}
        else:
            batch = {"embeds": _sds((B, S, cfg.frontend_dim), jnp.bfloat16)}
        return {"batch": batch}
    # decode: one new token against an S-long cache
    if cfg.frontend == "none":
        inputs = {"tokens": _sds((B,), jnp.int32)}
    else:
        inputs = {"embeds": _sds((B, cfg.frontend_dim), jnp.bfloat16)}
    cache = jax.tree_util.tree_map(
        lambda sd: _sds(*sd),
        lm.cache_spec(cfg, B, S, kv_quant=kv_quant),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )
    return {"inputs": inputs, "cache": cache, "pos": _sds((B,), jnp.int32)}


def params_spec(cfg: ArchConfig):
    """ShapeDtypeStruct tree of the parameters (via eval_shape)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def opt_spec(params_tree):
    from repro.optim import adamw_init

    return jax.eval_shape(adamw_init, params_tree)
