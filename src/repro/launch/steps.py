"""Jitted step functions: train_step / prefill_step / serve_step.

These are what the dry-run lowers and what the drivers run. Each builder
returns ``(fn, in_shardings, out_shardings, arg_specs)`` so `dryrun.py`,
`train.py` and the tests share one definition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.mesh import batch_axes
from repro.launch.shapes import ShapeCase, input_specs, opt_spec, params_spec
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    replicated,
    shardings_for_tree,
)
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update


def activation_policy(
    mesh,
    *,
    batch_sharded: bool = True,
    seq_parallel: bool = False,
    n_experts: int = 0,
) -> lm.ShardingPolicy:
    """Pin activations batch-over-data and CE logits vocab-over-model.

    ``seq_parallel=True`` (train/prefill, S >> 1) additionally shards
    the *sequence* dim over ``model`` at block boundaries (Megatron-SP):
    the per-repeat carry stash the backward pass keeps — the dominant
    live buffer under scan-over-layers — shrinks by the model-axis
    size, and norms compute on 1/model of the tokens. GSPMD inserts the
    all-gather at the first block matmul and the reduce-scatter after
    the output projection.

    ``batch_sharded=False`` (long_500k, batch=1) leaves activations
    unpinned — the parallel axis there is the cache sequence dim.
    """
    if mesh is None or not batch_sharded:
        return lm.NO_POLICY
    ba = batch_axes(mesh)
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    seq_axis = "model" if seq_parallel else None
    groups = 1
    for a in ba:
        groups *= mesh.shape[a]
    model_size = mesh.shape["model"]
    # EP dispatch (experts over `model`) only when the expert count
    # divides the axis; otherwise the expert GEMMs run tensor-parallel
    # over d_ff (matching param_spec's fallback) and the dispatch stays
    # batch-sharded only.
    ep_ok = n_experts == 0 or n_experts % model_size == 0
    # E-leading dispatch layout (see layers.moe_capacity)
    dispatch = P("model", ba, None, None) if ep_ok else P(None, ba, None, None)
    return lm.ShardingPolicy(
        act=NS(mesh, P(ba, seq_axis, None)),
        logits=NS(mesh, P(ba, None, "model")),
        moe_groups=groups,
        moe_dispatch=NS(mesh, dispatch),
        heads=NS(mesh, P(ba, None, "model", None)),
        channels=NS(mesh, P(ba, None, "model")),
        gathered=NS(mesh, P(ba, None, None)),
    )


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    remat: bool = True,
    policy: lm.ShardingPolicy = lm.NO_POLICY,
    micro_batches: int = 1,
    grad_shardings=None,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``micro_batches > 1`` runs gradient accumulation: the global batch
    is split on the batch axis and scanned, accumulating fp32 grads.
    Every activation-sized buffer (the per-repeat carry stash the
    backward keeps, attention workspaces, CE chunks) scales down by the
    microbatch count at the cost of one params-sized fp32 accumulator —
    the standard memory/throughput knob for the biggest assigned archs.
    """

    def grad_fn(params, mb):
        out, g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, mb, remat=remat, policy=policy),
            has_aux=True,
        )(params)
        if grad_shardings is not None:
            # pin per-microbatch grads to the parameter layout: the
            # cross-data reduction becomes a reduce-scatter into the
            # FSDP shard instead of a full all-reduce (ZeRO-2 flavour)
            g = jax.lax.with_sharding_constraint(g, grad_shardings)
        return out, g

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(micro_batches, b // micro_batches,
                                    *leaf.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g
                )
                return acc, (l, m)

            grads, (losses, ms) = jax.lax.scan(body, acc0, micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / micro_batches, grads
            )
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


#: target upper bound on the dominant per-device live activation set
_STASH_BUDGET_BYTES = (1 << 30) * 3 // 4


def auto_micro_batches(cfg: ArchConfig, case: ShapeCase, mesh) -> int:
    """Smallest power-of-two divisor of the per-device batch keeping the
    dominant live buffers under budget. Model (all scale ~1/u):

    - per-repeat carry stash the backward keeps:
      ``n_layers x B_loc x S/model x d x 2B``;
    - MoE combine output (fp32, full-S per data shard):
      ``T_loc x d x 4B``;
    - MoE dispatch (G, E, C, d) bf16, /model when expert-parallel.
    """
    n_data = 1
    for a in batch_axes(mesh):
        n_data *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    b_loc = max(1, case.global_batch // n_data)
    s_loc = max(1, case.seq_len // model)
    live = cfg.n_layers * b_loc * s_loc * cfg.d_model * 2
    if cfg.n_experts:
        t_loc = b_loc * case.seq_len
        live += t_loc * cfg.d_model * 4  # fp32 combine
        disp = t_loc * cfg.top_k * cfg.capacity_factor * cfg.d_model * 2
        if cfg.n_experts % model == 0:
            disp /= model  # expert-parallel dispatch is model-sharded
        live += disp
    micro = 1
    while micro < b_loc and live / micro > _STASH_BUDGET_BYTES:
        micro *= 2
    while b_loc % micro:
        micro //= 2
    return max(1, micro)


def make_prefill_step(
    cfg: ArchConfig,
    cache_len: int,
    *,
    remat: bool = True,
    policy: lm.ShardingPolicy = lm.NO_POLICY,
):
    """(params, batch) -> (last-token logits, cache)."""

    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, cache_len, remat=remat, policy=policy)

    return prefill_step


def make_serve_step(
    cfg: ArchConfig,
    *,
    policy: lm.ShardingPolicy = lm.NO_POLICY,
    kv_quant: bool = False,
):
    """(params, cache, inputs, pos) -> (logits, cache)."""

    def serve_step(params, cache, inputs, pos):
        return lm.decode_step(
            params, cfg, cache, inputs, pos, policy=policy, kv_quant=kv_quant
        )

    return serve_step


def lowerable(
    cfg: ArchConfig,
    case: ShapeCase,
    mesh,
    opt_cfg: AdamWConfig | None = None,
    *,
    kv_quant: bool = False,
):
    """Build (jitted_fn, example_args) for one (arch x shape) cell.

    Args are ShapeDtypeStructs; call ``.lower(*args)`` on the result.
    ``kv_quant`` switches the decode cache to int8+scales (§Perf).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    p_spec = params_spec(cfg)
    p_shard = shardings_for_tree(mesh, p_spec)
    specs = input_specs(cfg, case, kv_quant=kv_quant and case.kind == "decode")

    if case.kind == "train":
        o_spec = opt_spec(p_spec)
        o_shard = opt_state_shardings(mesh, p_shard)
        b_shard = batch_shardings(mesh, specs["batch"])
        policy = activation_policy(
            mesh, seq_parallel=True, n_experts=cfg.n_experts
        )
        micro = auto_micro_batches(cfg, case, mesh)
        fn = jax.jit(
            make_train_step(
                cfg, opt_cfg, policy=policy, micro_batches=micro,
                grad_shardings=p_shard,
            ),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, replicated(mesh)),
            donate_argnums=(0, 1),
        )
        return fn, (p_spec, o_spec, specs["batch"])

    if case.kind == "prefill":
        b_shard = batch_shardings(mesh, specs["batch"])
        policy = activation_policy(
            mesh, seq_parallel=True, n_experts=cfg.n_experts
        )
        step = make_prefill_step(cfg, case.seq_len, policy=policy)
        cache_sd = jax.eval_shape(step, p_spec, specs["batch"])[1]
        c_shard = cache_shardings(mesh, cache_sd, seq_sharded=False)
        logits_shard = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(batch_axes(mesh), None)
        )
        fn = jax.jit(
            step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )
        return fn, (p_spec, specs["batch"])

    # decode
    seq_sharded = case.global_batch == 1
    c_shard = cache_shardings(mesh, specs["cache"], seq_sharded=seq_sharded)
    policy = activation_policy(
        mesh, batch_sharded=not seq_sharded, n_experts=cfg.n_experts
    )
    if seq_sharded:
        i_shard = jax.tree_util.tree_map(lambda _: replicated(mesh), specs["inputs"])
        pos_shard = replicated(mesh)
        logits_shard = replicated(mesh)
    else:
        i_shard = batch_shardings(mesh, specs["inputs"])
        pos_shard = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(batch_axes(mesh))
        )
        logits_shard = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(batch_axes(mesh), None)
        )
    fn = jax.jit(
        make_serve_step(cfg, policy=policy, kv_quant=kv_quant),
        in_shardings=(p_shard, c_shard, i_shard, pos_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
    )
    return fn, (p_spec, specs["cache"], specs["inputs"], specs["pos"])
