"""Gradient compression: int8 quantization with error feedback.

Cross-pod gradient all-reduce is the collective-term floor for
multi-pod data parallelism (§Roofline: the ``pod`` axis crosses the
slower inter-pod links). Per-tensor symmetric int8 quantization cuts
those bytes 4x (fp32 moments stay local; only the exchanged gradient is
compressed); the residual is carried to the next step (error feedback,
Seide et al. / EF-SGD), which keeps SGD convergence guarantees.

Pure-pytree implementation: `compress` returns (int8 payload, scales),
`decompress` reconstructs, `ErrorFeedbackState` holds the residuals.
The train driver applies it around the cross-pod reduce only.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass
class ErrorFeedbackState:
    residual: object  # pytree matching grads, fp32

    @staticmethod
    def init(grads):
        return ErrorFeedbackState(
            residual=jax.tree_util.tree_map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads
            )
        )


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_gradients(grads, ef: ErrorFeedbackState | None = None):
    """-> (payload {q, scale} pytree, new ErrorFeedbackState).

    With error feedback, compresses ``g + residual`` and stores the
    quantization error back into the residual.
    """
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if ef is not None:
        g32 = jax.tree_util.tree_map(jnp.add, g32, ef.residual)
    qs = jax.tree_util.tree_map(_quantize, g32)
    payload = {
        "q": jax.tree_util.tree_map(lambda t: t[0], qs,
                                    is_leaf=lambda x: isinstance(x, tuple)),
        "scale": jax.tree_util.tree_map(lambda t: t[1], qs,
                                        is_leaf=lambda x: isinstance(x, tuple)),
    }
    recon = jax.tree_util.tree_map(_dequantize, payload["q"], payload["scale"])
    new_ef = ErrorFeedbackState(
        residual=jax.tree_util.tree_map(jnp.subtract, g32, recon)
    )
    return payload, new_ef


def decompress_gradients(payload):
    return jax.tree_util.tree_map(
        _dequantize, payload["q"], payload["scale"]
    )


def compression_ratio(grads) -> float:
    """Bytes(fp32) / bytes(int8 + scale) for this pytree."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    leaves = len(jax.tree_util.tree_leaves(grads))
    return (4.0 * n) / (1.0 * n + 4.0 * leaves)
