from repro.runtime.ft import FaultTolerantLoop, HeartbeatMonitor, WorkerState
from repro.runtime.compression import (
    compress_gradients,
    decompress_gradients,
    ErrorFeedbackState,
)
from repro.runtime.straggler import StragglerMitigator
from repro.runtime.elastic import ElasticPlan, plan_remesh

__all__ = [
    "FaultTolerantLoop",
    "HeartbeatMonitor",
    "WorkerState",
    "compress_gradients",
    "decompress_gradients",
    "ErrorFeedbackState",
    "StragglerMitigator",
    "ElasticPlan",
    "plan_remesh",
]
