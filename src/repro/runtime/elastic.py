"""Elastic scaling: re-mesh planning after node loss / expansion.

When workers die (heartbeat DEAD) or capacity arrives, the job must
resize without restarting from scratch. The plan:

1. choose the largest valid mesh from the surviving chip count —
   valid = the ``model`` axis is preserved (TP degree is baked into
   weight shapes) and ``data`` shrinks/grows to the largest divisor of
   the global batch;
2. restore the latest checkpoint re-sharded onto the new mesh (our
   checkpoints are layout-agnostic npz + treedef: restore simply
   re-shards under the new jit);
3. keep the *global* batch constant when possible (preferred: gradient
   accumulation rises on the smaller mesh) so the training trajectory
   stays comparable.

Pure planning logic — drivers execute the plan; tests verify the
invariants (never exceeds surviving chips, preserves model axis,
accumulation x data_parallel x microbatch == global batch).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ElasticPlan:
    data_parallel: int
    model_parallel: int
    grad_accumulation: int
    chips_used: int
    chips_idle: int

    @property
    def valid(self) -> bool:
        return self.data_parallel >= 1 and self.model_parallel >= 1


def plan_remesh(
    surviving_chips: int,
    *,
    model_parallel: int,
    global_batch: int,
    old_data_parallel: int,
    old_grad_accumulation: int = 1,
) -> ElasticPlan:
    """Largest data-parallel degree that (a) fits the surviving chips,
    (b) divides the global batch (so per-shard batch stays integral)."""
    if surviving_chips < model_parallel:
        return ElasticPlan(0, model_parallel, 0, 0, surviving_chips)
    max_dp = surviving_chips // model_parallel
    dp = min(max_dp, old_data_parallel)
    while dp > 1 and global_batch % dp:
        dp -= 1
    # keep global batch: effective tokens = dp * micro * accum
    old_capacity = old_data_parallel * old_grad_accumulation
    accum = max(1, -(-old_capacity // dp))
    return ElasticPlan(
        data_parallel=dp,
        model_parallel=model_parallel,
        grad_accumulation=accum,
        chips_used=dp * model_parallel,
        chips_idle=surviving_chips - dp * model_parallel,
    )
