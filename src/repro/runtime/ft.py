"""Fault tolerance: heartbeat monitoring + checkpoint/restart loop.

At thousand-node scale the mean time between node failures drops below
the job length, so the framework — not the operator — must own recovery:

- `HeartbeatMonitor` tracks per-worker liveness (the coordinator-side
  view; on a real deployment heartbeats arrive over RPC, here they are
  injected by the caller/tests).
- `FaultTolerantLoop` wraps a step function with (a) periodic atomic
  checkpoints, (b) failure detection, (c) restart-from-latest with the
  deterministic data pipeline repositioned — so a crash at step N costs
  at most ``ckpt_every`` steps of work, never silent corruption.

The same loop also hosts the PHAROS angle: a *deadline* per step (from
the RT analysis of the training pipeline). A step exceeding its
deadline marks the contributing worker a straggler candidate
(`runtime.straggler`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

from repro.checkpoint import CheckpointManager


class WorkerState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class _Worker:
    last_beat: float
    state: WorkerState = WorkerState.HEALTHY


class HeartbeatMonitor:
    """Coordinator-side liveness view over injected heartbeats."""

    def __init__(self, workers: list[str], *, suspect_after: float = 5.0,
                 dead_after: float = 15.0,
                 # rtlint: disable=clock-domain -- injectable host-liveness
                 # clock default; tests inject a virtual clock
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.suspect_after = suspect_after
        self.dead_after = dead_after
        now = clock()
        self.workers = {w: _Worker(last_beat=now) for w in workers}

    def beat(self, worker: str) -> None:
        w = self.workers[worker]
        w.last_beat = self.clock()
        w.state = WorkerState.HEALTHY

    def sweep(self) -> dict[str, WorkerState]:
        now = self.clock()
        for w in self.workers.values():
            silent = now - w.last_beat
            if silent >= self.dead_after:
                w.state = WorkerState.DEAD
            elif silent >= self.suspect_after:
                w.state = WorkerState.SUSPECT
        return {k: v.state for k, v in self.workers.items()}

    def dead(self) -> list[str]:
        return [k for k, v in self.sweep().items() if v is WorkerState.DEAD]

    def healthy_count(self) -> int:
        return sum(
            1 for v in self.sweep().values() if v is WorkerState.HEALTHY
        )


@dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    failures_seen: int = 0
    checkpoints: int = 0
    resumed_from: list[int] = field(default_factory=list)


class FaultTolerantLoop:
    """Checkpoint/restart driver around a pure step function.

    ``step_fn(step, state) -> state`` must be deterministic given
    (step, state) — with the deterministic data pipeline this holds, so
    recovery replays to an identical trajectory (tested).

    ``failure_hook(step) -> bool`` lets tests/chaos-drills inject a
    failure before a step; a real deployment wires the heartbeat
    monitor's `dead()` here instead.
    """

    def __init__(
        self,
        mgr: CheckpointManager,
        step_fn,
        *,
        failure_hook=None,
        max_restarts: int = 16,
    ):
        self.mgr = mgr
        self.step_fn = step_fn
        self.failure_hook = failure_hook or (lambda step: False)
        self.max_restarts = max_restarts
        self.report = LoopReport()

    def run(self, init_state, total_steps: int):
        """Run to ``total_steps`` surviving injected failures."""
        restarts = 0
        while True:
            start, state = self.mgr.restore_latest(init_state)
            if start:
                self.report.resumed_from.append(start)
            try:
                for step in range(start, total_steps):
                    if self.failure_hook(step):
                        self.report.failures_seen += 1
                        raise RuntimeError(f"injected failure at step {step}")
                    state = self.step_fn(step, state)
                    self.report.steps_run += 1
                    if self.mgr.maybe_save(step + 1, state):
                        self.report.checkpoints += 1
                return state, self.report
            except RuntimeError:
                restarts += 1
                self.report.restarts = restarts
                if restarts > self.max_restarts:
                    raise
