"""Straggler mitigation, PHAROS-style: deadlines for training steps.

The paper's lens — every job must have bounded response time — applies
to the *training pipeline* too: a synchronous step is a job whose
deadline is the step-time budget; a worker that repeatedly blows the
budget is a straggler that would stall all N workers.

`StragglerMitigator` keeps per-worker EWMA step times, flags workers
slower than ``threshold x`` the fleet median, and recommends an action:

- ``backup``   — schedule a backup copy of the straggler's shard
                 (speculative execution; first finisher wins),
- ``exclude``  — drop the worker and trigger an elastic re-mesh
                 (`runtime.elastic`) when it exceeds the miss budget,

mirroring how the serving side handles deadline misses (SRT: bounded,
not zero, misses).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerReport:
    stragglers: list[str]
    actions: dict[str, str]
    median_step: float


class StragglerMitigator:
    def __init__(
        self,
        workers: list[str],
        *,
        ewma: float = 0.3,
        threshold: float = 1.5,
        miss_budget: int = 5,
    ):
        self.ewma = ewma
        self.threshold = threshold
        self.miss_budget = miss_budget
        self.step_time: dict[str, float] = {w: 0.0 for w in workers}
        self.misses: dict[str, int] = {w: 0 for w in workers}

    def observe(self, worker: str, step_seconds: float) -> None:
        prev = self.step_time[worker]
        self.step_time[worker] = (
            step_seconds
            if prev == 0.0
            else (1 - self.ewma) * prev + self.ewma * step_seconds
        )

    def assess(self) -> StragglerReport:
        times = [t for t in self.step_time.values() if t > 0.0]
        if not times:
            return StragglerReport([], {}, 0.0)
        median = float(np.median(times))
        stragglers, actions = [], {}
        for w, t in self.step_time.items():
            if t > self.threshold * median > 0:
                self.misses[w] += 1
                stragglers.append(w)
                actions[w] = (
                    "exclude" if self.misses[w] >= self.miss_budget else "backup"
                )
            else:
                self.misses[w] = max(0, self.misses[w] - 1)
        return StragglerReport(stragglers, actions, median)
