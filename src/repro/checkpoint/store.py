"""Sharded npz checkpoints with atomic commit and auto-resume.

Layout (one directory per step)::

    <root>/step_000123/
        shard_00000_of_00004.npz   # this host's param/opt leaves
        meta.json                  # treedef structure + leaf manifest
        COMMITTED                  # written last -> atomic visibility

Fault-tolerance contract (runtime/ft.py builds on this):

- `save_checkpoint` writes into ``step_xxx.tmp`` and renames after the
  COMMITTED marker is inside — a crash mid-save never corrupts the
  latest checkpoint, and `latest_step` only ever sees committed dirs.
- every host writes only its own shard file (host-sharded state);
  restore reads the shard(s) it owns. On a single-host dev box there is
  exactly one shard.
- `CheckpointManager.keep` bounds disk usage (old steps pruned after a
  successful commit).

Arrays are gathered with `jax.device_get` before writing — for
fully-replicated or host-local shards this is the host's own data; for
cross-host global arrays a production deployment would swap in
`multihost_utils.process_allgather`, which is the only line that would
change.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np

_COMMITTED = "COMMITTED"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(
    root: str,
    step: int,
    state,
    host_id: int = 0,
    num_hosts: int = 1,
) -> str:
    """Atomically write ``state`` (any pytree) for ``step``."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + f".tmp_{host_id}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(state)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # npz cannot serialize ml_dtypes (bfloat16 etc.): store the
            # raw bits; meta's dtype string restores the view.
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrays[f"leaf_{i}"] = a
    shard_name = f"shard_{host_id:05d}_of_{num_hosts:05d}.npz"
    np.savez(os.path.join(tmp, shard_name), **arrays)
    meta = {
        "step": step,
        "num_hosts": num_hosts,
        "paths": paths,
        "dtypes": dtypes,
        "shapes": [list(x.shape) for x in arrays.values()],
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, _COMMITTED), "w") as f:
        f.write("ok\n")
    # atomic publish: rename tmp -> final (POSIX rename is atomic)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(root: str) -> int | None:
    """Largest committed step under ``root`` (None if no checkpoint)."""
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        if not name.startswith("step_") or name.endswith((".tmp", ".trash")):
            continue
        path = os.path.join(root, name)
        if not os.path.exists(os.path.join(path, _COMMITTED)):
            continue
        try:
            s = int(name.split("_")[1].split(".")[0])
        except ValueError:
            continue
        best = s if best is None else max(best, s)
    return best


def restore_checkpoint(root: str, step: int, like, host_id: int = 0):
    """Restore the pytree saved at ``step``; ``like`` provides treedef.

    Leaf order is matched by path string, so adding/removing state
    fields fails loudly instead of silently mis-assigning arrays.
    """
    path = os.path.join(root, f"step_{step:09d}")
    if not os.path.exists(os.path.join(path, _COMMITTED)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    shard = [n for n in os.listdir(path) if n.startswith(f"shard_{host_id:05d}_")]
    if not shard:
        raise FileNotFoundError(f"host {host_id} shard missing in {path}")
    import ml_dtypes

    with np.load(os.path.join(path, shard[0])) as z:
        arrays = []
        for i, dt in enumerate(meta["dtypes"]):
            a = z[f"leaf_{i}"]
            if dt == "bfloat16":
                a = a.view(ml_dtypes.bfloat16)
            arrays.append(a)

    like_paths, like_leaves, treedef = _flatten_with_paths(like)
    if like_paths != meta["paths"]:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  saved:    {meta['paths'][:5]}...\n"
            f"  expected: {like_paths[:5]}..."
        )
    restored = [
        jax.numpy.asarray(a, dtype=l.dtype) for a, l in zip(arrays, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Periodic save + auto-resume + retention, used by launch/train.py."""

    def __init__(
        self,
        root: str,
        every: int = 100,
        keep: int = 3,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        self.root = root
        self.every = max(1, every)
        self.keep = max(1, keep)
        self.host_id = host_id
        self.num_hosts = num_hosts

    def maybe_save(self, step: int, state) -> str | None:
        if step % self.every:
            return None
        out = save_checkpoint(
            self.root, step, state, self.host_id, self.num_hosts
        )
        self._prune()
        return out

    def restore_latest(self, like):
        """(step, state) of the newest committed checkpoint, or (0, like)."""
        s = latest_step(self.root)
        if s is None:
            return 0, like
        return s, restore_checkpoint(self.root, s, like, self.host_id)

    def _prune(self) -> None:
        steps = sorted(
            s
            for s in (
                latest_step_of(name)
                for name in os.listdir(self.root)
                if name.startswith("step_") and not name.endswith(".tmp")
            )
            if s is not None
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True
            )


def latest_step_of(name: str) -> int | None:
    try:
        return int(name.split("_")[1].split(".")[0])
    except (IndexError, ValueError):
        return None
