"""Pure-jnp oracle for the WKV-6 recurrence (naive sequential scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """Same contract as ops.rwkv6_scan, computed step by step."""
    B, S, H, hd = r.shape
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    u = u.astype(f32)

    def step(S_mat, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, hd)
        kv = jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        y = jnp.einsum(
            "bhd,bhde->bhe", r_t, S_mat + u[None, :, :, None] * kv
        )
        S_new = w_t[..., None] * S_mat + kv
        return S_new, y

    S0 = jnp.zeros((B, H, hd, hd), f32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_fin
