"""RWKV-6 chunked WKV scan — Pallas TPU kernel.

GLA-style blocking identical to `repro.models.rwkv._tmix_impl`: the
(batch*heads) axis is parallel, the chunk axis sequential with the
(hd, hd) state matrix in VMEM scratch. Within a chunk everything is
GEMM-shaped for the MXU:

- the cumulative log-decay is a lower-triangular-ones matmul (no cumsum
  primitive needed on the VPU),
- intra-chunk interaction is ``(r*W_prev) @ (k/W)^T`` masked strictly
  lower-triangular, then ``@ v``,
- the carry update is ``k_scaled^T @ v``.

Decay logits are clamped upstream (models/rwkv._DECAY_CLAMP) so the
``exp(-cumw)`` rescale stays in fp32 range for chunk <= 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _wkv_kernel(
    r_ref,  # (1, ch, hd)
    k_ref,
    v_ref,
    w_ref,
    u_ref,  # (1, hd)
    y_ref,  # (1, ch, hd) out
    sout_ref,  # (1, hd, hd) out
    s_scr,  # (hd, hd) scratch
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    rc = r_ref[0]  # (ch, hd) fp32
    kc = k_ref[0]
    vc = v_ref[0]
    wc = w_ref[0]
    u = u_ref[0]  # (hd,)

    logw = jnp.log(wc)  # (ch, hd), negative
    tri_incl = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cumw = jnp.dot(tri_incl, logw, preferred_element_type=jnp.float32)
    w_prev = jnp.exp(cumw - logw)  # prod_{s<=t-1} w_s
    rw = rc * w_prev
    kw = kc * jnp.exp(-cumw)  # k_j / prod_{s<=j} w_s

    S = s_scr[...]
    y_inter = jnp.dot(rw, S, preferred_element_type=jnp.float32)
    att = jnp.dot(rw, kw.T, preferred_element_type=jnp.float32)  # (ch, ch)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(rows > cols, att, 0.0)  # strict lower triangle
    y_intra = jnp.dot(att, vc, preferred_element_type=jnp.float32)
    diag = jnp.sum(rc * u[None, :] * kc, axis=-1, keepdims=True)  # (ch, 1)
    y_ref[0] = y_inter + y_intra + diag * vc

    w_tot = jnp.exp(cumw[-1])  # (hd,)
    k_scale = kc * jnp.exp(cumw[-1][None, :] - cumw)  # prod_{s>j} w_s
    s_scr[...] = w_tot[:, None] * S + jnp.dot(
        k_scale.T, vc, preferred_element_type=jnp.float32
    )
    sout_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("n_heads", "chunk", "interpret"))
def rwkv6_scan_call(r, k, v, w, u, *, n_heads: int, chunk: int, interpret=True):
    """r/k/v/w: (B*H, S, hd) fp32; u: (H, hd). Returns (y, S_final)."""
    BH, S, hd = r.shape
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    n_chunks = S // chunk

    grid = (BH, n_chunks)
    call = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, hd), lambda bh, c: (bh % n_heads, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )
    f32 = jnp.float32
    return call(r.astype(f32), k.astype(f32), v.astype(f32), w.astype(f32), u.astype(f32))
