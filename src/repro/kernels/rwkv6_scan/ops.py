"""Public API for the RWKV-6 WKV scan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_call

DEFAULT_CHUNK = 64


def _shrink_to_divisor(chunk: int, extent: int) -> int:
    c = min(chunk, extent)
    while extent % c:
        c //= 2
    return max(c, 1)


def rwkv6_scan(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK, interpret=True):
    """WKV-6 recurrence over (B, S, H, hd) tensors.

    ``S_t = diag(w_t) S_{t-1} + k_t v_t^T``;
    ``y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)``.
    Returns (y (B, S, H, hd) fp32, S_final (B, H, hd, hd) fp32).
    """
    B, S, H, hd = r.shape

    def flat(t):
        return jnp.swapaxes(t, 1, 2).reshape(B * H, S, hd)

    ch = _shrink_to_divisor(chunk, S)
    y, s_fin = rwkv6_scan_call(
        flat(r), flat(k), flat(v), flat(w), u, n_heads=H, chunk=ch,
        interpret=interpret,
    )
    y = jnp.swapaxes(y.reshape(B, H, S, hd), 1, 2)
    return y, s_fin.reshape(B, H, hd, hd)
