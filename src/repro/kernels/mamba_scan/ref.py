"""Pure-jnp oracle for the selective scan (associative_scan form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dt, B, C, x, A, h0=None):
    """Same contract as ops.mamba_scan; computed via associative scan."""
    dt = dt.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    x = x.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bb, S, di = x.shape
    ns = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bb, di, ns), jnp.float32)
    a = jnp.exp(dt[..., None] * A)  # (Bb, S, di, ns)
    b = (dt * x)[..., None] * B[:, :, None, :]  # (Bb, S, di, ns)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = bb + aa * h0[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h, C)
    return y, h[:, -1]
