"""Public API for the selective-scan kernel."""
from __future__ import annotations

from repro.kernels.mamba_scan.kernel import mamba_scan_call

DEFAULT_CHUNK = 64


def _shrink_to_divisor(chunk: int, extent: int) -> int:
    c = min(chunk, extent)
    while extent % c:
        c //= 2
    return max(c, 1)


def mamba_scan(dt, B, C, x, A, h0=None, *, chunk: int = DEFAULT_CHUNK, interpret=True):
    """Selective scan ``h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t``,
    ``y_t = <h_t, C_t>``. Shapes as in kernel.py; ``h0`` defaults to 0.
    Returns (y, h_final) fp32.
    """
    import jax.numpy as jnp

    Bb, S, di = x.shape
    ns = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bb, di, ns), jnp.float32)
    ch = _shrink_to_divisor(chunk, S)
    return mamba_scan_call(dt, B, C, x, A, h0, chunk=ch, interpret=interpret)
