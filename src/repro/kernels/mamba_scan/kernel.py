"""Chunked selective-SSM scan — Pallas TPU kernel (jamba's mamba mixer).

The diagonal recurrence ``h_t = a_t * h_{t-1} + b_t`` (per (d_inner,
d_state) channel) is blocked exactly like `repro.models.ssm`: the grid is
``(batch, n_chunks)`` with the chunk axis sequential; the carried state
``h`` lives in VMEM scratch across chunk iterations, so HBM sees each
input element once and each output element once (the scan itself is
bandwidth-bound — its roofline term is the chunk streaming, not FLOPs).

In-chunk, the recurrence is a `fori_loop` over time steps operating on
VMEM-resident (d_inner, d_state) tiles — the TPU analogue of the
register-resident inner loop of the CUDA scan the paper's workloads
assume; no (B, S, d_inner, d_state) tensor is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _scan_kernel(
    dt_ref,  # (1, ch, di) fp32
    b_ref,  # (1, ch, ns) fp32
    c_ref,  # (1, ch, ns) fp32
    x_ref,  # (1, ch, di) fp32
    a_ref,  # (di, ns) fp32
    h0_ref,  # (1, di, ns) fp32
    y_ref,  # (1, ch, di) out
    hout_ref,  # (1, di, ns) out
    h_scr,  # (di, ns) scratch
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    A = a_ref[...]  # (di, ns)
    dt = dt_ref[0]  # (ch, di)
    xs = x_ref[0]
    Bm = b_ref[0]  # (ch, ns)
    Cm = c_ref[0]

    def step(t, h):
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]  # (di,)
        x_t = jax.lax.dynamic_slice_in_dim(xs, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(Bm, t, 1, 0)[0]  # (ns,)
        c_t = jax.lax.dynamic_slice_in_dim(Cm, t, 1, 0)[0]
        a_t = jnp.exp(dt_t[:, None] * A)  # (di, ns)
        h = a_t * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)  # (di,)
        y_ref[0, t, :] = y_t
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h
    hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan_call(dt, B, C, x, A, h0, *, chunk: int, interpret: bool = True):
    """dt/x: (Bb, S, di); B/C: (Bb, S, ns); A: (di, ns); h0: (Bb, di, ns).

    Returns (y (Bb, S, di), h_final (Bb, di, ns)), all fp32.
    """
    Bb, S, di = x.shape
    ns = A.shape[1]
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    n_chunks = S // chunk

    grid = (Bb, n_chunks)
    call = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ns), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ns), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((di, ns), lambda b, c: (0, 0)),
            pl.BlockSpec((1, di, ns), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, di, ns), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, di), jnp.float32),
            jax.ShapeDtypeStruct((Bb, di, ns), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, ns), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )
    return call(
        dt.astype(jnp.float32),
        B.astype(jnp.float32),
        C.astype(jnp.float32),
        x.astype(jnp.float32),
        A.astype(jnp.float32),
        h0.astype(jnp.float32),
    )
