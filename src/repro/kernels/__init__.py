"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships three files:

- ``kernel.py`` — the ``pl.pallas_call`` with explicit BlockSpec VMEM
  tiling (TPU is the target; validated with ``interpret=True`` on CPU);
- ``ops.py``    — the jit'd public wrapper;
- ``ref.py``    — the pure-jnp oracle the tests sweep against.

Kernels:

- ``preemptible_matmul`` — the paper's §3.4 tile-granular preemption
  mechanism: grid-windowed output-stationary GEMM resumable from a flat
  tile index, partial fp32 accumulator persisted in HBM.
- ``flash_attention``    — causal GQA attention, online softmax.
- ``mamba_scan``         — chunked selective-SSM scan (jamba mixer).
- ``rwkv6_scan``         — chunked WKV-6 recurrence (GLA-style GEMMs).
"""
from repro.kernels.preemptible_matmul import (
    MatmulProgress,
    matmul,
    matmul_resumable,
    matmul_window,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

__all__ = [
    "MatmulProgress",
    "matmul",
    "matmul_resumable",
    "matmul_window",
    "flash_attention",
    "mamba_scan",
    "rwkv6_scan",
]
