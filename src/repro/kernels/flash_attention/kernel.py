"""Causal flash attention (GQA) — Pallas TPU kernel.

Blocking mirrors `repro.models.layers._attn_chunked`: queries are tiled
into ``block_q`` rows; keys/values stream in ``block_k`` tiles along the
minor grid axis with the online-softmax state (running max ``m``,
normalizer ``l``, unnormalized accumulator ``acc``) living in VMEM
scratch across the K sweep. Causal blocks strictly above the diagonal
are skipped with ``pl.when`` (no FLOPs, no VMEM traffic beyond the
prefetch pipeline).

GQA is handled in the index maps: query head ``h`` reads KV head
``h // (H // Hkv)`` — the KV tensor is never materialized per-q-head.

Scratch rows are replicated across 128 lanes (TPU fp32 tile is 8x128);
column 0 is authoritative.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_LANES = 128
_NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    block_q: int,
    block_k: int,
    n_kblocks: int,
    scale: float,
    causal: bool,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # diagonal-or-below blocks only (first q row >= last k row iff any
    # element of the block is unmasked)
    run = (not causal) or (qi * block_q + block_q - 1 >= ki * block_k)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(rows >= cols, s, _NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_kblocks - 1)
    def _finish():
        l = l_scr[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_q_heads",
        "n_kv_heads",
        "block_q",
        "block_k",
        "causal",
        "interpret",
    ),
)
def flash_attention_call(
    q,
    k,
    v,
    *,
    n_q_heads: int,
    n_kv_heads: int,
    block_q: int,
    block_k: int,
    causal: bool = True,
    interpret: bool = True,
):
    """q: (B*H, S, hd); k/v: (B*Hkv, S, hd). Returns (B*H, S, hd)."""
    BH, S, hd = q.shape
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} not divisible by blocks ({block_q},{block_k})")
    group = n_q_heads // n_kv_heads
    n_qb, n_kb = S // block_q, S // block_k
    scale = hd**-0.5

    def kv_head(bh):
        b, h = bh // n_q_heads, bh % n_q_heads
        return b * n_kv_heads + h // group

    grid = (BH, n_qb, n_kb)
    call = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            block_q=block_q,
            block_k=block_k,
            n_kblocks=n_kb,
            scale=scale,
            causal=causal,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (kv_head(bh), ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (kv_head(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )
    return call(q, k, v)
