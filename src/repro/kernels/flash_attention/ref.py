"""Pure-jnp oracle for flash attention (materialized scores, fp32)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    s = jnp.einsum(
        "bqhd,bshd->bhqs",
        q.astype(jnp.float32),
        kx.astype(jnp.float32),
    ) * (hd**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
