"""Public flash-attention API over (B, S, H, hd) activations."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_call

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _shrink_to_divisor(block: int, extent: int) -> int:
    b = min(block, extent)
    while extent % b:
        b //= 2
    return max(b, 1)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Causal GQA attention. q: (B, S, H, hd); k/v: (B, S, Hkv, hd).

    Returns (B, S, H, hd) in q's dtype. Softmax runs in fp32 in-kernel.
    """
    B, S, H, hd = q.shape
    _, _, Hkv, _ = k.shape
    if H % Hkv:
        raise ValueError("n_heads must be divisible by n_kv_heads")
    bq = _shrink_to_divisor(block_q, S)
    bk = _shrink_to_divisor(block_k, S)
    qf = jnp.swapaxes(q, 1, 2).reshape(B * H, S, hd)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * Hkv, S, hd)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * Hkv, S, hd)
    out = flash_attention_call(
        qf,
        kf,
        vf,
        n_q_heads=H,
        n_kv_heads=Hkv,
        block_q=bq,
        block_k=bk,
        causal=causal,
        interpret=interpret,
    )
    return jnp.swapaxes(out.reshape(B, H, S, hd), 1, 2)
