"""Preemptible output-stationary matmul — the paper's §3.4 mechanism on TPU.

PHAROS preempts *inside* a layer at tile boundaries: the accelerator
finishes the in-flight tile, spills the partial output to DDR, records
loop iterators in the progress table, runs the high-priority job, then
reloads and resumes. An XLA dispatch is non-interruptible, so on TPU the
preemption quantum becomes a *grid window*: one `pallas_call` executes
output tiles ``[start, start + window)`` of the flattened (m, n) tile
grid and accumulates into an HBM-resident fp32 buffer (aliased in/out,
so untouched tiles persist). The host scheduler interleaves windows of
different jobs; the progress table entry is just ``next_tile``.

The overhead this structure pays is exactly Eq. 5's:

    e_tile  — the in-flight window must finish before the preemptor runs,
    e_store — the fp32 partial tiles are written back to HBM,
    e_load  — resume re-streams the A/B operand tiles (+ partial C).

Grid: ``(window, k_steps)`` with k minor — each window position owns one
output tile, revisited across k so the accumulator stays in VMEM for the
whole K reduction; block index maps use a scalar-prefetch ``start`` so
the same compiled kernel serves every window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _window_kernel(start_ref, a_ref, b_ref, cin_ref, o_ref, *, k_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = cin_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block", "window", "n_tiles_n", "k_steps", "interpret"),
)
def matmul_window_call(
    start,
    a,
    b,
    c_acc,
    *,
    block: tuple[int, int, int],
    window: int,
    n_tiles_n: int,
    k_steps: int,
    interpret: bool = True,
):
    """Execute output tiles ``[start, start + window)``; returns new c_acc.

    ``a``: (M, K) any float dtype, ``b``: (K, N), ``c_acc``: (M, N) fp32.
    All dims must be multiples of the block. ``start`` is a traced int32
    scalar — one compiled kernel serves every window of a given geometry.
    """
    bm, bk, bn = block

    def im_a(w, k, s):
        return ((s[0] + w) // n_tiles_n, k)

    def im_b(w, k, s):
        return (k, (s[0] + w) % n_tiles_n)

    def im_c(w, k, s):
        return ((s[0] + w) // n_tiles_n, (s[0] + w) % n_tiles_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(window, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), im_a),
            pl.BlockSpec((bk, bn), im_b),
            pl.BlockSpec((bm, bn), im_c),
        ],
        out_specs=pl.BlockSpec((bm, bn), im_c),
    )
    call = pl.pallas_call(
        functools.partial(_window_kernel, k_steps=k_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(c_acc.shape, jnp.float32),
        input_output_aliases={3: 0},  # c_acc (after the scalar operand)
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )
    start_vec = jnp.asarray([start], jnp.int32)
    return call(start_vec, a, b, c_acc)
