"""Pure-jnp oracle for the preemptible matmul."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b):
    """Full product in fp32 (the kernel accumulates in fp32)."""
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def matmul_window_ref(a, b, c_acc, start: int, window: int, block):
    """Oracle for one window: add A@B's contribution for the output
    tiles with flat index in [start, start + window), leave the rest."""
    bm, bk, bn = block
    M, _ = a.shape
    _, N = b.shape
    n_m, n_n = M // bm, N // bn
    full = matmul_ref(a, b)
    out = jnp.array(c_acc)
    for flat in range(start, min(start + window, n_m * n_n)):
        i, j = divmod(flat, n_n)
        sl = (slice(i * bm, (i + 1) * bm), slice(j * bn, (j + 1) * bn))
        out = out.at[sl].set(c_acc[sl] + full[sl])
    return out


def matmul_partial_ref(a, b, upto_tile: int, block):
    """Oracle for a fresh run preempted after ``upto_tile`` tiles."""
    c0 = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    return matmul_window_ref(a, b, c0, 0, upto_tile, block)
