"""Public API for the preemptible matmul (jit'd wrappers + progress model).

A *job segment* on an accelerator is a chain of GEMMs; each GEMM is a
sequence of tile windows. `MatmulProgress` is the on-host progress-table
entry (paper Fig. 2): the flat index of the next unexecuted tile. The
serving scheduler (repro.pipeline.serve) preempts by simply not issuing
the next window and running another job's window instead.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.preemptible_matmul.kernel import matmul_window_call

DEFAULT_BLOCK = (128, 128, 128)


def grid_geometry(M: int, N: int, K: int, block: tuple[int, int, int]):
    """(n_tiles_m, n_tiles_n, k_steps, total_tiles); dims must divide."""
    bm, bk, bn = block
    if M % bm or N % bn or K % bk:
        raise ValueError(
            f"shape ({M},{K},{N}) not divisible by block {block}; "
            "pad operands first (pad_operands)"
        )
    n_m, n_n, k_steps = M // bm, N // bn, K // bk
    return n_m, n_n, k_steps, n_m * n_n


def pick_window(total_tiles: int, requested: int) -> int:
    """Largest divisor of ``total_tiles`` that is <= requested.

    Windows must tile the grid exactly so every (start, window) call
    covers in-range tiles only (out-of-range block indices would clobber
    live tiles — see kernel.py docstring).
    """
    w = max(1, min(requested, total_tiles))
    while total_tiles % w:
        w -= 1
    return w


def pad_operands(a, b, block: tuple[int, int, int]):
    """Zero-pad (a, b) up to block multiples; returns (a, b, unpad_fn)."""
    bm, bk, bn = block
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, "inner dims disagree"
    Mp = math.ceil(M / bm) * bm
    Kp = math.ceil(K / bk) * bk
    Np = math.ceil(N / bn) * bn
    ap = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    bp = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    return ap, bp, lambda c: c[:M, :N]


@dataclass
class MatmulProgress:
    """Progress-table entry for one in-flight GEMM (paper Fig. 2)."""

    next_tile: int
    total_tiles: int

    @property
    def done(self) -> bool:
        return self.next_tile >= self.total_tiles

    @property
    def fraction(self) -> float:
        return self.next_tile / self.total_tiles


def matmul_window(
    a,
    b,
    c_acc,
    start: int,
    *,
    block=DEFAULT_BLOCK,
    window_tiles: int = 8,
    interpret: bool = True,
):
    """Run one window of output tiles starting at flat index ``start``.

    Returns ``(c_acc', next_tile)``. The caller owns scheduling: to
    preempt, simply stop calling; to resume, call again with the saved
    ``next_tile``. ``c_acc`` must be fp32 with block-multiple shape.
    """
    M, K = a.shape
    _, N = b.shape
    _, n_n, k_steps, total = grid_geometry(M, N, K, block)
    w = pick_window(total, window_tiles)
    c_acc = matmul_window_call(
        jnp.asarray(start, jnp.int32),
        a,
        b,
        c_acc,
        block=block,
        window=w,
        n_tiles_n=n_n,
        k_steps=k_steps,
        interpret=interpret,
    )
    return c_acc, min(start + w, total)


def matmul_resumable(
    a,
    b,
    *,
    block=DEFAULT_BLOCK,
    window_tiles: int = 8,
    start_tile: int = 0,
    max_windows: int | None = None,
    c_acc=None,
    interpret: bool = True,
):
    """Run (part of) ``a @ b`` window by window.

    Returns ``(c_acc, progress)``; run to completion when
    ``max_windows`` is None. Restart by passing the previous ``c_acc``
    and ``progress.next_tile``.
    """
    M, K = a.shape
    _, N = b.shape
    _, n_n, k_steps, total = grid_geometry(M, N, K, block)
    w = pick_window(total, window_tiles)
    if c_acc is None:
        c_acc = jnp.zeros((M, N), jnp.float32)
    tile = start_tile
    steps = 0
    while tile < total and (max_windows is None or steps < max_windows):
        c_acc, tile = matmul_window(
            a,
            b,
            c_acc,
            tile,
            block=block,
            window_tiles=w,
            interpret=interpret,
        )
        steps += 1
    return c_acc, MatmulProgress(next_tile=tile, total_tiles=total)


def matmul(a, b, *, block=DEFAULT_BLOCK, window_tiles: int = 64, interpret=True):
    """Plain full matmul through the preemptible kernel (for testing)."""
    c, prog = matmul_resumable(
        a, b, block=block, window_tiles=window_tiles, interpret=interpret
    )
    assert prog.done
    return c
