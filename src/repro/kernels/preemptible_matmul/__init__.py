from repro.kernels.preemptible_matmul.ops import (
    DEFAULT_BLOCK,
    MatmulProgress,
    grid_geometry,
    matmul,
    matmul_resumable,
    matmul_window,
    pad_operands,
    pick_window,
)

__all__ = [
    "DEFAULT_BLOCK",
    "MatmulProgress",
    "grid_geometry",
    "matmul",
    "matmul_resumable",
    "matmul_window",
    "pad_operands",
    "pick_window",
]
