"""Version compatibility shims for the Pallas TPU API.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this
JAX exposes so the kernels build on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # older releases
    CompilerParams = pltpu.TPUCompilerParams
