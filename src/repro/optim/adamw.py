"""AdamW + global-norm clipping + cosine schedule (pure JAX, no optax).

Moments are fp32 regardless of parameter dtype (bf16 master-less
training: params stay bf16, the fp32 first/second moments carry the
precision — standard large-model practice and what the dry-run memory
model assumes: 2 + 4 + 4 bytes/param for (param, m, v)).

All functions are pytree-polymorphic and jit/pjit-safe; the optimizer
state inherits each parameter's sharding, so FSDP shards moments for
free under `jax.jit` with sharded params.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to lr_min."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (
        1.0 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    ), norm


def adamw_init(params):
    """State: fp32 (m, v) mirroring the param tree + scalar step."""
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _is_matrix(p) -> bool:
    return p.ndim >= 2  # decay weights, not biases/norms/scalars


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    state = {"m": new_m, "v": new_v, "step": step}
    return new_p, state, {"grad_norm": gnorm, "lr": lr}
