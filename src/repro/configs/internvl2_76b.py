"""internvl2-76b — VLM: InternViT frontend + InternLM2-style decoder.

[arXiv:2404.16821] Backbone: 80L, d_model=8192, 64H (GQA kv=8),
d_ff=28672, vocab=128256. The InternViT-6B vision tower is a STUB per
the assignment: ``input_specs`` provides precomputed patch embeddings
(dim 3200) which a linear projector maps into the LM space.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision_stub",
    frontend_dim=3200,
    mlp_type="swiglu",
    rope_theta=1e6,
    max_seq=131072,
)
