"""qwen1.5-32b — dense with QKV bias and full MHA (kv = heads).

[hf:Qwen family] 64L, d_model=5120, 40H (kv=40, i.e. MHA), d_ff=27392,
vocab=152064, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    mlp_type="swiglu",
    rope_theta=1e6,
    max_seq=131072,
)
