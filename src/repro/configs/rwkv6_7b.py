"""rwkv6-7b "Finch" — attention-free, data-dependent decay.

[arXiv:2404.05892] 32L, d_model=4096, d_ff=14336 (channel-mix),
vocab=65536, head size 64 -> 64 rwkv heads. O(1) decode state ->
participates in ``long_500k``.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=32,  # unused (attn-free); kept for schema completeness
    n_kv_heads=32,
    d_ff=14336,
    vocab=65536,
    attn_free=True,
    rwkv_head_size=64,
    max_seq=524288,
    run_long_context=True,
)
