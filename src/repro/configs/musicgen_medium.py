"""musicgen-medium — decoder-only over EnCodec audio tokens.

[arXiv:2306.05284] 48L, d_model=1536, 24H (kv=24, MHA), d_ff=6144,
vocab=2048 (EnCodec codebook). The EnCodec frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
(EnCodec latent dim 128) that a linear projector lifts to d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    frontend="audio_stub",
    frontend_dim=128,
    mlp_type="gelu",
    rope_theta=1e4,
    max_seq=32768,
)
