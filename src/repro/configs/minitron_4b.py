"""minitron-4b — width/depth-pruned Nemotron distillation.

[arXiv:2407.14679] 32L, d_model=3072, 24H (GQA kv=8), d_ff=9216,
vocab=256000. Nemotron lineage: squared-ReLU (non-gated) MLP,
untied huge embedding table.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    mlp_type="gelu",
    rope_theta=1e4,
    max_seq=131072,
)
