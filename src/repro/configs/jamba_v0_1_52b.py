"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=65536, MoE 16 experts top-2 on every other layer. One attention
layer per 8-layer block (1:7 attn:mamba); sub-quadratic decode state ->
participates in ``long_500k``.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    mamba_chunk=256,
    mlp_type="swiglu",
    rope_theta=1e6,
    max_seq=524288,
    run_long_context=True,
)
