"""dbrx-132b — large fine-grained MoE (16 experts, top-4).

[hf:databricks/dbrx-base] 40L, d_model=6144, 48H (GQA kv=8),
d_ff=10752 per expert, vocab=100352, 16 experts top-4 every layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    top_k=4,
    moe_every=1,
    mlp_type="swiglu",
    rope_theta=5e5,
    max_seq=131072,
)
