"""Architecture configuration schema.

Every assigned architecture is a decoder-style stack of residual blocks;
a block = (mixer, ffn) where mixer in {attn, mamba, rwkv} and ffn in
{dense, moe, rwkv_cmix}. Heterogeneous stacks (jamba) repeat a fixed
pattern, which the model assembler exploits: parameters are stacked over
pattern repeats and the stack is executed with ``lax.scan`` so the HLO
contains each distinct layer once (critical for 512-device dry-run
compile times).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # layer i is MoE iff n_experts>0 and i % moe_every == moe_every-1
    capacity_factor: float = 1.25  # advisory (sort-based path is dropless)
    #: storage padding of the expert banks (0 = none). Padding to a
    #: multiple of the TP axis restores expert-parallel sharding when
    #: the true expert count does not divide it (granite: 40 -> 48);
    #: padded experts are never routed to (router stays n_experts wide).
    expert_pad_to: int = 0

    # --- hybrid / SSM ---
    attn_every: int = 0  # jamba: attn layer iff i % attn_every == attn_every // 2
    attn_free: bool = False  # rwkv: no attention anywhere
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 64
    rwkv_head_size: int = 64

    # --- flavour ---
    qkv_bias: bool = False
    mlp_type: str = "swiglu"  # swiglu | gelu
    frontend: str = "none"  # none | vision_stub | audio_stub
    frontend_dim: int = 0  # stub embedding dim (0 -> tokens, no stub)
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    max_seq: int = 131072
    tie_embeddings: bool = False

    # --- shape sets this arch participates in ---
    run_long_context: bool = False  # long_500k only for ssm/hybrid

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    # ------------------------------------------------------------------
    # layer plan & repeating pattern
    # ------------------------------------------------------------------
    def layer_plan(self) -> tuple[tuple[str, str], ...]:
        """(mixer, ffn) kind per layer."""
        plan = []
        for i in range(self.n_layers):
            if self.attn_free:
                mixer = "rwkv"
            elif self.attn_every > 0:
                mixer = "attn" if i % self.attn_every == self.attn_every // 2 else "mamba"
            else:
                mixer = "attn"
            if mixer == "rwkv":
                ffn = "rwkv_cmix"
            elif self.n_experts > 0 and i % self.moe_every == self.moe_every - 1:
                ffn = "moe"
            else:
                ffn = "dense"
            plan.append((mixer, ffn))
        return tuple(plan)

    def pattern(self) -> tuple[tuple[str, str], ...]:
        """Shortest repeating block pattern dividing n_layers."""
        plan = self.layer_plan()
        n = len(plan)
        for p in range(1, n + 1):
            if n % p == 0 and all(plan[i] == plan[i % p] for i in range(n)):
                return plan[:p]
        return plan

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern())

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.mamba_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    # ------------------------------------------------------------------
    # parameter counting (roofline MODEL_FLOPS = 6 N D / 6 N_active D)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict[str, float]:
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = active = v * d  # embed
        total += d * v  # lm head
        active += d * v
        for mixer, ffn in self.layer_plan():
            if mixer == "attn":
                p = d * h * hd + 2 * d * kv * hd + h * hd * d
            elif mixer == "mamba":
                di, ns = self.d_inner, self.mamba_d_state
                p = d * 2 * di + di * self.mamba_d_conv + di * ns  # in, conv, A
                p += di * (1 + 2 * ns)  # dt, B, C projections (folded x_proj)
                p += di * d  # out
            else:  # rwkv time-mix
                p = 5 * d * d + d * d  # r,k,v,g,o + decay proj (approx lora)
            total += p
            active += p
            if ffn == "dense":
                q = (3 if self.mlp_type == "swiglu" else 2) * d * f
                total += q
                active += q
            elif ffn == "moe":
                per = (3 if self.mlp_type == "swiglu" else 2) * d * f
                total += self.n_experts * per + d * self.n_experts
                active += self.top_k * per + d * self.n_experts
            else:  # rwkv channel-mix
                q = d * int(3.5 * d) + int(3.5 * d) * d
                total += q
                active += q
        return {"total": float(total), "active": float(active)}


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests.

    Preserves the layer *pattern* (hybrid interleave, MoE cadence, GQA
    ratio) while shrinking width/depth/vocab so one step runs on CPU.
    """
    pat = len(cfg.pattern())
    n_layers = pat * min(2, cfg.n_repeats)
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_heads = min(cfg.n_heads, 4 * ratio) if not cfg.attn_free else 4
    n_kv = max(1, n_heads // ratio)
    head_dim = 16
    d_model = n_heads * head_dim if not cfg.attn_free else 64
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(32, d_model * 2) if cfg.n_experts == 0 else 32,
        vocab=256,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        rwkv_head_size=16,
        mamba_d_state=8,
        mamba_chunk=8,
        max_seq=128,
    )
