"""granite-moe-3b-a800m — fine-grained MoE (40 experts, top-8).

[hf:ibm-granite family] 32L, d_model=1536, 24H (GQA kv=8), d_ff=512
(per-expert, fine-grained), vocab=49155, 40 experts top-8 every layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    moe_every=1,
    expert_pad_to=48,  # 40 does not divide the 16-way model axis
    mlp_type="swiglu",
    rope_theta=1e4,
    max_seq=131072,
    tie_embeddings=True,
)
