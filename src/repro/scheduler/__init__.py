"""Discrete-event scheduling simulator for PHAROS (paper §3.2, §5.2–5.3).

Simulates a pipeline of accelerators (stages), each running one of the
paper's scheduling policies:

- ``fifo``            — FIFO *with* polling (segment ready once the same
                        job finished upstream and the previous job of the
                        same task finished its corresponding segment);
- ``fifo_no_polling`` — baseline FIFO where a job's segment on a stage is
                        gated on the previous job of the same task having
                        finished *all* of its segments on that stage;
- ``edf``             — preemptive EDF with tile-granular preemption
                        overhead (xi = e_tile + e_store + e_load).

Used for: schedulability detection via backlog growth over >100x periods
(paper §5.2), response-time statistics (Fig. 8), preemption counting.
"""
from repro.scheduler.des import (
    SimTask,
    SimConfig,
    SimResult,
    StageOverhead,
    simulate,
    simulate_taskset,
)

__all__ = [
    "SimTask",
    "SimConfig",
    "SimResult",
    "StageOverhead",
    "simulate",
    "simulate_taskset",
]
