"""Event-driven pipeline scheduler simulator.

Clock semantics: the simulator runs on its own event-driven virtual
timebase — event timestamps are exact model seconds, never wall time.
It shares no clock with the serving runtime; the conformance harness
(`repro.conformance`) aligns the two by driving both from the same
WCETs and release traces.

Design notes
------------
* Entities: ``M`` stages, each a single server with a job pool. A task
  is a sequence of segments ``[(stage, wcet), ...]`` executed strictly
  in order; chained (PHAROS) designs have increasing stage indices,
  throughput-guided baselines may revisit stages (backtracking), which
  the polling/no-polling FIFO variants treat differently.
* Preemption model (EDF only; FIFO never preempts). Two granularities,
  selected by ``SimConfig.preemption``:

  - ``"instant"`` — idealized: when a job with an earlier absolute
    deadline arrives at a busy stage, the running job is preempted
    immediately. Overhead mirrors the paper's tile-granular mechanism:
    the preemptor starts after ``pre = e_tile + e_store`` (drain the
    current tile, spill partial outputs) and the preempted job pays
    ``post = e_load`` extra on resume (buffer reload).
  - ``"window"`` — limited preemption, matching the `PharosServer`
    runtime: each segment executes as a sequence of non-preemptible
    *chunks* (`SimTask.chunks`, e.g. the `CostModel`'s per-layer tile
    windows; default: one chunk = the whole segment). Preemption
    decisions happen **only at chunk boundaries**, so an urgent job
    blocks for at most the in-flight chunk. Because the boundary
    already absorbed the drain (``e_tile`` becomes real blocking, not
    inserted work), each actual preemption *event* charges only
    ``e_store`` to the preemptor's start and ``e_load`` to the
    preempted job's resume — Eq. 4's xi is paid per preemption event,
    not inflated per job.
* Events are versioned per stage (``epoch``): a scheduled completion is
  ignored if the stage has been re-dispatched since it was scheduled.
* Simultaneous-event ordering mirrors the serving runtime's control
  flow exactly: at one instant, all due releases fire first (in task
  order — the gateway submits its merged, ``(time, task)``-sorted
  schedule before stepping), then stage completions are processed in
  ascending stage index (``PharosServer.step`` iterates stages in
  index order). FIFO pools break arrival-time ties by *pool insertion
  order* (the runtime's deque order), so fan-in stages — two upstream
  stages forwarding into one downstream stage at the same instant —
  order jobs identically in both layers.
* Release-time shedding (`SimConfig.shedding`): the DES can mirror the
  gateway's backlog-triggered overload policies *inside* the
  simulation — per-release verdicts (submit / drop / degrade to
  best-effort) against the simulated backlog with the same hysteresis
  the `BacklogMonitor` applies, so DES, runtime and analysis can be
  conformance-checked under overload (see
  `repro.traffic.shedding.des_release_shedding`).
* Schedulability detection (paper §5.2): simulate ``horizon`` (default
  >100x max period); declare *non*-schedulable if unfinished jobs
  accumulate or response times grow between the first and second half.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable

#: release-time shedding verdicts (string-identical to the gateway's
#: `repro.traffic.shedding` constants so adapters need no translation)
SHED_SUBMIT = "submit"
SHED_DROP = "drop"
SHED_BEST_EFFORT = "best_effort"


@dataclass(frozen=True)
class SimTask:
    """One task: ordered segments of (stage, wcet).

    Releases are strictly periodic (``phase + n * period``) unless
    ``arrivals`` gives an explicit release-time sequence — sporadic,
    Poisson, bursty MMPP, and trace-driven traffic (repro.traffic) all
    flow through that one hook. With explicit arrivals ``period`` is
    only used for analysis/metrics (set it to the minimum inter-arrival
    for sporadic traffic, or the provisioned period for stochastic
    traffic) and ``phase`` is ignored; the simulation releases exactly
    ``len(arrivals)`` jobs.
    """

    segments: tuple[tuple[int, float], ...]
    period: float
    deadline: float = 0.0  # relative; 0 -> implicit (= period)
    phase: float = 0.0
    name: str = ""
    arrivals: tuple[float, ...] | None = None  # explicit release times
    #: per-segment non-preemptible chunk lengths (window-boundary
    #: preemption, ``SimConfig.preemption == "window"``); aligned with
    #: ``segments`` as passed in, each tuple summing to that segment's
    #: WCET. None -> every segment is one indivisible chunk.
    chunks: tuple[tuple[float, ...], ...] | None = None

    def __post_init__(self) -> None:
        if self.deadline == 0.0:
            object.__setattr__(self, "deadline", self.period)
        raw = tuple(self.segments)
        if self.chunks is not None and len(self.chunks) != len(raw):
            raise ValueError("chunks must align 1:1 with segments")
        keep = [i for i, (_s, w) in enumerate(raw) if w > 0.0]
        segs = tuple((raw[i][0], raw[i][1]) for i in keep)
        object.__setattr__(self, "segments", segs)
        if not segs:
            raise ValueError("task has no non-empty segments")
        if self.chunks is not None:
            chs = tuple(tuple(float(c) for c in self.chunks[i]) for i in keep)
            for (_s, w), ch in zip(segs, chs):
                if not ch or any(c <= 0.0 for c in ch):
                    raise ValueError("chunk lengths must be positive")
                if abs(sum(ch) - w) > 1e-6 * max(w, 1e-12):
                    raise ValueError(
                        "segment chunks must sum to the segment WCET"
                    )
            object.__setattr__(self, "chunks", chs)
        if self.arrivals is not None:
            arr = tuple(float(a) for a in self.arrivals)
            if any(a < 0.0 for a in arr):
                raise ValueError("arrival times must be non-negative")
            if any(b < a for a, b in zip(arr, arr[1:])):
                raise ValueError("arrival times must be non-decreasing")
            object.__setattr__(self, "arrivals", arr)

    def segment_chunks(self, seg_idx: int) -> tuple[float, ...]:
        """Non-preemptible chunk schedule of one segment (the whole
        segment when no explicit schedule was given)."""
        if self.chunks is not None:
            return self.chunks[seg_idx]
        return (self.segments[seg_idx][1],)

    def min_inter_arrival(self) -> float:
        """Smallest observed gap (periodic tasks: the period) — the
        conservative 'period' for utilization accounting."""
        if self.arrivals is None or len(self.arrivals) < 2:
            return self.period
        return min(b - a for a, b in zip(self.arrivals, self.arrivals[1:]))


@dataclass(frozen=True)
class StageOverhead:
    """Per-stage preemption cost split (Eq. 5)."""

    e_tile: float = 0.0
    e_store: float = 0.0
    e_load: float = 0.0

    @property
    def pre(self) -> float:  # paid before the preemptor starts
        return self.e_tile + self.e_store

    @property
    def post(self) -> float:  # paid by the preempted job on resume
        return self.e_load

    @property
    def xi(self) -> float:
        return self.e_tile + self.e_store + self.e_load


@dataclass
class ReleaseShedding:
    """Release-time overload shedding against *simulated* backlog.

    Mirrors the gateway's `BacklogMonitor` + `SheddingPolicy` pair
    inside the DES: at every release, each task's pending-job count is
    checked against its ``limits[i]`` engage threshold with the same
    hysteresis (engage above the limit, disengage at half), and while
    any task is engaged ``classify(task_id, overloaded)`` decides the
    releasing job's fate — `SHED_SUBMIT`, `SHED_DROP` (never enters the
    system) or `SHED_BEST_EFFORT` (enters with an infinite absolute
    deadline: EDF orders it after every guaranteed job).

    The DES stays dependency-free: ``classify`` is an opaque callable;
    `repro.traffic.shedding.des_release_shedding` builds one from a
    real `SheddingPolicy` + `AdmissionController` + request contracts,
    with ``limits`` derived from the analysis response bounds exactly
    like `TrafficGateway.open` derives the gateway's.
    """

    limits: tuple[int, ...]
    classify: Callable[[int, tuple[int, ...]], str]
    engaged: dict[int, bool] = field(default_factory=dict)

    def observe(self, task_idx: int, pending: int) -> bool:
        limit = self.limits[task_idx]
        on = self.engaged.get(task_idx, False)
        if not on and pending > limit:
            on = True
        elif on and pending <= max(1, limit // 2):
            on = False
        self.engaged[task_idx] = on
        return on


@dataclass
class SimConfig:
    policy: str = "edf"  # "fifo" | "fifo_no_polling" | "edf"
    horizon: float = 0.0  # 0 -> 120 x max period
    overheads: list[StageOverhead] | None = None  # None -> zero overhead
    #: "instant" — idealized immediate preemption; "window" — limited
    #: preemption at `SimTask.chunks` boundaries only (the runtime's
    #: tile-window semantics), xi charged per actual preemption event
    preemption: str = "instant"
    backlog_limit: int = 64  # pending jobs per task before declaring overload
    #: divergence tolerance, 2nd half vs 1st half of the trace. Growth
    #: is declared only when *both* the mean and the max response rise
    #: past this factor. The paper's detector is backlog accumulation
    #: (`backlog_limit`) alone; this heuristic is a secondary early
    #: signal, so the tolerance is deliberately loose — bounded systems
    #: with near-commensurate periods legitimately drift their worst
    #: phasing/collision rate across a finite trace by tens of percent,
    #: while true divergence (u > 1) grows the response linearly in the
    #: horizon (far past 2x between halves).
    growth_tol: float = 2.0
    #: release-time overload shedding (None -> every release enters).
    #: Duck-typed: anything with `ReleaseShedding`'s observe / engaged /
    #: classify surface works — `repro.traffic.modes.ModeController`
    #: plugs in here to run mixed-criticality mode switching against
    #: the simulated backlog (its committed transitions are drained via
    #: an optional ``drain_events()`` hook into ``mode_switch`` trace
    #: events and `SimResult.mode_switches`)
    shedding: ReleaseShedding | None = None
    #: schedule-trace sink (duck-typed `repro.obs.TraceRecorder` — the
    #: DES stays dependency-free). Resolved once per `simulate` call:
    #: None or a disabled recorder means zero per-event work and zero
    #: events emitted; an enabled recorder receives release / dispatch /
    #: preempt_store / preempt_load / segment_end / complete /
    #: deadline_miss / shed events on the DES's virtual timebase
    trace: object | None = None


@dataclass
class SimResult:
    schedulable: bool
    response_times: list[list[float]]  # per task, completed jobs in order
    max_response: list[float]
    mean_response: list[float]
    preemptions: int
    jobs_released: int
    jobs_completed: int
    overload_detected: bool
    growth_detected: bool
    #: release times of the completed jobs, aligned 1:1 with
    #: ``response_times`` — the join key for matching "the same job"
    #: across runs whose shed sets differ (conformance under overload)
    completed_releases: list[list[float]] = field(default_factory=list)
    #: release-time shedding accounting (all zero without
    #: `SimConfig.shedding`)
    jobs_shed: int = 0
    shed_per_task: list[int] = field(default_factory=list)
    degraded_per_task: list[int] = field(default_factory=list)
    #: committed mixed-criticality transitions, in commit order:
    #: ``(t, mode, survivors)`` tuples drained from a mode-aware
    #: shedding hook (`repro.traffic.modes.ModeController`); empty
    #: without one
    mode_switches: list[tuple[float, str, tuple[str, ...]]] = field(
        default_factory=list
    )

    def max_response_overall(self) -> float:
        vals = [m for m in self.max_response if m > 0.0]
        return max(vals) if vals else 0.0

    def response_percentiles(
        self, task_idx: int, qs=(50, 95, 99)
    ) -> dict[str, float]:
        """Nearest-rank response-time percentiles of one task
        (`repro.obs.metrics.percentile` — the one shared
        implementation)."""
        from repro.obs.metrics import percentile_summary

        return percentile_summary(self.response_times[task_idx], qs)

    def tardiness_percentiles(
        self, task_idx: int, deadline: float, qs=(50, 95, 99)
    ) -> dict[str, float]:
        """Per-task tardiness (``max(0, response - deadline)``)
        percentiles against the given relative deadline."""
        from repro.obs.metrics import percentile_summary

        return percentile_summary(
            [
                max(0.0, r - deadline)
                for r in self.response_times[task_idx]
            ],
            qs,
        )


class _Job:
    __slots__ = (
        "task_id",
        "idx",
        "release",
        "abs_deadline",
        "name",
        "seg_idx",
        "remaining",
        "arrive_stage_t",
        "enter_seq",
        "stage_done",
        "chunk_i",
        "carry",
    )

    def __init__(self, task_id: int, idx: int, release: float, abs_deadline: float):
        self.task_id = task_id
        self.idx = idx
        self.release = release
        self.abs_deadline = abs_deadline
        # task name cached per job when tracing (one lookup per release
        # instead of one per emitted event); "" untraced
        self.name = ""
        self.seg_idx = 0  # next segment to execute
        self.remaining = 0.0  # remaining service of the segment in flight
        self.arrive_stage_t = release
        self.enter_seq = 0  # pool-insertion order (FIFO tie-breaking)
        # per-segment completion flags, for the polling variants
        self.stage_done: list[bool] = []
        # window-boundary (limited-preemption) bookkeeping
        self.chunk_i = 0  # next chunk of the segment in flight
        self.carry = 0.0  # resume overhead owed before the next chunk


class _Stage:
    __slots__ = ("idx", "pool", "running", "run_start", "epoch", "block_until")

    def __init__(self, idx: int):
        self.idx = idx
        self.pool: list[_Job] = []
        self.running: _Job | None = None
        self.run_start = 0.0
        self.epoch = 0
        self.block_until = 0.0  # non-preemptible overhead window end


def _job_key_fifo(j: _Job):
    # pool-insertion order breaks arrival-time ties — the runtime's
    # FIFO deque order (fan-in forwards land in upstream-stage order)
    return (j.arrive_stage_t, j.enter_seq)


def _job_key_edf(j: _Job):
    return (j.abs_deadline, j.release, j.task_id, j.idx)


def simulate(tasks: list[SimTask], cfg: SimConfig) -> SimResult:
    if cfg.policy not in ("fifo", "fifo_no_polling", "edf"):
        raise ValueError(f"unknown policy {cfg.policy!r}")
    if cfg.preemption not in ("instant", "window"):
        raise ValueError(f"unknown preemption model {cfg.preemption!r}")
    n_stages = 1 + max(s for t in tasks for s, _ in t.segments)
    overheads = cfg.overheads or [StageOverhead()] * n_stages
    if len(overheads) < n_stages:
        raise ValueError("overheads shorter than number of stages")
    horizon = cfg.horizon or 120.0 * max(t.period for t in tasks)
    preemptive = cfg.policy == "edf"
    window_mode = cfg.preemption == "window"
    key = _job_key_edf if preemptive else _job_key_fifo
    # trace sink resolved once (`repro.obs.TraceRecorder.sink`):
    # disabled tracing costs one `is not None` test per emission site
    # and emits nothing at all; enabled tracing pays one call + one
    # row tuple per event — the <5% DES budget obs_bench enforces
    tr = (
        cfg.trace.sink()
        if cfg.trace is not None and getattr(cfg.trace, "enabled", False)
        else None
    )
    names = (
        [t.name or f"task{i}" for i, t in enumerate(tasks)]
        if tr is not None
        else []
    )

    stages = [_Stage(k) for k in range(n_stages)]
    # Event heap: (time, kind, prio, seq, data). kinds: 0=release,
    # 1=complete. Simultaneous events mirror the runtime's control
    # flow: releases before completions (the serving loop submits due
    # arrivals before stepping), releases in task order (the gateway's
    # merged schedule), completions in ascending stage index
    # (`PharosServer.step` iterates stages in index order). ``prio`` is
    # the task id for releases and the stage index for completions —
    # data[0] either way.
    evq: list[tuple[float, int, int, int, tuple]] = []
    seq = 0

    def push(t: float, kind: int, data: tuple) -> None:
        nonlocal seq
        heapq.heappush(evq, (t, kind, data[0], seq, data))
        seq += 1

    # Per-task bookkeeping for the FIFO gating variants and metrics.
    n_tasks = len(tasks)
    response: list[list[float]] = [[] for _ in range(n_tasks)]
    # jobs of each task that have completed ALL segments, contiguous prefix
    completed_upto = [-1] * n_tasks
    # per (task, job_idx) segment-completion map for "with polling" gating
    seg_complete: dict[tuple[int, int], list[bool]] = {}
    pending_count = [0] * n_tasks
    completed_releases: list[list[float]] = [[] for _ in range(n_tasks)]
    preemptions = 0
    jobs_released = 0
    jobs_completed = 0
    jobs_shed = 0
    shed_per_task = [0] * n_tasks
    degraded_per_task = [0] * n_tasks
    mode_switches: list[tuple[float, str, tuple[str, ...]]] = []
    # mode-transition drain hook, resolved once like the trace sink: a
    # mode-aware shedding object (`repro.traffic.modes.ModeController`)
    # commits transitions during the observe sweep and the DES stamps
    # them with its virtual clock here
    drain_modes = (
        getattr(cfg.shedding, "drain_events", None)
        if cfg.shedding is not None
        else None
    )
    overload = False
    enter_counter = 0

    # Queue of jobs waiting for their same-task gating condition, per task.
    gated: list[list[_Job]] = [[] for _ in range(n_tasks)]

    def gate_open(job: _Job) -> bool:
        """May `job` enter the pool of its next segment's stage?"""
        t_id, j_idx, s_idx = job.task_id, job.idx, job.seg_idx
        if j_idx == 0:
            return True
        stage_k = tasks[t_id].segments[s_idx][0]
        if cfg.policy == "fifo_no_polling":
            # previous job of this task must have finished ALL its
            # segments mapped to this stage
            prev = seg_complete.get((t_id, j_idx - 1))
            if prev is None:  # previous job fully done and GC'd
                return completed_upto[t_id] >= j_idx - 1
            for si, (st, _w) in enumerate(tasks[t_id].segments):
                if st == stage_k and not prev[si]:
                    return False
            return True
        else:
            # With polling (and EDF) the same-task precedence —
            # job j's segment must not *run* before job j-1's
            # corresponding segment is done — is already enforced by
            # the pool ordering itself: identical visit sequences mean
            # j can never overtake j-1 at any stage (FIFO keeps j-1
            # ahead in insertion order; EDF gives it the earlier
            # deadline), so j reaches the server only after j-1's
            # segment completed. Enqueue immediately — the serving
            # runtime does exactly this, and holding j back to the
            # gate-open instant would hand its queue position to
            # third-party jobs arriving in between (the fan-in
            # tie-breaking drift the conformance harness used to
            # absorb in `quantum_slack`).
            return True

    def enter_stage(job: _Job, now: float) -> None:
        nonlocal enter_counter
        stage_k = tasks[job.task_id].segments[job.seg_idx][0]
        job.arrive_stage_t = now
        enter_counter += 1
        job.enter_seq = enter_counter
        job.remaining = tasks[job.task_id].segments[job.seg_idx][1]
        job.chunk_i = 0
        job.carry = 0.0
        stages[stage_k].pool.append(job)
        dispatch(stages[stage_k], now)

    def try_admit(job: _Job, now: float) -> None:
        if gate_open(job):
            enter_stage(job, now)
        else:
            gated[job.task_id].append(job)

    def recheck_gated(t_id: int, now: float) -> None:
        still = []
        for job in gated[t_id]:
            if gate_open(job):
                enter_stage(job, now)
            else:
                still.append(job)
        gated[t_id] = still

    def advance_completed(t_id: int) -> None:
        """Advance the contiguous fully-completed job prefix."""
        while True:
            flags = seg_complete.get((t_id, completed_upto[t_id] + 1))
            if flags is None or not all(flags):
                break
            completed_upto[t_id] += 1
            seg_complete.pop((t_id, completed_upto[t_id] - 1), None)

    def start_chunk(st: _Stage, job: _Job, now: float) -> None:
        """Window mode: occupy the stage with ``job``'s next
        non-preemptible chunk (plus any resume overhead owed)."""
        quantum = (
            tasks[job.task_id].segment_chunks(job.seg_idx)[job.chunk_i]
            + job.carry
        )
        job.carry = 0.0
        st.running = job
        st.epoch += 1
        st.run_start = now
        push(now + quantum, 1, (st.idx, st.epoch))

    def dispatch(st: _Stage, now: float) -> None:
        """(Re)assign the stage server; possibly preempt (EDF).

        Window mode never preempts here: a busy stage stays busy until
        its chunk-completion event (`on_chunk_boundary`) fires.
        """
        nonlocal preemptions
        if not st.pool and st.running is None:
            return
        if st.running is not None:
            if window_mode or not preemptive or not st.pool:
                return
            best = min(st.pool, key=key)
            if best.abs_deadline >= st.running.abs_deadline:
                return
            if now < st.block_until:
                return  # inside a non-preemptible overhead window
            # --- preemption: drain tile + spill, then swap ---
            ov = overheads[st.idx]
            run = st.running
            done_frac = now - st.run_start
            run.remaining = max(0.0, run.remaining - done_frac) + ov.post
            st.pool.append(run)  # back to the pool, resumes later
            st.pool.remove(best)
            preemptions += 1
            if tr is not None:
                tr((now, "preempt_store", run.name,
                    st.idx, run.release, ov.pre))
                tr((now, "preempt_load", run.name,
                    st.idx, run.release, ov.post))
                tr((now, "dispatch", best.name, st.idx, best.release))
            st.running = best
            st.epoch += 1
            st.block_until = now + ov.pre
            st.run_start = now + ov.pre
            push(st.run_start + best.remaining, 1, (st.idx, st.epoch))
            return
        # idle server: pick next
        nxt = min(st.pool, key=key)
        st.pool.remove(nxt)
        if tr is not None:
            tr((now, "dispatch", nxt.name, st.idx, nxt.release))
        if window_mode:
            start_chunk(st, nxt, now)
            return
        st.running = nxt
        st.epoch += 1
        st.run_start = now
        push(now + nxt.remaining, 1, (st.idx, st.epoch))

    def on_chunk_boundary(st: _Stage, now: float) -> None:
        """Window mode completion event: one non-preemptible chunk
        finished. Either the segment is done, or this is the only point
        where an EDF preemption decision may happen — the runtime's
        tile-window boundary. A boundary preemption charges ``e_store``
        to the preemptor's start and ``e_load`` to the preempted job's
        resume (the drain already happened: the chunk ran to its end)."""
        nonlocal preemptions
        job = st.running
        assert job is not None
        chs = tasks[job.task_id].segment_chunks(job.seg_idx)
        job.chunk_i += 1
        job.remaining = max(0.0, job.remaining - chs[job.chunk_i - 1])
        if job.chunk_i >= len(chs):
            on_complete(st, now)
            return
        if preemptive and st.pool:
            best = min(st.pool, key=key)
            if best.abs_deadline < job.abs_deadline:
                ov = overheads[st.idx]
                job.carry += ov.post  # reload when it resumes
                st.pool.append(job)
                st.pool.remove(best)
                preemptions += 1
                best.carry += ov.e_store  # spill of the preempted job
                if tr is not None:
                    tr((now, "preempt_store", job.name,
                        st.idx, job.release, ov.e_store))
                    tr((now, "preempt_load", job.name,
                        st.idx, job.release, ov.post))
                    tr((now, "dispatch", best.name,
                        st.idx, best.release))
                start_chunk(st, best, now)
                return
        start_chunk(st, job, now)  # keep running: next chunk

    def on_complete(st: _Stage, now: float) -> None:
        nonlocal jobs_completed
        job = st.running
        assert job is not None
        st.running = None
        st.epoch += 1
        t_id, j_idx = job.task_id, job.idx
        seg_complete[(t_id, j_idx)][job.seg_idx] = True
        job.seg_idx += 1
        if job.seg_idx >= len(tasks[t_id].segments):
            # job fully done
            response[t_id].append(now - job.release)
            completed_releases[t_id].append(job.release)
            pending_count[t_id] -= 1
            jobs_completed += 1
            advance_completed(t_id)
            if tr is not None:
                # the bare-float payload is the absolute deadline:
                # response/tardiness/missed derive at read time (t -
                # release, t - deadline) — a dict plus the arithmetic
                # here would triple this site's cost, and a separate
                # deadline_miss event would double it for late jobs
                tr((now, "complete", job.name, st.idx, job.release,
                    job.abs_deadline))
        else:
            if tr is not None and not st.pool:
                # only the idle edge needs an explicit boundary: when
                # the pool is non-empty the same-instant dispatch of
                # the successor marks it (and closes the Chrome span)
                tr((now, "segment_end", job.name,
                    st.idx, job.release))
            try_admit(job, now)
        recheck_gated(t_id, now)
        dispatch(st, now)

    # ---- main loop ----
    release_counts = [0] * n_tasks
    for t_id, t in enumerate(tasks):
        if t.arrivals is not None:
            if t.arrivals:
                push(t.arrivals[0], 0, (t_id,))
        else:
            push(t.phase, 0, (t_id,))

    growth = False
    while evq:
        now, kind, _prio, _s, data = heapq.heappop(evq)
        if now > horizon or overload:
            break
        if kind == 0:
            (t_id,) = data
            t = tasks[t_id]
            j_idx = release_counts[t_id]
            release_counts[t_id] += 1
            # the arrival stream continues whatever this release's fate
            if t.arrivals is not None:
                if j_idx + 1 < len(t.arrivals):
                    push(t.arrivals[j_idx + 1], 0, (t_id,))
            else:
                push(now + t.period, 0, (t_id,))
            verdict = SHED_SUBMIT
            if cfg.shedding is not None:
                # refresh hysteresis for every task (pending counts
                # change between releases as jobs complete), exactly
                # like the gateway's per-release monitor sweep
                for i2 in range(n_tasks):
                    cfg.shedding.observe(i2, pending_count[i2])
                if drain_modes is not None:
                    for sw in drain_modes():
                        mode_switches.append((now, sw.mode, sw.survivors))
                        if tr is not None:
                            tr((now, "mode_switch", "", -1, None, {
                                "mode": sw.mode,
                                "survivors": sw.survivors,
                                "schedulable": sw.schedulable,
                            }))
                overloaded = tuple(
                    i2
                    for i2 in range(n_tasks)
                    if cfg.shedding.engaged.get(i2)
                )
                if overloaded:
                    verdict = cfg.shedding.classify(t_id, overloaded)
            if verdict == SHED_DROP:
                jobs_shed += 1
                shed_per_task[t_id] += 1
                if tr is not None:
                    tr((now, "shed", names[t_id],
                        t.segments[0][0], now))
                # a shed job must not deadlock the same-task gating
                # chain: mark its segments trivially complete so the
                # next job's gate sees through it
                seg_complete[(t_id, j_idx)] = [True] * len(t.segments)
                advance_completed(t_id)
                recheck_gated(t_id, now)
                continue
            jobs_released += 1
            if tr is not None:
                if verdict == SHED_BEST_EFFORT:
                    tr((now, "release", names[t_id],
                        t.segments[0][0], now, {"best_effort": True}))
                else:
                    tr((now, "release", names[t_id],
                        t.segments[0][0], now))
            deadline = (
                math.inf if verdict == SHED_BEST_EFFORT else t.deadline
            )
            if verdict == SHED_BEST_EFFORT:
                degraded_per_task[t_id] += 1
            job = _Job(t_id, j_idx, now, now + deadline)
            if tr is not None:
                job.name = names[t_id]
            seg_complete[(t_id, j_idx)] = [False] * len(t.segments)
            pending_count[t_id] += 1
            if pending_count[t_id] > cfg.backlog_limit:
                overload = True
            try_admit(job, now)
        else:
            st_idx, epoch = data
            st = stages[st_idx]
            if st.epoch != epoch or st.running is None:
                continue  # stale completion (preempted/re-dispatched)
            if window_mode:
                on_chunk_boundary(st, now)
            else:
                on_complete(st, now)

    # ---- verdict ----
    # Theory cap: with every stage utilization < 1, any work-conserving
    # policy bounds a job's response by the sum of per-stage busy
    # periods L_k <= (sum_i e_i^k) / (1 - u_k). Observed responses under
    # this cap are NOT divergence, no matter how the finite-horizon
    # halves drift (near-commensurate periods can push the first
    # collision arbitrarily late).
    # Explicit-arrival tasks use their minimum observed inter-arrival as
    # the utilization-accounting period — at most as many releases can
    # occur in any interval as a periodic task at that gap, so the cap
    # stays a valid upper bound (and degrades to inf for bursty traces
    # whose min gap saturates a stage — conservative direction).
    # Under a preemptive policy the busy-period demand must carry the
    # Eq. 4 overhead inflation: a system whose overhead-inflated
    # utilization reaches 1 can genuinely diverge even though its raw
    # u^k < 1, and a raw-WCET cap would wrongly clear the growth flag
    # for it. Instant preemption inflates by xi per stage visit; window
    # mode charges (e_store + e_load) per actual preemption event, and a
    # segment of c chunks can be preempted at most c - 1 times (only at
    # its own interior boundaries), so the per-visit inflation is
    # (e_store + e_load) * (c - 1) — e_tile is real blocking there, not
    # inserted work.
    theory_cap = 0.0
    acct_periods = [t.min_inter_arrival() for t in tasks]
    for k in range(n_stages):
        xi_k = overheads[k].xi if preemptive else 0.0
        ev_k = overheads[k].e_store + overheads[k].e_load
        e_k = []
        for t in tasks:
            raw = sum(w for st, w in t.segments if st == k)
            if not preemptive or raw <= 0.0:
                e_k.append(raw if raw > 0.0 else 0.0)
                continue
            if window_mode:
                infl = sum(
                    ev_k * (len(t.segment_chunks(si)) - 1)
                    for si, (st, _w) in enumerate(t.segments)
                    if st == k
                )
            else:
                visits = sum(1 for st, _w in t.segments if st == k)
                infl = xi_k * visits
            e_k.append(raw + infl)
        u_k = sum(
            e / p for e, p in zip(e_k, acct_periods) if p > 0.0
        )
        if u_k >= 1.0 - 1e-12 or any(
            e > 0.0 and p <= 0.0 for e, p in zip(e_k, acct_periods)
        ):
            theory_cap = math.inf
            break
        theory_cap += sum(e_k) / (1.0 - u_k)
    max_r, mean_r = [], []
    for t_id in range(n_tasks):
        r = response[t_id]
        max_r.append(max(r) if r else 0.0)
        mean_r.append(sum(r) / len(r) if r else 0.0)
        if len(r) >= 8:
            half = len(r) // 2
            mean1 = sum(r[:half]) / half
            mean2 = sum(r[half:]) / (len(r) - half)
            max1, max2 = max(r[:half]), max(r[half:])
            if (
                mean2 > mean1 * cfg.growth_tol + 1e-12
                and max2 > max1 * cfg.growth_tol + 1e-12
            ):
                growth = True
        elif release_counts[t_id] - shed_per_task[t_id] >= 8:
            # Few completions despite many releases is only divergence
            # when completions actually *lag* the releases: a finite
            # trace whose last jobs are simply cut off by the horizon
            # (explicit-arrival bursts, long tails) must not be flagged.
            # Short traces where the lag is large but under the margin
            # are inherently ambiguous (pipeline fill vs true growth);
            # this heuristic deliberately errs schedulable there and
            # leaves those to the primary detectors (backlog_limit
            # overload and, on longer traces, the two-halves test).
            # Shed jobs never entered the system, so they are not lag.
            entered = release_counts[t_id] - shed_per_task[t_id]
            lag = entered - len(r)
            if lag >= 8 and 2 * lag > entered:
                growth = True  # most released jobs never finished
    if (
        growth
        and theory_cap != math.inf
        and all(m <= theory_cap + 1e-9 for m in max_r)
    ):
        growth = False  # bounded by the busy-period cap -> not divergence
    schedulable = (not overload) and (not growth) and jobs_completed > 0
    return SimResult(
        schedulable=schedulable,
        response_times=response,
        max_response=max_r,
        mean_response=mean_r,
        preemptions=preemptions,
        jobs_released=jobs_released,
        jobs_completed=jobs_completed,
        overload_detected=overload,
        growth_detected=growth,
        completed_releases=completed_releases,
        jobs_shed=jobs_shed,
        shed_per_task=shed_per_task,
        degraded_per_task=degraded_per_task,
        mode_switches=mode_switches,
    )


def simulate_taskset(
    table,
    taskset,
    policy: str,
    horizon: float = 0.0,
    overheads: list[StageOverhead] | None = None,
    mapping_orders: list[list[int]] | None = None,
    arrivals: list[list[float] | None] | None = None,
    chunk_schedules: list[dict[int, tuple[float, ...]]] | None = None,
    preemption: str = "instant",
    shedding: ReleaseShedding | None = None,
    trace: object | None = None,
) -> SimResult:
    """Bridge from `SegmentTable`/`TaskSet` (core.rt) to the simulator.

    ``mapping_orders`` optionally gives, per task, the order in which its
    stages are visited (for non-chained TG baselines); default is
    ascending stage index (the PHAROS pipelined topology).

    ``arrivals`` optionally gives, per task, an explicit release-time
    sequence (see `SimTask.arrivals`); ``None`` entries stay periodic.

    ``chunk_schedules`` (with ``preemption="window"``) gives, per task,
    a stage -> non-preemptible chunk lengths map (e.g.
    `repro.conformance.CostModel.chunk_schedule`); stages without an
    entry run their whole segment as one chunk. Tasks that revisit a
    stage (non-chained mapping orders) cannot carry per-stage chunk
    schedules — the map would be ambiguous per visit.

    ``trace`` optionally forwards a `repro.obs.TraceRecorder` to
    `SimConfig.trace` (None: tracing off, zero events).
    """
    if arrivals is not None and len(arrivals) != len(taskset):
        raise ValueError("arrivals length != taskset size")
    if chunk_schedules is not None and len(chunk_schedules) != len(taskset):
        raise ValueError("chunk_schedules length != taskset size")
    tasks = []
    for i, t in enumerate(taskset.tasks):
        order = (
            mapping_orders[i]
            if mapping_orders is not None
            else table.active_stages(i)
        )
        segs = tuple((k, table.base[i][k]) for k in order if table.base[i][k] > 0)
        arr = arrivals[i] if arrivals is not None else None
        chunks = None
        if chunk_schedules is not None:
            sched = chunk_schedules[i]
            if len({k for k, _w in segs}) != len(segs):
                raise ValueError(
                    "per-stage chunk schedules need chained (no-revisit) "
                    "stage orders"
                )
            chunks = tuple(
                sched.get(k, (w,)) for k, w in segs
            )
        tasks.append(
            SimTask(
                segments=segs,
                period=t.period,
                deadline=t.deadline,
                name=t.name,
                arrivals=tuple(arr) if arr is not None else None,
                chunks=chunks,
            )
        )
    if overheads is None and policy == "edf":
        overheads = [
            StageOverhead(e_tile=o / 3.0, e_store=o / 3.0, e_load=o / 3.0)
            for o in table.overhead
        ]
    cfg = SimConfig(
        policy=policy,
        horizon=horizon,
        overheads=overheads,
        preemption=preemption,
        shedding=shedding,
        trace=trace,
    )
    return simulate(tasks, cfg)
