"""Mamba (selective SSM) block — jamba's mixer layer.

Chunked selective scan: the sequence is split into chunks of
``cfg.mamba_chunk``; within a chunk the diagonal affine recurrence
``h_t = a_t * h_{t-1} + b_t`` runs as an associative scan, chunks are
chained by a carried state. This mirrors the Pallas kernel's blocking
(`repro.kernels.mamba_scan`) and keeps peak memory at
``B * chunk * d_inner * d_state`` instead of the full sequence.

Decode maintains ``(conv_state, ssm_state)`` and advances one token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.module import dense_init, ones, zeros


def mamba_init(key, cfg, dtype=jnp.bfloat16):
    d, di, ns, dc = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, ns + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ns, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a),  # (di, ns) fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
        "norm": ones((d,), dtype),
    }


def _split_xproj(p, xs, cfg):
    dt_rank = p["dt_proj"].shape[0]
    ns = cfg.mamba_d_state
    proj = xs @ p["x_proj"]
    dt, B, C = jnp.split(proj, [dt_rank, dt_rank + ns], axis=-1)
    dt = jax.nn.softplus(
        (dt @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )  # (..., di)
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def _causal_conv(p, x, cfg):
    """Depthwise causal conv over time. x: (B, S, di)."""
    dc = cfg.mamba_d_conv
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(dc)
    )
    return jax.nn.silu(out + p["conv_b"])


def mamba(p, x, cfg, inner_pin=None, entry_pin=None):
    """Full-sequence mamba mixer (train). x: (B, S, d)."""
    out, _ = _mamba_impl(p, x, cfg, inner_pin, entry_pin)
    return out


def mamba_prefill(p, x, cfg, inner_pin=None, entry_pin=None):
    """Full-sequence mixer that also emits the decode cache
    ``{"conv": (B, dc-1, di), "ssm": (B, di, ns)}``."""
    return _mamba_impl(p, x, cfg, inner_pin, entry_pin)


def _mamba_impl(p, x, cfg, inner_pin=None, entry_pin=None):
    Bb, S, d = x.shape
    di, ns, ch = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_chunk
    ch = min(ch, S)
    while S % ch:
        ch //= 2
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    xz = xn @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = _causal_conv(p, xs, cfg)
    if inner_pin is not None:
        # d_inner is the TP axis of the scan: pin (B, S, di) over model
        # so the chunk workspaces and the remat stash shard with it
        xs = inner_pin(xs)
    dt, Bt, Ct = _split_xproj(p, xs, cfg)
    if inner_pin is not None:
        dt = inner_pin(dt)
    A = -jnp.exp(p["A_log"])  # (di, ns)

    # chunked diagonal scan over (di, ns)
    n_chunks = S // ch
    xs_f = xs.astype(jnp.float32)

    @jax.checkpoint
    def chunk_body(h_carry, inputs):
        dt_c, B_c, C_c, x_c = inputs  # (Bb, ch, ...)
        a = jnp.exp(dt_c[..., None] * A)  # (Bb, ch, di, ns)
        b = (dt_c * x_c)[..., None] * B_c[:, :, None, :]  # (Bb, ch, di, ns)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = bb + aa * h_carry[:, None]
        y = jnp.einsum("bcdn,bcn->bcd", h, C_c)
        return h[:, -1], y

    def to_chunks(t):
        return jnp.swapaxes(
            t.reshape(Bb, n_chunks, ch, *t.shape[2:]), 0, 1
        )  # (n_chunks, Bb, ch, ...)

    h0 = jnp.zeros((Bb, di, ns), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_body, h0, (to_chunks(dt), to_chunks(Bt), to_chunks(Ct), to_chunks(xs_f))
    )
    y = jnp.swapaxes(ys, 0, 1).reshape(Bb, S, di)
    y = y + xs_f * p["D"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    dc = cfg.mamba_d_conv
    # conv cache holds the last dc-1 *pre-conv* inputs; recover them from
    # the in_proj output (xs before _causal_conv ran) — recompute the
    # pre-conv slice cheaply from xn.
    xz_tail = rms_norm(x[:, S - (dc - 1) :], p["norm"], cfg.norm_eps) @ p["in_proj"]
    conv_cache = jnp.split(xz_tail, 2, axis=-1)[0].astype(jnp.bfloat16)
    return x + out, {"conv": conv_cache, "ssm": h_final}


def mamba_cache_init(cfg, batch: int, dtype=jnp.float32):
    di, ns, dc = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, dc - 1, di), jnp.bfloat16),
        "ssm": jnp.zeros((batch, di, ns), dtype),
    }


def mamba_decode(p, x, cfg, cache):
    """One-token decode. x: (B, 1, d)."""
    Bb = x.shape[0]
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = xn @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, 1, di)
    window = jnp.concatenate([cache["conv"], xs], axis=1)  # (B, dc, di)
    conv_out = jnp.einsum("btd,td->bd", window, p["conv_w"]) + p["conv_b"]
    xs1 = jax.nn.silu(conv_out)[:, None, :]  # (B, 1, di)
    dt, Bt, Ct = _split_xproj(p, xs1, cfg)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)  # (B, di, ns)
    b = (dt[:, 0] * xs1[:, 0].astype(jnp.float32))[..., None] * Bt[:, 0, None, :]
    h = a * cache["ssm"] + b
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0])
    y = y + xs1[:, 0].astype(jnp.float32) * p["D"]
    out = (y[:, None, :].astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    new_cache = {"conv": window[:, 1:], "ssm": h}
    return x + out, new_cache
