"""Decoder-LM assembler over `ArchConfig`.

Every assigned architecture is a stack of residual blocks; a block =
(mixer, ffn) with mixer in {attn, mamba, rwkv} and ffn in {dense, moe,
rwkv_cmix}. The stack repeats `cfg.pattern()` `cfg.n_repeats` times;
parameters (and decode caches) carry a leading repeats axis and the
stack executes under ``jax.lax.scan`` so the HLO contains each distinct
layer once — this keeps 512-device dry-run compiles tractable and is
what activation-checkpointing wraps (one remat boundary per repeat).

Entry points
------------
- ``init_params(key, cfg)``            parameter pytree
- ``forward(params, cfg, batch)``      logits (B, S, V), train/eval
- ``loss_fn(params, cfg, batch)``      (loss, metrics), S-chunked CE
- ``init_cache(cfg, B, cache_len)``    decode cache pytree
- ``prefill(params, cfg, batch, L)``   (last-token logits, cache)
- ``decode_step(params, cfg, cache, inputs, pos)`` one-token serve step

Inputs: ``batch["tokens"]`` (B, S) int32 for token models, or
``batch["embeds"]`` (B, S, frontend_dim) for the VLM/audio stub
frontends (the modality encoder is out of scope per the assignment —
`input_specs` provides precomputed patch/frame embeddings).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.module import dense_init, embed_init, ones, stack_init


# ---------------------------------------------------------------------------
# activation sharding policy
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingPolicy:
    """Activation sharding constraints pinned inside the model.

    GSPMD propagation from parameter/batch shardings alone can re-shard
    the contraction dim instead of the batch (replicating activations
    and turning FSDP all-gathers into giant partial-sum all-reduces —
    observed on the 16x16 dry-run before these pins existed). Pinning
    the block carry and the CE logits keeps the batch axis on ``data``
    throughout, which is the FSDP/TP schedule the roofline assumes.

    ``act``: NamedSharding for (B, S, d) activations; ``logits``: for
    (B, chunk, vocab) CE chunks. ``None`` leaves XLA free (single-host
    tests, serving paths that shard differently).

    ``moe_groups``/``moe_dispatch`` drive the GShard-style capacity MoE:
    groups = number of data shards (routing stays shard-local), dispatch
    = NamedSharding of the (G, E, C, d) expert-parallel layout.
    """

    act: Any = None
    logits: Any = None
    moe_groups: int = 1
    moe_dispatch: Any = None
    #: (B, S, H, hd) pin after q/k/v projections — forces the Megatron
    #: head-parallel attention schedule (all-gather S at entry when the
    #: boundary is sequence-parallel, heads over `model` inside).
    heads: Any = None
    #: (B, S, C) channel pin for recurrent mixers: the mamba scan is
    #: elementwise over d_inner and the rwkv scan independent per head,
    #: so their (B, chunk, channels, state) workspaces shard over
    #: `model` — without this pin the inner-scan stashes replicate
    #: (observed: jamba train at 123 GB/device).
    channels: Any = None
    #: (B, S, d) entry pin with S *gathered* (batch-only sharding):
    #: applied to the normed input right before the big projections, so
    #: the matmuls consume data-sharded weights (FSDP gathers of the
    #: small per-device shard) instead of GSPMD's fallback of gathering
    #: the whole weight to every device in fp32.
    gathered: Any = None

    def pin_act(self, x):
        if self.act is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.act)

    def pin_logits(self, x):
        if self.logits is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.logits)

    def pin_heads(self, x):
        if self.heads is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.heads)

    def pin_channels(self, x):
        if self.channels is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.channels)

    def pin_gathered(self, x):
        if self.gathered is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.gathered)


NO_POLICY = ShardingPolicy()

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
_MIXER_INIT = {
    "attn": L.attn_init,
    "mamba": S.mamba_init,
    "rwkv": R.rwkv_tmix_init,
}
_FFN_INIT = {
    "dense": L.mlp_init,
    "moe": L.moe_init,
    "rwkv_cmix": R.rwkv_cmix_init,
}


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    pattern = cfg.pattern()
    n_rep = cfg.n_repeats
    keys = jax.random.split(key, len(pattern) + 3)

    blocks = []
    for j, (mixer, ffn) in enumerate(pattern):
        km, kf = jax.random.split(keys[j])
        blocks.append(
            {
                "mixer": stack_init(
                    partial(_MIXER_INIT[mixer], cfg=cfg, dtype=dtype), km, n_rep
                ),
                "ffn": stack_init(
                    partial(_FFN_INIT[ffn], cfg=cfg, dtype=dtype), kf, n_rep
                ),
            }
        )

    params = {"blocks": tuple(blocks), "final_norm": ones((cfg.d_model,), dtype)}
    if cfg.frontend == "none":
        params["embed"] = embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                keys[-2], cfg.d_model, cfg.vocab, dtype
            )
    else:
        params["frontend_proj"] = dense_init(
            keys[-3], cfg.frontend_dim, cfg.d_model, dtype
        )
        params["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab, dtype)
    return params


# ---------------------------------------------------------------------------
# block dispatch
# ---------------------------------------------------------------------------
def _apply_block(kind, pm, pf, x, cfg, positions, policy):
    mixer, ffn = kind
    if mixer == "attn":
        x = L.attention(pm, x, cfg, positions, head_pin=policy.pin_heads,
                        entry_pin=policy.pin_gathered)
    elif mixer == "mamba":
        x = S.mamba(pm, x, cfg, inner_pin=policy.pin_channels,
                    entry_pin=policy.pin_gathered)
    else:
        x = R.rwkv_tmix(pm, x, cfg, head_pin=policy.pin_heads,
                        entry_pin=policy.pin_gathered)
    if ffn == "dense":
        x = L.mlp(pf, x, cfg, hidden_pin=policy.pin_channels,
                  entry_pin=policy.pin_gathered)
    elif ffn == "moe":
        # SPMD path: GShard capacity MoE (partitions); host path (no
        # dispatch sharding): exact dropless sort-based MoE.
        if policy.moe_dispatch is None:
            x = L.moe_dropless(pf, x, cfg)
        else:
            x = L.moe_capacity(
                pf, x, cfg,
                groups=policy.moe_groups,
                dispatch_sharding=policy.moe_dispatch,
            )
    else:
        x = R.rwkv_cmix(pf, x, cfg, entry_pin=policy.pin_gathered)
    return x


def _apply_block_prefill(kind, pm, pf, x, cfg, positions, cache_len, policy):
    mixer, ffn = kind
    if mixer == "attn":
        x, cache = L.attention_prefill(
            pm, x, cfg, positions, cache_len, head_pin=policy.pin_heads,
            entry_pin=policy.pin_gathered,
        )
    elif mixer == "mamba":
        x, cache = S.mamba_prefill(pm, x, cfg, inner_pin=policy.pin_channels,
                                   entry_pin=policy.pin_gathered)
    else:
        x, cache = R.rwkv_tmix_prefill(pm, x, cfg, head_pin=policy.pin_heads,
                                       entry_pin=policy.pin_gathered)
    if ffn == "dense":
        x = L.mlp(pf, x, cfg, hidden_pin=policy.pin_channels,
                  entry_pin=policy.pin_gathered)
    elif ffn == "moe":
        # SPMD path: GShard capacity MoE (partitions); host path (no
        # dispatch sharding): exact dropless sort-based MoE.
        if policy.moe_dispatch is None:
            x = L.moe_dropless(pf, x, cfg)
        else:
            x = L.moe_capacity(
                pf, x, cfg,
                groups=policy.moe_groups,
                dispatch_sharding=policy.moe_dispatch,
            )
    else:
        x, cmix_last = R.rwkv_cmix_prefill(pf, x, cfg)
        cache = dict(cache, cmix_last=cmix_last)
    return x, cache


def _apply_block_decode(kind, pm, pf, x, cfg, cache, pos, policy,
                        kv_quant=False):
    mixer, ffn = kind
    if mixer == "attn":
        if kv_quant:
            x, cache = L.attention_decode_q8(pm, x, cfg, cache, pos)
        else:
            x, cache = L.attention_decode(pm, x, cfg, cache, pos)
    elif mixer == "mamba":
        x, cache = S.mamba_decode(pm, x, cfg, cache)
    else:
        x, cache = R.rwkv_tmix_decode(pm, x, cfg, cache)
    if ffn == "dense":
        x = L.mlp(pf, x, cfg, hidden_pin=policy.pin_channels,
                  entry_pin=policy.pin_gathered)
    elif ffn == "moe":
        # SPMD path: GShard capacity MoE (partitions); host path (no
        # dispatch sharding): exact dropless sort-based MoE.
        if policy.moe_dispatch is None:
            x = L.moe_dropless(pf, x, cfg)
        else:
            x = L.moe_capacity(
                pf, x, cfg,
                groups=policy.moe_groups,
                dispatch_sharding=policy.moe_dispatch,
            )
    else:
        x, cache = R.rwkv_cmix_decode(pf, x, cfg, cache)
    return x, cache


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ArchConfig, batch):
    if cfg.frontend == "none":
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"].astype(params["frontend_proj"].dtype) @ params[
            "frontend_proj"
        ]
    return x


def _head(params, cfg: ArchConfig):
    if cfg.frontend == "none" and cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _positions(x):
    B, Sq = x.shape[:2]
    return jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def backbone(
    params,
    cfg: ArchConfig,
    batch,
    *,
    remat: bool = True,
    policy: ShardingPolicy = NO_POLICY,
):
    """Embed -> scan(pattern x repeats) -> final norm. Returns (B,S,d)."""
    x = policy.pin_act(_embed_inputs(params, cfg, batch))
    positions = _positions(x)
    pattern = cfg.pattern()

    def body(x, rep):
        for j, kind in enumerate(pattern):
            x = _apply_block(kind, rep[j]["mixer"], rep[j]["ffn"], x, cfg, positions, policy)
            x = policy.pin_act(x)
        return x, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward(
    params,
    cfg: ArchConfig,
    batch,
    *,
    remat: bool = True,
    policy: ShardingPolicy = NO_POLICY,
):
    """Full logits (B, S, V) — use for smoke tests / small models only;
    training uses `loss_fn` (never materializes all logits at once)."""
    x = backbone(params, cfg, batch, remat=remat, policy=policy)
    return (x @ _head(params, cfg)).astype(jnp.float32)


#: sequence-chunk length for the cross-entropy scan: bounds live logits
#: memory at (B, CE_CHUNK, V) fp32 per device group.
CE_CHUNK = 512


def loss_fn(
    params,
    cfg: ArchConfig,
    batch,
    *,
    remat: bool = True,
    policy: ShardingPolicy = NO_POLICY,
):
    """Mean next-token cross entropy with S-chunked logits.

    ``batch["labels"]`` (B, S) int32; optional ``batch["mask"]`` (B, S)
    weights (defaults to all-ones). Labels are already shifted by the
    data pipeline (labels[t] = target for position t).
    """
    x = backbone(params, cfg, batch, remat=remat, policy=policy)
    head = _head(params, cfg)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    B, Sq, d = x.shape

    chunk = min(CE_CHUNK, Sq)
    while Sq % chunk:
        chunk //= 2
    n_chunks = Sq // chunk

    def ce(x_c, lab_c, m_c):
        logits = policy.pin_logits((x_c @ head).astype(jnp.float32))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab_c[..., None], axis=-1)[..., 0]
        return ((lse - gold) * m_c).sum()

    ce = jax.checkpoint(ce)

    def body(acc, inp):
        x_c, lab_c, m_c = inp
        return acc + ce(x_c, lab_c, m_c), None

    xs = (
        jnp.moveaxis(x.reshape(B, n_chunks, chunk, d), 1, 0),
        jnp.moveaxis(labels.reshape(B, n_chunks, chunk), 1, 0),
        jnp.moveaxis(mask.reshape(B, n_chunks, chunk), 1, 0),
    )
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = total / denom
    return loss, {"loss": loss, "tokens": denom}


# ---------------------------------------------------------------------------
# decode cache
# ---------------------------------------------------------------------------
def _block_cache_shape(kind, cfg: ArchConfig, B: int, cache_len: int,
                       kv_quant: bool = False):
    mixer, ffn = kind
    if mixer == "attn":
        if kv_quant:
            return {
                "k": ((B, cfg.n_kv_heads, cache_len, cfg.head_dim), jnp.int8),
                "v": ((B, cfg.n_kv_heads, cache_len, cfg.head_dim), jnp.int8),
                "k_scale": ((B, cfg.n_kv_heads, cache_len), jnp.bfloat16),
                "v_scale": ((B, cfg.n_kv_heads, cache_len), jnp.bfloat16),
            }
        return {
            "k": ((B, cfg.n_kv_heads, cache_len, cfg.head_dim), jnp.bfloat16),
            "v": ((B, cfg.n_kv_heads, cache_len, cfg.head_dim), jnp.bfloat16),
        }
    if mixer == "mamba":
        return {
            "conv": ((B, cfg.mamba_d_conv - 1, cfg.d_inner), jnp.bfloat16),
            "ssm": ((B, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
        }
    # rwkv
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    return {
        "S": ((B, H, hd, hd), jnp.float32),
        "tmix_last": ((B, cfg.d_model), jnp.bfloat16),
        "cmix_last": ((B, cfg.d_model), jnp.bfloat16),
    }


def cache_spec(cfg: ArchConfig, B: int, cache_len: int,
               kv_quant: bool = False):
    """(shape, dtype) pytree of the decode cache (leading repeats axis)."""
    n_rep = cfg.n_repeats
    out = []
    for kind in cfg.pattern():
        shapes = _block_cache_shape(kind, cfg, B, cache_len, kv_quant)
        out.append(
            {k: ((n_rep, *shp), dt) for k, (shp, dt) in shapes.items()}
        )
    return tuple(out)


def init_cache(cfg: ArchConfig, B: int, cache_len: int):
    return jax.tree_util.tree_map(
        lambda sd: jnp.zeros(*sd),
        cache_spec(cfg, B, cache_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------
def prefill(
    params,
    cfg: ArchConfig,
    batch,
    cache_len: int,
    *,
    remat: bool = True,
    policy: ShardingPolicy = NO_POLICY,
):
    """Run the full prompt; return (last-token logits (B,V), cache)."""
    x = policy.pin_act(_embed_inputs(params, cfg, batch))
    positions = _positions(x)
    pattern = cfg.pattern()

    def body(x, rep):
        caches = []
        for j, kind in enumerate(pattern):
            x, c = _apply_block_prefill(
                kind, rep[j]["mixer"], rep[j]["ffn"], x, cfg, positions,
                cache_len, policy,
            )
            x = policy.pin_act(x)
            caches.append(c)
        return x, tuple(caches)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ _head(params, cfg)).astype(jnp.float32)
    return logits, cache


def decode_step(
    params,
    cfg: ArchConfig,
    cache,
    inputs,
    pos,
    *,
    policy: ShardingPolicy = NO_POLICY,
    kv_quant: bool = False,
):
    """One new token for every sequence in the batch.

    ``inputs``: {"tokens": (B,) int32} or {"embeds": (B, frontend_dim)};
    ``pos``: (B,) int32 — index the new token is written at (= current
    sequence length). Returns (logits (B, V), new_cache).
    """
    if cfg.frontend == "none":
        x = params["embed"][inputs["tokens"]][:, None, :]
    else:
        x = (
            inputs["embeds"].astype(params["frontend_proj"].dtype)
            @ params["frontend_proj"]
        )[:, None, :]
    x = policy.pin_act(x)
    pattern = cfg.pattern()

    def body(x, rep_and_cache):
        rep, cache_rep = rep_and_cache
        new = []
        for j, kind in enumerate(pattern):
            x, c = _apply_block_decode(
                kind, rep[j]["mixer"], rep[j]["ffn"], x, cfg, cache_rep[j],
                pos, policy, kv_quant=kv_quant,
            )
            x = policy.pin_act(x)
            new.append(c)
        return x, tuple(new)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ _head(params, cfg)).astype(jnp.float32)
    return logits, new_cache
