"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (hd = head size), per key-channel ``i``:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with data-dependent decay ``w_t = exp(-exp(logit_t))`` produced by a
low-rank projection of the shifted input (the RWKV6 novelty vs RWKV5).

Implementation is chunked (GLA-style): within a chunk, cumulative decay
products turn the recurrence into two GEMMs (intra-chunk lower-tri
attention-like product + inter-chunk carry), matching the Pallas kernel
`repro.kernels.rwkv6_scan`. Decay logits are clamped so cumulative
ratios stay in fp32 range for the configured chunk length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.module import dense_init, ones, zeros

_DECAY_CLAMP = (-8.0, -1.0)  # log-logit clamp: decay in ~[exp(-0.37), 1)
_LORA_RANK = 64


def rwkv_tmix_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    return {
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # data-dependent decay, low-rank
        "w_lora_a": dense_init(ks[5], d, _LORA_RANK, dtype),
        "w_lora_b": dense_init(ks[6], _LORA_RANK, d, dtype),
        "w0": jnp.full((d,), -5.0, jnp.float32),
        "u": (jax.random.normal(ks[7], (H, hd), jnp.float32) * 0.1),
        "ln_x": ones((d,), dtype),
        "norm": ones((d,), dtype),
    }


def rwkv_cmix_init(key, cfg, dtype=jnp.bfloat16):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
        "norm": ones((d,), dtype),
    }


def _shift(x, last=None):
    """Token shift; `last` (B, d) is the previous block-input token."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _decay(p, xw):
    logit = p["w0"] + (
        jax.nn.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    ).astype(jnp.float32)
    logit = jnp.clip(logit, *_DECAY_CLAMP)
    return jnp.exp(-jnp.exp(logit))  # in (0, 1)


def _tmix_inputs(p, xn, cfg, last=None):
    sx = _shift(xn, last) - xn
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    B, S, d = xn.shape
    r = ((xn + sx * p["mix_r"]) @ p["wr"]).reshape(B, S, H, hd)
    k = ((xn + sx * p["mix_k"]) @ p["wk"]).reshape(B, S, H, hd)
    v = ((xn + sx * p["mix_v"]) @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu((xn + sx * p["mix_g"]) @ p["wg"])
    w = _decay(p, xn + sx * p["mix_w"]).reshape(B, S, H, hd)
    return r, k, v, g, w


def rwkv_tmix(p, x, cfg, chunk: int = 64, head_pin=None, entry_pin=None):
    """Full-sequence time-mix. x: (B, S, d)."""
    out, _ = _tmix_impl(p, x, cfg, chunk, head_pin, entry_pin)
    return out


def rwkv_tmix_prefill(p, x, cfg, chunk: int = 64, head_pin=None,
                      entry_pin=None):
    """Time-mix that also emits the decode state
    ``{"S": (B,H,hd,hd), "tmix_last": (B,d)}``."""
    return _tmix_impl(p, x, cfg, chunk, head_pin, entry_pin)


def _tmix_impl(p, x, cfg, chunk: int = 64, head_pin=None, entry_pin=None):
    B, S, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    r, k, v, g, w = _tmix_inputs(p, xn, cfg)
    if head_pin is not None:
        # heads are independent in the WKV recurrence: pin (B,S,H,hd)
        # over model so per-chunk workspaces and stashes shard
        r, k, v, w = head_pin(r), head_pin(k), head_pin(v), head_pin(w)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = p["u"]

    n_chunks = S // chunk

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape(B, n_chunks, chunk, H, hd), 1, 0
        )  # (n_chunks, B, chunk, H, hd)

    @jax.checkpoint
    def chunk_body(S_carry, inputs):
        rc, kc, vc, wc = inputs  # (B, C, H, hd)
        logw = jnp.log(wc)
        cumw = jnp.cumsum(logw, axis=1)  # log prod_{s<=t} w_s
        Wt = jnp.exp(cumw)  # (B, C, H, hd)
        # inter-chunk: r_t . diag(W_{t-1}-style) @ S_carry ; note S update
        # uses decay *before* position t: prod_{s<=t-1}. w_t applies to
        # S_{t-1}, so the carry seen at t has decay prod_{s<=t} ... the
        # standard form: y_t uses S_{t-1}; S_{t-1} = diag(prod_{s<=t-1} w)
        # S_in + intra terms. We therefore use W shifted right by one.
        Wt_prev = jnp.exp(cumw - logw)  # prod_{s<=t-1}
        y_inter = jnp.einsum("bchd,bhde->bche", rc * Wt_prev, S_carry)
        # intra-chunk, strict lower triangle
        rw = rc * Wt_prev  # (B, C, H, hd)
        kw = kc / jnp.maximum(Wt, 1e-30)  # k_j / prod_{s<=j} w_s
        att = jnp.einsum("bchd,bjhd->bhcj", rw, kw)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcj,bjhe->bche", att, vc)
        # diagonal bonus term
        diag = jnp.einsum("bchd,hd,bchd->bch", rc, u, kc)
        y_diag = diag[..., None] * vc
        # carry update: S_out = diag(prod_all w) S_in + sum_j diag(prod_{s>j} w) k_j v_j^T
        Wtot = jnp.exp(cumw[:, -1])  # (B, H, hd)
        kscale = kc * jnp.exp(cumw[:, -1][:, None] - cumw)  # prod_{s>j} w_s
        S_new = Wtot[..., None] * S_carry + jnp.einsum(
            "bjhd,bjhe->bhde", kscale, vc
        )
        return S_new, y_inter + y_intra + y_diag

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_final, ys = jax.lax.scan(
        chunk_body, S0, (to_chunks(rf), to_chunks(kf), to_chunks(vf), to_chunks(wf))
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = x + (y * g) @ p["wo"]
    return out, {"S": S_final, "tmix_last": xn[:, -1].astype(jnp.bfloat16)}


def rwkv_cmix_prefill(p, x, cfg):
    """Channel-mix that also emits ``cmix_last`` (B, d)."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    out = rwkv_cmix(p, x, cfg)
    return out, xn[:, -1].astype(jnp.bfloat16)


def rwkv_cmix(p, x, cfg, last=None, entry_pin=None):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    sx = _shift(xn, last) - xn
    kin = (xn + sx * p["mix_k"]) @ p["wk"]
    rin = jax.nn.sigmoid((xn + sx * p["mix_r"]) @ p["wr"])
    hmid = jnp.square(jax.nn.relu(kin))
    return x + rin * (hmid @ p["wv"])


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------
def rwkv_cache_init(cfg, batch: int):
    H, hd, d = cfg.n_rwkv_heads, cfg.rwkv_head_size, cfg.d_model
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "tmix_last": jnp.zeros((batch, d), jnp.bfloat16),
        "cmix_last": jnp.zeros((batch, d), jnp.bfloat16),
    }


def rwkv_tmix_decode(p, x, cfg, cache):
    """x: (B, 1, d)."""
    B = x.shape[0]
    H, hd, d = cfg.n_rwkv_heads, cfg.rwkv_head_size, cfg.d_model
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    r, k, v, g, w = _tmix_inputs(p, xn, cfg, last=cache["tmix_last"])
    rf, kf, vf, wf = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    S = cache["S"]  # (B, H, hd, hd)
    y = jnp.einsum("bhd,bhde->bhe", rf, S) + jnp.einsum(
        "bhd,hd,bhd,bhe->bhe", rf, p["u"], kf, vf
    )
    S_new = wf[..., None] * S + jnp.einsum("bhd,bhe->bhde", kf, vf)
    y = y.reshape(B, 1, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = x + (y * g) @ p["wo"]
    new_cache = dict(cache, S=S_new, tmix_last=xn[:, 0])
    return out, new_cache


def rwkv_cmix_decode(p, x, cfg, cache):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    out = rwkv_cmix(p, x, cfg, last=cache["cmix_last"])
    return out, dict(cache, cmix_last=xn[:, 0])
