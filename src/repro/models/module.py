"""Minimal pure-JAX parameter/module utilities (no flax/haiku).

Parameters are nested dicts of jnp arrays. Initializers take an explicit
key; layer stacks are built by vmapping init over a leading repeat axis
so `lax.scan` can drive them (one compiled instance per distinct layer).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float | None = None):
    """Truncated-normal fan-in init."""
    std = scale if scale is not None else (1.0 / np.sqrt(d_in))
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
    return (w * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    w = jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)
    return (w * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype)


def stack_init(
    init_fn: Callable, key, n: int
):
    """Initialize ``n`` copies of a sub-tree with a leading stack axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
