"""Transformer building blocks: RMSNorm, RoPE, GQA attention, MLP, MoE.

Conventions
-----------
- activations ``(B, S, d)`` bf16; reductions (norms, softmax, router)
  in fp32.
- attention is causal; decode path consumes a KV cache and one new
  token per sequence (``q_len == 1``).
- MoE is sort-based dropless: per top-k slot, tokens are permuted into
  expert order and pushed through ``jax.lax.ragged_dot`` (grouped GEMM),
  so FLOPs scale with *active* parameters, and the expert dimension
  never materializes a (tokens, experts, capacity) dispatch tensor.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.module import dense_init, ones, zeros


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA)
# ---------------------------------------------------------------------------
def attn_init(key, cfg, dtype=jnp.bfloat16):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
        "norm": ones((d,), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((h * hd,), dtype)
        p["bk"] = zeros((kv * hd,), dtype)
        p["bv"] = zeros((kv * hd,), dtype)
    return p


def _qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q.reshape(B, S, h, hd), positions, cfg.rope_theta)
    k = apply_rope(k.reshape(B, S, kv, hd), positions, cfg.rope_theta)
    v = v.reshape(B, S, kv, hd)
    return q, k, v


#: above this sequence length the causal attention switches to the
#: q-chunked (flash-style) path so scores never materialize (S, S).
ATTN_CHUNK = 1024


def _expand_kv(t, groups: int):
    """(B, S, kv, hd) -> (B, S, kv*groups, hd) by head repetition."""
    if groups == 1:
        return t
    B, S, kv, hd = t.shape
    return jnp.broadcast_to(
        t[:, :, :, None, :], (B, S, kv, groups, hd)
    ).reshape(B, S, kv * groups, hd)


def _attn_full(q, k, v, positions, scale):
    """Materialized causal attention (short sequences). q/k/v: (B,S,h,hd)."""
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    causal = positions[:, None, :, None] >= positions[:, None, None, :]
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def _attn_chunked(q, k, v, positions, scale, chunk: int):
    """Flash-style attention: scan over query chunks, keys stay whole.

    Per-step live memory is (B, h, chunk, S) instead of (B, h, S, S);
    the Pallas flash kernel (`repro.kernels.flash_attention`) is the TPU
    realization of the same blocking. The chunk body is remat'd so the
    backward pass recomputes the fp32 score tile instead of stashing
    (n_chunks, B, h, chunk, S) — the score stash, not the weights, is
    what blows past HBM at 32k prefill otherwise.
    """
    B, S, h, hd = q.shape
    n_chunks = S // chunk

    qc = jnp.moveaxis(q.reshape(B, n_chunks, chunk, h, hd), 1, 0)
    pc = jnp.moveaxis(positions.reshape(B, n_chunks, chunk), 1, 0)

    @jax.checkpoint
    def body(_, inp):
        q_i, p_i = inp  # (B, chunk, h, hd), (B, chunk)
        s = jnp.einsum("bqhd,bshd->bhqs", q_i, k).astype(jnp.float32) * scale
        causal = p_i[:, None, :, None] >= positions[:, None, None, :]
        s = jnp.where(causal, s, -1e30)
        o = jnp.einsum(
            "bhqs,bshd->bqhd", jax.nn.softmax(s, axis=-1).astype(q.dtype), v
        )
        return None, o

    _, out = jax.lax.scan(body, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, h, hd)


def attention(p, x, cfg, positions, head_pin=None, entry_pin=None):
    """Causal self-attention over the full sequence (train/prefill)."""
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    q, k, v = _qkv(p, xn, cfg, positions)
    k = _expand_kv(k, h // kv)
    v = _expand_kv(v, h // kv)
    if head_pin is not None:
        q, k, v = head_pin(q), head_pin(k), head_pin(v)
    scale = hd**-0.5
    if S <= ATTN_CHUNK:
        out = _attn_full(q, k, v, positions, scale)
    else:
        chunk = ATTN_CHUNK
        while S % chunk:  # degrade gracefully for odd smoke shapes
            chunk //= 2
        out = _attn_chunked(q, k, v, positions, scale, chunk)
    out = out.reshape(B, S, h * hd)
    return x + out @ p["wo"]


def attention_prefill(p, x, cfg, positions, cache_len: int, head_pin=None,
                      entry_pin=None):
    """Full-sequence attention that also emits the KV cache.

    Returns (out, {"k","v"}) with cache layout (B, kv, cache_len, hd),
    zero-padded past S — ready for `attention_decode` to append to.
    """
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    q, k, v = _qkv(p, xn, cfg, positions)
    ke = _expand_kv(k, h // kv)
    ve = _expand_kv(v, h // kv)
    if head_pin is not None:
        q, ke, ve = head_pin(q), head_pin(ke), head_pin(ve)
    scale = hd**-0.5
    if S <= ATTN_CHUNK:
        out = _attn_full(q, ke, ve, positions, scale)
    else:
        chunk = ATTN_CHUNK
        while S % chunk:
            chunk //= 2
        out = _attn_chunked(q, ke, ve, positions, scale, chunk)
    out = out.reshape(B, S, h * hd)
    pad = ((0, 0), (0, 0), (0, cache_len - S), (0, 0))
    cache = {
        "k": jnp.pad(jnp.swapaxes(k, 1, 2), pad),
        "v": jnp.pad(jnp.swapaxes(v, 1, 2), pad),
    }
    return x + out @ p["wo"], cache


def quantize_kv(k, axis=-1):
    """Symmetric int8 over ``axis``; returns (q8, scale)."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=axis) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(k.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attention_decode_q8(p, x, cfg, cache, pos):
    """Int8-KV decode step (serving perf variant).

    cache: {"k","v": int8 (B, kv, S, hd), "k_scale","v_scale": bf16
    (B, kv, S)} — per-(token, head) symmetric scales. Halves both the
    KV HBM footprint and the decode sweep bytes vs bf16; the dequant
    fuses into the attention einsum stream.
    """
    B, _, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k_new, v_new = _qkv(p, xn, cfg, pos[:, None])
    kq, ks = quantize_kv(jnp.swapaxes(k_new, 1, 2))  # (B, kv, 1, hd)
    vq, vs = quantize_kv(jnp.swapaxes(v_new, 1, 2))
    S_max = cache["k"].shape[2]
    onehot8 = jax.nn.one_hot(pos, S_max, dtype=jnp.int8)  # (B, S)
    onehot_s = jax.nn.one_hot(pos, S_max, dtype=jnp.bfloat16)
    k_upd = cache["k"] + onehot8[:, None, :, None] * kq
    v_upd = cache["v"] + onehot8[:, None, :, None] * vq
    ks_upd = cache["k_scale"] + onehot_s[:, None, :] * ks
    vs_upd = cache["v_scale"] + onehot_s[:, None, :] * vs
    groups = h // kv
    qr = q.reshape(B, kv, groups, hd)
    # scales are per (token, head), so they commute with the hd/S
    # contractions: apply them to the 1-D score/prob side instead of
    # dequantizing the full cache (no (B,kv,S,hd) fp32 buffer exists)
    scores = jnp.einsum(
        "bkgh,bksh->bkgs",
        qr.astype(jnp.bfloat16),
        k_upd.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    scores = scores * ks_upd.astype(jnp.float32)[:, :, None, :]
    scores *= hd**-0.5
    valid = jnp.arange(S_max)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * vs_upd.astype(jnp.float32)[:, :, None, :]
    out = jnp.einsum(
        "bkgs,bksh->bkgh",
        probs.astype(jnp.bfloat16),
        v_upd.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(B, 1, h * hd)
    new_cache = {
        "k": k_upd, "v": v_upd, "k_scale": ks_upd, "v_scale": vs_upd,
    }
    return x + out @ p["wo"], new_cache


def attention_decode(p, x, cfg, cache, pos):
    """One-token decode. cache: {'k','v': (B, kv, S_max, hd)}, pos (B,)."""
    B, _, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k_new, v_new = _qkv(p, xn, cfg, pos[:, None])
    # write the new kv at position `pos` (dynamic per-batch index)
    S_max = cache["k"].shape[2]
    onehot = jax.nn.one_hot(pos, S_max, dtype=cache["k"].dtype)  # (B, S_max)
    k_upd = cache["k"] + onehot[:, None, :, None] * jnp.swapaxes(k_new, 1, 2)
    v_upd = cache["v"] + onehot[:, None, :, None] * jnp.swapaxes(v_new, 1, 2)
    groups = h // kv
    q = q.reshape(B, kv, groups, hd)  # q_len == 1 squeezed
    scores = jnp.einsum("bkgh,bksh->bkgs", q, k_upd).astype(jnp.float32)
    scores *= hd**-0.5
    valid = jnp.arange(S_max)[None, :] <= pos[:, None]  # (B, S_max)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", probs, v_upd).reshape(B, 1, h * hd)
    return x + out @ p["wo"], {"k": k_upd, "v": v_upd}


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg, dtype=jnp.bfloat16):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, f, dtype),
        "w_out": dense_init(ks[1], f, d, dtype),
        "norm": ones((d,), dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp(p, x, cfg, hidden_pin=None, entry_pin=None):
    """``hidden_pin`` pins (B, S, f) with f over `model`, forcing the
    Megatron column/row-parallel schedule. Without it, GSPMD facing
    sequence-parallel activations gathers the *weights* to fully
    replicated per layer instead (observed: fp32 full-(d,f) all-gathers
    plus fp32 full weight-grad all-reduces per layer per microbatch)."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if entry_pin is not None:
        xn = entry_pin(xn)
    if cfg.mlp_type == "swiglu":
        gate = xn @ p["w_gate"]
        up = xn @ p["w_in"]
        if hidden_pin is not None:
            gate, up = hidden_pin(gate), hidden_pin(up)
        hmid = jax.nn.silu(gate) * up
    else:
        up = xn @ p["w_in"]
        if hidden_pin is not None:
            up = hidden_pin(up)
        hmid = jax.nn.gelu(up)
    return x + hmid @ p["w_out"]


# ---------------------------------------------------------------------------
# MoE (sort + ragged_dot, dropless)
# ---------------------------------------------------------------------------
def moe_init(key, cfg, dtype=jnp.bfloat16):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    e_store = max(e, cfg.expert_pad_to or 0)
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)

    def expert_mat(k, d_in, d_out):
        w = jax.random.truncated_normal(
            k, -2.0, 2.0, (e_store, d_in, d_out), jnp.float32
        )
        return (w / jnp.sqrt(d_in)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_in": expert_mat(ks[1], d, f),
        "w_out": expert_mat(ks[2], f, d),
        "norm": ones((d,), dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = expert_mat(ks[3], d, f)
    del scale
    return p


def moe_capacity(p, x, cfg, *, groups: int = 1, dispatch_sharding=None):
    """GShard-style grouped capacity MoE — the SPMD production path.

    Tokens are viewed as ``(G, T_g, d)`` where ``G`` equals the number
    of data shards, so *all* routing ops (top-k selection, gathers,
    position-in-expert bookkeeping) are shard-local; the only cross-
    device movement is the dispatch pin to the expert-parallel layout
    ``(G/data, E/model, C, d)`` — GSPMD lowers it to the canonical EP
    all-to-all pair around the expert GEMMs.

    Per expert, the top-``C`` tokens by gate survive (``C = ceil(T_g *
    top_k * capacity_factor / E)``); overflow tokens are dropped for
    that expert (keeping their residual path) — standard GShard/Switch
    semantics. With a generous capacity factor nothing drops and the
    result matches `moe_dropless` exactly (tested).
    """
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    T = B * S
    if T % groups:
        raise ValueError(f"tokens {T} not divisible by moe groups {groups}")
    tg = T // groups
    cap = min(tg, -(-tg * k * int(100 * cfg.capacity_factor) // (e * 100)))

    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    xg = xn.reshape(groups, tg, d)
    logits = xg.astype(jnp.float32) @ p["router"]  # (G, T_g, E)
    gates, experts = jax.lax.top_k(logits, k)  # (G, T_g, k)
    gates = jax.nn.softmax(gates, axis=-1)

    # per-token score for each expert (its gate if selected, else 0)
    scores = jnp.zeros((groups, tg, e), jnp.float32)
    for slot in range(k):
        scores = jnp.maximum(
            scores,
            jax.nn.one_hot(experts[:, :, slot], e, dtype=jnp.float32)
            * gates[:, :, slot : slot + 1],
        )

    # per-expert capacity selection (local to each group)
    top_scores, top_idx = jax.lax.top_k(
        jnp.swapaxes(scores, 1, 2), cap
    )  # (G, E, C): token indices into T_g
    e_store = p["w_in"].shape[0]
    if e_store > e:  # padded experts: zero rows, never selected
        padding = ((0, 0), (0, e_store - e), (0, 0))
        top_scores = jnp.pad(top_scores, padding)
        top_idx = jnp.pad(top_idx, padding)
        e = e_store
    sel_valid = top_scores > 0.0

    # dispatch gather: (G, E, C, d), then pin to the EP layout.
    # (A broadcast-batched (G,E,T,d) operand was tried to give the VJP
    # scatter a batch dim — refuted: GSPMD gathered the broadcast itself
    # per layer (dbrx +0.8 TB/step); see EXPERIMENTS.md §Perf cell 2.)
    sel = jnp.take_along_axis(
        xg[:, None], top_idx[..., None], axis=2
    )  # (G, E, C, d)
    sel = sel * sel_valid[..., None].astype(sel.dtype)
    # E-leading layout through the expert GEMMs: dot_general wants the
    # batch dim first, and transposing an E-sharded tensor makes GSPMD
    # all-gather it (observed on granite: 3 x 1.2 GB per layer per
    # microbatch); with E leading the layout is already native.
    sel = jnp.swapaxes(sel, 0, 1)  # (E, G, C, d)
    if dispatch_sharding is not None:
        sel = jax.lax.with_sharding_constraint(sel, dispatch_sharding)

    # expert GEMMs, batched over (E is model-, G is data-sharded)
    h_in = jnp.einsum("egcd,edf->egcf", sel, p["w_in"])
    if cfg.mlp_type == "swiglu":
        h_gate = jnp.einsum("egcd,edf->egcf", sel, p["w_gate"])
        hmid = jax.nn.silu(h_gate) * h_in
    else:
        hmid = jax.nn.gelu(h_in)
    y_sel = jnp.einsum("egcf,efd->egcd", hmid, p["w_out"])  # (E, G, C, d)
    if dispatch_sharding is not None:
        y_sel = jax.lax.with_sharding_constraint(y_sel, dispatch_sharding)
    y_sel = jnp.swapaxes(y_sel, 0, 1)  # back to (G, E, C, d)

    # combine: gate-weight each expert output and scatter-add back to its
    # token (local per group; invalid slots carry zero weight so their
    # arbitrary indices are harmless)
    weighted = y_sel.astype(jnp.float32) * (
        top_scores * sel_valid.astype(jnp.float32)
    )[..., None]
    # keep E as a scatter batch dim — reshaping (E, C) together would
    # merge a model-sharded axis with an unsharded one and force GSPMD
    # to all-gather the dispatch tensors (observed on granite)
    out = jax.vmap(
        lambda u, i: jnp.zeros((tg, d), jnp.float32).at[i].add(u)
    )(weighted, top_idx)
    return x + out.astype(x.dtype).reshape(B, S, d)


def moe(p, x, cfg, *, groups: int = 1, dispatch_sharding=None):
    """Default MoE entry point — the SPMD-safe capacity formulation."""
    return moe_capacity(
        p, x, cfg, groups=groups, dispatch_sharding=dispatch_sharding
    )


def moe_dropless(p, x, cfg):
    """Top-k MoE over tokens; per-slot permute -> grouped GEMM -> unpermute.

    Exactly-dropless sort-based path (``jax.lax.ragged_dot``). Single-
    accelerator semantics: the global argsort does not partition under
    GSPMD, so the SPMD path uses `moe_capacity` instead; this version is
    the semantic oracle the capacity path is tested against (they agree
    when capacity is generous) and the host-local serving path.
    """
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    flat = xn.reshape(B * S, d)
    logits = flat.astype(jnp.float32) @ p["router"]  # (T, E)
    gates, experts = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    e_store = p["w_in"].shape[0]

    def one_slot(slot_experts, slot_gates):
        order = jnp.argsort(slot_experts)  # tokens grouped by expert
        xs = flat[order]
        group_sizes = jnp.bincount(slot_experts, length=e_store).astype(jnp.int32)
        h_in = jax.lax.ragged_dot(xs, p["w_in"], group_sizes)
        if cfg.mlp_type == "swiglu":
            h_gate = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
            hmid = jax.nn.silu(h_gate) * h_in
        else:
            hmid = jax.nn.gelu(h_in)
        ys = jax.lax.ragged_dot(hmid, p["w_out"], group_sizes)
        inv = jnp.argsort(order)
        return ys[inv] * slot_gates[:, None].astype(ys.dtype)

    out = jnp.zeros_like(flat)
    for slot in range(k):  # unrolled: k is small (2..8)
        out = out + one_slot(experts[:, slot], gates[:, slot])
    return x + out.reshape(B, S, d)


def moe_aux_loss(p, x, cfg):
    """Load-balancing auxiliary loss (Switch-style), returned separately."""
    B, S, d = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    logits = xn.reshape(B * S, d).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
