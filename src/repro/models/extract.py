"""ArchConfig -> PHAROS `Workload` extraction.

PHAROS models a task as an ordered chain of layers priced by their
dominant GEMM (paper §3.3). This module flattens an assigned LM
architecture into that chain so the DSE / schedulers / DES treat LM
inference (or a training microbatch) exactly like the paper's DNN
tasks: segments = consecutive layers, WCET from the exec model.

Modes
-----
- ``prefill``: one job = forward over (batch, seq) tokens.
- ``decode``:  one job = one new token per sequence with a ctx-long
  KV cache / state — attention layers become memory-bound cache sweeps,
  which is what makes decode-heavy tasksets collective/HBM-limited.
- ``train``:   forward + backward (3x forward FLOPs on GEMMs) for one
  microbatch.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.core.rt.task import LayerDesc, Workload

_BF16 = 2


def _gemm(name, M, K, N, kind="mlp", mult: float = 1.0) -> LayerDesc:
    """GEMM layer; ``mult`` scales flops+bytes (train bwd = 3x)."""
    return LayerDesc(
        name,
        M=M,
        K=K,
        N=N,
        kind=kind,
        flops=mult * 2.0 * M * K * N,
        bytes_rw=mult * _BF16 * (M * K + K * N + M * N),
    )


def _attn_layers(cfg: ArchConfig, M: int, S_ctx: int, mode: str, mult: float, i: int):
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    qkv_n = (h + 2 * kv) * hd
    out = [_gemm(f"l{i}_qkv", M, d, qkv_n, "attn_proj", mult)]
    if mode == "decode":
        # one query against an S_ctx KV cache: 2 GEMV sweeps per head;
        # traffic dominated by reading the cache once.
        flops = mult * 2.0 * 2.0 * M * h * hd * S_ctx
        byts = mult * _BF16 * 2.0 * M * kv * S_ctx * hd  # K+V cache read
        out.append(
            LayerDesc(
                f"l{i}_attn",
                M=M,
                K=h * hd,
                N=S_ctx,
                kind="attn_decode",
                flops=flops,
                bytes_rw=byts,
            )
        )
    else:
        # causal: average S/2 keys per query
        flops = mult * 2.0 * 2.0 * M * h * hd * (S_ctx / 2.0)
        byts = mult * _BF16 * (2 * M * (h * hd) + M * S_ctx)
        out.append(
            LayerDesc(
                f"l{i}_attn",
                M=M,
                K=h * hd,
                N=S_ctx,
                kind="attn",
                flops=flops,
                bytes_rw=byts,
            )
        )
    out.append(_gemm(f"l{i}_out", M, h * hd, d, "attn_proj", mult))
    return out


def _mamba_layers(cfg: ArchConfig, M: int, mult: float, i: int):
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.mamba_d_state
    dt_rank = max(1, d // 16)
    scan_flops = mult * 8.0 * M * di * ns  # elementwise recurrence ops
    return [
        _gemm(f"l{i}_in", M, d, 2 * di, "ssm_proj", mult),
        _gemm(f"l{i}_xproj", M, di, dt_rank + 2 * ns, "ssm_proj", mult),
        LayerDesc(
            f"l{i}_scan",
            M=M,
            K=di,
            N=ns,
            kind="scan",
            flops=scan_flops,
            bytes_rw=mult * 4.0 * (2 * M * di * ns),
        ),
        _gemm(f"l{i}_out", M, di, d, "ssm_proj", mult),
    ]


def _rwkv_layers(cfg: ArchConfig, M: int, mult: float, i: int):
    d = cfg.d_model
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    scan_flops = mult * 4.0 * M * H * hd * hd  # state update + readout
    return [
        _gemm(f"l{i}_rkvg", M, d, 4 * d, "rwkv_proj", mult),
        LayerDesc(
            f"l{i}_wkv",
            M=M,
            K=d,
            N=hd,
            kind="scan",
            flops=scan_flops,
            bytes_rw=mult * 4.0 * 2 * M * d,
        ),
        _gemm(f"l{i}_out", M, d, d, "rwkv_proj", mult),
    ]


def _ffn_layers(cfg: ArchConfig, ffn: str, M: int, mult: float, i: int):
    d, f = cfg.d_model, cfg.d_ff
    n_up = 2 if cfg.mlp_type == "swiglu" else 1
    if ffn == "dense":
        return [
            _gemm(f"l{i}_up", M, d, n_up * f, "mlp", mult),
            _gemm(f"l{i}_dn", M, f, d, "mlp", mult),
        ]
    if ffn == "moe":
        Ma = M * cfg.top_k  # active-token rows through experts
        return [
            _gemm(f"l{i}_router", M, d, cfg.n_experts, "moe_router", mult),
            _gemm(f"l{i}_moe_up", Ma, d, n_up * f, "moe", mult),
            _gemm(f"l{i}_moe_dn", Ma, f, d, "moe", mult),
        ]
    # rwkv channel-mix
    return [
        _gemm(f"l{i}_cmix_up", M, d, f, "mlp", mult),
        _gemm(f"l{i}_cmix_dn", M, f, d, "mlp", mult),
    ]


def arch_workload(
    cfg: ArchConfig,
    batch: int,
    seq: int,
    mode: str = "prefill",
    include_head: bool = True,
) -> Workload:
    """Flatten ``cfg`` into the PHAROS layer chain for one job.

    ``mode='decode'`` prices one token/sequence against a ``seq``-long
    context; other modes price the full (batch, seq) block.
    """
    if mode not in ("prefill", "decode", "train"):
        raise ValueError(f"unknown mode {mode!r}")
    mult = 3.0 if mode == "train" else 1.0
    M = batch if mode == "decode" else batch * seq
    layers: list[LayerDesc] = []
    for i, (mixer, ffn) in enumerate(cfg.layer_plan()):
        if mixer == "attn":
            layers += _attn_layers(cfg, M, seq, mode, mult, i)
        elif mixer == "mamba":
            layers += _mamba_layers(cfg, M, mult, i)
        else:
            layers += _rwkv_layers(cfg, M, mult, i)
        layers += _ffn_layers(cfg, ffn, M, mult, i)
    if include_head:
        layers.append(_gemm("lm_head", M, cfg.d_model, cfg.vocab, "head", mult))
    return Workload(f"{cfg.name}:{mode}", tuple(layers))
