"""Deterministic synthetic token pipeline, host-sharded.

Design requirements for a 1000-node deployment, all honoured here:

- **Determinism / restart**: batch ``i`` is a pure function of
  ``(seed, i)`` — a restarted job resumes from any step with identical
  data, no iterator state to checkpoint beyond the step counter.
- **Host sharding**: each host materializes only its slice of the
  global batch (``host_id / num_hosts``); the `global` array is never
  built on one host.
- **Structure, not noise**: tokens follow a per-sequence Markov chain
  (shift + mix) so the LM loss actually decreases — examples/train use
  it to show a real training curve, and tests assert learnability.
- Zero I/O: no filesystem or network dependencies (the container is
  offline); swapping in a real corpus only replaces `_sequence`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: structure strength: probability a token continues the chain
    #: (vs fresh uniform draw); higher -> more learnable signal
    coherence: float = 0.9


class SyntheticTokenDataset:
    """Deterministic, host-shardable synthetic LM dataset."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        if cfg.global_batch % num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def _sequence(self, rng: np.random.Generator):
        """One (seq_len + 1,) token chain: affine-recurrent vocab walk."""
        cfg = self.cfg
        n = cfg.seq_len + 1
        fresh = rng.integers(0, cfg.vocab, size=n)
        cont = rng.random(n) < cfg.coherence
        toks = np.empty(n, np.int64)
        toks[0] = fresh[0]
        mult, add = 31, 7  # fixed affine walk: next = (31*t + 7) % V
        for t in range(1, n):
            toks[t] = (mult * toks[t - 1] + add) % cfg.vocab if cont[t] else fresh[t]
        return toks

    def batch(self, step: int):
        """Host-local batch for global step ``step``:
        {"tokens","labels","mask"} with shapes (local_batch, seq_len)."""
        cfg = self.cfg
        tokens = np.empty((self.local_batch, cfg.seq_len), np.int32)
        labels = np.empty((self.local_batch, cfg.seq_len), np.int32)
        for i in range(self.local_batch):
            global_row = self.host_id * self.local_batch + i
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, global_row])
            )
            chain = self._sequence(rng)
            tokens[i] = chain[:-1]
            labels[i] = chain[1:]
        return {
            "tokens": tokens,
            "labels": labels,
            "mask": np.ones_like(labels, np.float32),
        }


def make_batch_iterator(cfg: DataConfig, host_id: int = 0, num_hosts: int = 1,
                        start_step: int = 0):
    """Infinite iterator of host-local batches starting at ``start_step``."""
    ds = SyntheticTokenDataset(cfg, host_id, num_hosts)
    step = start_step
    while True:
        yield step, ds.batch(step)
        step += 1
