from repro.data.pipeline import (
    DataConfig,
    SyntheticTokenDataset,
    make_batch_iterator,
)

__all__ = ["DataConfig", "SyntheticTokenDataset", "make_batch_iterator"]
