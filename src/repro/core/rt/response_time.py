"""Analytical response-time bounds for chained pipeline stages (§5.3).

The paper reports response-time *statistics* from simulation (Fig. 8)
and relies on Eq. 3 for schedulability. For completeness we also provide
safe analytical upper bounds per scheduling policy, built from classical
uniprocessor busy-period analysis, chained across stages:

- Each stage is a single work-conserving server (the accelerator).
- Stage-k release jitter of task i equals the sum of upstream response
  bounds (a job reaches stage k only after finishing stages < k).
- FIFO: a job's response time at a stage is bounded by the length of the
  synchronous busy period of that stage with jitter-inflated arrivals —
  FIFO serves in arrival order, so a job finishes no later than the end
  of the busy period containing its arrival.
- EDF (implicit deadlines, u <= 1): without jitter, uniprocessor EDF
  meets all deadlines, so R <= d. With release jitter J, a safe bound is
  R <= d + J_max (jitter can delay completion at most by itself under a
  deadline-ordered work-conserving server) — we additionally cap by the
  jitter-inflated busy period, taking the tighter of the two.
- Limited preemption (the runtime's tile-window and the DES's
  ``preemption="window"`` semantics): preemption happens only at
  non-preemptible chunk boundaries, so a job additionally suffers a
  *blocking term* ``B^k`` — the longest non-preemptible chunk of work
  on stage k that may be in flight when it gains priority. EDF picks
  earliest-deadline work whenever any is pending, so within one busy
  interval at most **one** later-deadline chunk can be in service
  (after its boundary, no later-deadline work restarts while
  earlier-deadline work waits); the stage bound therefore gains a
  single ``B^k`` in both the deadline term and the busy period.
  FIFO needs no blocking term: it never preempts, and every chunk in
  service when a job arrives belongs to an earlier arrival already
  counted by its busy period.

These bounds require strict u^k < 1 for a finite busy period; at u == 1
the theory still promises *bounded* tardiness but the busy-period fixed
point diverges, and we return ``inf`` (documented conservatism).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.rt.task import SegmentTable, TaskSet

_MAX_ITERS = 10_000


def busy_period(
    wcets: list[float],
    periods: list[float],
    jitters: list[float] | None = None,
    blocking: float = 0.0,
) -> float:
    """Longest synchronous busy period: least L > 0 with
    ``L = B + sum_i ceil((L + J_i) / p_i) * e_i``. Returns inf if
    u >= 1. ``blocking`` is the limited-preemption term ``B``: at most
    one non-preemptible chunk of excluded (lower-priority) work may be
    in service when the busy period starts.
    """
    if jitters is None:
        jitters = [0.0] * len(wcets)
    active = [
        (e, p, j) for e, p, j in zip(wcets, periods, jitters) if e > 0.0
    ]
    if not active:
        return blocking if blocking > 0.0 else 0.0
    if any(math.isinf(j) for _, _, j in active):
        # an active task with unbounded release jitter (its upstream
        # stage saturated) makes this stage's busy period unbounded too
        return math.inf
    u = sum(e / p for e, p, _ in active)
    if u >= 1.0 - 1e-12:
        return math.inf
    L = blocking + sum(e for e, _, _ in active)
    for _ in range(_MAX_ITERS):
        nxt = blocking + sum(
            math.ceil((L + j) / p) * e for e, p, j in active
        )
        if nxt <= L + 1e-15:
            return nxt
        L = nxt
    return math.inf


@dataclass
class StageBounds:
    """Per-stage response bounds ``R_i^k`` (0 for skipped stages)."""

    per_task: list[float]


def fifo_stage_bound(
    table: SegmentTable,
    taskset: TaskSet,
    k: int,
    jitters: list[float],
) -> StageBounds:
    """FIFO response bound at stage k: busy-period cap for active tasks."""
    wcets = [table.wcet(i, k, preemptive=False) for i in range(table.n_tasks)]
    periods = [t.period for t in taskset.tasks]
    L = busy_period(wcets, periods, jitters)
    return StageBounds(per_task=[L if e > 0 else 0.0 for e in wcets])


def edf_stage_bound(
    table: SegmentTable,
    taskset: TaskSet,
    k: int,
    jitters: list[float],
    blocking: float = 0.0,
) -> StageBounds:
    """EDF response bound at stage k: min(d_i + J_i + B, busy period).

    ``blocking`` is the stage's limited-preemption term ``B^k`` (the
    longest non-preemptible chunk that can hold an urgent job at a
    window boundary); it enters the deadline term once and the busy
    period once — see the module docstring for why a single ``B``
    suffices under EDF.

    The deadline term is only a valid bound while the stage's busy
    period is finite (its premise — uniprocessor EDF meets deadlines —
    needs ``u < 1``): on a saturated or overloaded stage (``L == inf``)
    claiming ``R <= d + J + B`` would be unsound, so the bound degrades
    to ``inf`` (caught by the cross-layer conformance harness: the DES
    exceeded the "bound" on exactly such stages).
    """
    wcets = [table.wcet(i, k, preemptive=True) for i in range(table.n_tasks)]
    periods = [t.period for t in taskset.tasks]
    L = busy_period(wcets, periods, jitters, blocking=blocking)
    out = []
    for i, e in enumerate(wcets):
        if e <= 0:
            out.append(0.0)
            continue
        if L == math.inf:
            out.append(math.inf)
            continue
        deadline_bound = taskset.tasks[i].deadline + jitters[i] + blocking
        out.append(min(max(deadline_bound, e), L))
    return StageBounds(per_task=out)


def end_to_end_bounds(
    table: SegmentTable,
    taskset: TaskSet,
    policy: str,
    blocking: list[float] | None = None,
) -> list[float]:
    """End-to-end response-time upper bound per task.

    Chains the per-stage bounds: the stage-k jitter of task i is the sum
    of its bounds at stages < k (its segment cannot be released earlier
    than its own arrival nor later than the upstream bound).

    ``blocking`` optionally gives the per-stage limited-preemption
    blocking term ``B^k`` (max non-preemptible chunk on stage k, e.g.
    `repro.conformance.CostModel.stage_window_quantum`) for systems
    whose scheduler preempts only at chunk/window boundaries. It only
    affects EDF; FIFO never preempts, so chunk granularity cannot
    change its schedule.
    """
    if policy not in ("fifo", "edf"):
        raise ValueError(f"unknown policy {policy!r}")
    if blocking is not None and len(blocking) != table.n_stages:
        raise ValueError("blocking vector length != n_stages")
    n = table.n_tasks
    totals = [0.0] * n
    jitters = [0.0] * n
    for k in range(table.n_stages):
        if policy == "fifo":
            sb = fifo_stage_bound(table, taskset, k, jitters)
        else:
            sb = edf_stage_bound(
                table,
                taskset,
                k,
                jitters,
                blocking=blocking[k] if blocking is not None else 0.0,
            )
        for i in range(n):
            if table.base[i][k] > 0.0:
                totals[i] += sb.per_task[i]
                jitters[i] = totals[i]
    return totals
