"""Batched (vectorized) RT analysis over arrays of candidate designs.

The DSE evaluates thousands of candidate designs per beam iteration;
calling the scalar Eq. 2/3 and busy-period routines once per candidate
makes Python interpreter overhead the bottleneck. This module provides
numpy-vectorized versions that evaluate a whole *stack* of candidate
`SegmentTable`s at once: ``base`` is a ``[C, n_tasks, n_stages]`` array
(candidate-major), ``overhead`` a ``[n_stages]`` or ``[C, n_stages]``
array, and every function returns per-candidate results.

Bit-compatibility contract: every function here produces **bit-identical
float64 results** to its scalar counterpart in
`repro.core.rt.schedulability` / `repro.core.rt.response_time`. That is
not best-effort — the property suite asserts exact ``==`` over
randomized designs — and it is what lets the DSE swap the batched
evaluator in without perturbing a single search decision. The rules
that make it hold:

- only the *candidate* axis is vectorized; reductions over tasks and
  stages run as explicit Python loops in the same order as the scalar
  code (float addition is not associative — numpy's pairwise ``sum``
  would diverge in the last ulp);
- inactive entries contribute exact ``0.0`` terms (adding ``0.0`` is an
  identity on every finite float), mirroring the scalar ``e > 0``
  filters without changing accumulation order;
- fixed-point iterations (`batched_busy_period`) update all still-
  converging candidates with the same update expression the scalar
  loop uses; converged/diverged lanes are frozen by masking.
"""
from __future__ import annotations

import numpy as np

from repro.core.rt.schedulability import EPS
from repro.core.rt.task import TaskSet

#: scalar `busy_period` limits, shared so the lockstep never drifts
_MAX_ITERS = 10_000
_DIVERGE_EPS = 1e-12
_CONVERGE_EPS = 1e-15


def _as_batch(base) -> np.ndarray:
    a = np.asarray(base, dtype=np.float64)
    if a.ndim != 3:
        raise ValueError(f"base must be [C, n_tasks, n_stages], got {a.shape}")
    return a


def _overhead_rows(overhead, n_cand: int, n_stages: int) -> np.ndarray:
    ov = np.asarray(overhead, dtype=np.float64)
    if ov.ndim == 1:
        ov = np.broadcast_to(ov, (n_cand, n_stages))
    if ov.shape != (n_cand, n_stages):
        raise ValueError("overhead must be [n_stages] or [C, n_stages]")
    return ov


def batched_wcets(base, overhead, preemptive: bool) -> np.ndarray:
    """``e_i^k`` per candidate (Eq. 4): ``b + xi`` when preemptive and
    the stage is active, ``b`` otherwise, ``0`` on skipped stages."""
    b = _as_batch(base)
    if not preemptive:
        return np.where(b > 0.0, b, 0.0)
    ov = _overhead_rows(overhead, b.shape[0], b.shape[2])
    return np.where(b > 0.0, b + ov[:, None, :], 0.0)


def batched_stage_utilizations(
    base, overhead, taskset: TaskSet, preemptive: bool
) -> np.ndarray:
    """Eq. 2 per candidate: ``u^k = sum_i e_i^k / p_i`` -> [C, K]."""
    b = _as_batch(base)
    if len(taskset) != b.shape[1]:
        raise ValueError("taskset size != segment table size")
    e = batched_wcets(b, overhead, preemptive)
    util = np.zeros((b.shape[0], b.shape[2]))
    # task-order accumulation matches the scalar generator sum exactly
    for i, t in enumerate(taskset.tasks):
        util += e[:, i, :] / t.period
    return util


def batched_max_utilization(
    base, overhead, taskset: TaskSet, preemptive: bool
) -> np.ndarray:
    """``max_k u^k`` per candidate — the DSE objective vector."""
    return batched_stage_utilizations(
        base, overhead, taskset, preemptive
    ).max(axis=1)


def batched_srt_schedulable(
    base, overhead, taskset: TaskSet, preemptive: bool
) -> np.ndarray:
    """Eq. 3 verdict per candidate (bool array)."""
    return (
        batched_max_utilization(base, overhead, taskset, preemptive)
        <= 1.0 + EPS
    )


def batched_tenant_utilizations(
    base, overhead, periods, preemptive: bool
) -> np.ndarray:
    """Per-*tenant* Eq. 2 contribution vectors -> ``[T, K]``.

    The serving-side dual of `batched_stage_utilizations`: instead of
    summing one shared taskset per candidate design, this prices every
    tenant of one design independently — ``base`` is ``[T, n_stages]``
    (one `TaskRequest.base` row per tenant), ``periods`` is ``[T]``,
    and row ``t`` is exactly ``TaskRequest.utilization`` of tenant
    ``t``: ``e^k / p`` with the Eq. 4 overhead applied iff preemptive
    and the stage is active. Bit-identical to the scalar method (same
    IEEE ops, no reductions), which is what lets the admission,
    rate-limit and placement hot paths score thousands of tenants in
    one array pass without perturbing a single decision.
    """
    b = np.asarray(base, dtype=np.float64)
    if b.ndim != 2:
        raise ValueError(f"base must be [T, n_stages], got {b.shape}")
    p = np.asarray(periods, dtype=np.float64)
    if p.shape != (b.shape[0],):
        raise ValueError("periods must align 1:1 with base rows")
    e = batched_wcets(b[None, :, :], overhead, preemptive)[0]
    return e / p[:, None]


def batched_admission_check(
    tenant_utils, current_util, util_cap: float = 1.0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized `AdmissionController.check` core over ``[T, K]``
    per-tenant utilization vectors against one cached Eq. 2 state.

    Returns ``(after, bottleneck, ok)``: the post-admit stage
    utilizations ``[T, K]``, the argmax stage per tenant (first max on
    ties, matching the scalar ``max(range, key=...)``), and the Eq. 3
    verdict ``after[bottleneck] <= util_cap + EPS`` — the same EPS
    band `srt_schedulable` applies. Each row is an *independent*
    non-committing check against ``current_util``, exactly like a
    Python loop over the scalar ``check``.
    """
    du = np.asarray(tenant_utils, dtype=np.float64)
    if du.ndim != 2:
        raise ValueError(f"tenant_utils must be [T, K], got {du.shape}")
    cur = np.asarray(current_util, dtype=np.float64)
    if cur.shape != (du.shape[1],):
        raise ValueError("current_util must be [n_stages]")
    after = du + cur[None, :]
    bottleneck = after.argmax(axis=1)
    peak = after[np.arange(after.shape[0]), bottleneck]
    ok = peak <= util_cap + EPS
    return after, bottleneck, ok


def batched_stage_slacks(
    base, overhead, taskset: TaskSet, preemptive: bool
) -> np.ndarray:
    """Per-candidate `stage_slacks`: ``1 - u^k`` with the same tiny-
    negative clamp the scalar version applies inside the EPS band."""
    slack = 1.0 - batched_stage_utilizations(
        base, overhead, taskset, preemptive
    )
    return np.where((slack < 0.0) & (slack >= -EPS), 0.0, slack)


def batched_busy_period(
    wcets: np.ndarray,
    periods,
    jitters: np.ndarray | None = None,
    blocking=0.0,
) -> np.ndarray:
    """Vectorized `busy_period`: least ``L > 0`` with
    ``L = B + sum_i ceil((L + J_i) / p_i) * e_i`` per candidate.

    ``wcets``/``jitters`` are ``[C, n]``, ``periods`` ``[n]``,
    ``blocking`` scalar or ``[C]``. Candidates whose utilization is
    within ``1e-12`` of 1 (or that fail to converge in the scalar
    iteration cap) return ``inf``, exactly like the scalar routine.
    """
    e = np.asarray(wcets, dtype=np.float64)
    C, n = e.shape
    p = np.asarray(periods, dtype=np.float64)
    j = (
        np.zeros_like(e)
        if jitters is None
        else np.asarray(jitters, dtype=np.float64)
    )
    # the scalar loop never sees inactive tasks' jitters; zero them so
    # the exact-0.0-term trick below stays valid even when an upstream
    # stage handed an inactive task an infinite jitter
    j = np.where(e > 0.0, j, 0.0)
    blk = np.broadcast_to(
        np.asarray(blocking, dtype=np.float64), (C,)
    ).copy()

    # zero-WCET tasks contribute exact 0.0 terms in every expression
    # below, so summing over all tasks in task order reproduces the
    # scalar loop's active-only accumulation bit-for-bit
    u = np.zeros(C)
    wsum = np.zeros(C)
    for i in range(n):
        u += e[:, i] / p[i]
        wsum += e[:, i]
    no_active = ~(e > 0.0).any(axis=1)
    # an active task with infinite jitter diverges the busy period
    # (mirrors the scalar guard added for saturated upstream stages)
    inf_jitter = (np.isinf(j) & (e > 0.0)).any(axis=1)
    diverged = ((u >= 1.0 - _DIVERGE_EPS) | inf_jitter) & ~no_active

    L = blk + wsum
    out = np.where(diverged, np.inf, L)
    pending = np.flatnonzero(~diverged)
    for _ in range(_MAX_ITERS):
        if pending.size == 0:
            break
        Lp = out[pending]
        # accumulate the ceil terms from 0 and add blocking last — the
        # scalar expression is ``blocking + sum(...)``, and float
        # addition order decides the last ulp
        acc = np.zeros(pending.size)
        for i in range(n):
            acc += np.ceil((Lp + j[pending, i]) / p[i]) * e[pending, i]
        nxt = blk[pending] + acc
        out[pending] = nxt
        pending = pending[~(nxt <= Lp + _CONVERGE_EPS)]
    else:
        out[pending] = np.inf
    # scalar early-returns `blocking if blocking > 0 else 0.0` for an
    # all-skip row; the fixed point above already lands there, but the
    # blocking == 0 case must be exact +0.0, not a -0.0 survivor
    out[no_active & (blk <= 0.0)] = 0.0
    return out


def batched_end_to_end_bounds(
    base,
    overhead,
    taskset: TaskSet,
    policy: str,
    blocking=None,
) -> np.ndarray:
    """Vectorized `end_to_end_bounds` -> ``[C, n_tasks]``.

    Chains per-stage FIFO/EDF busy-period bounds with upstream-response
    jitter exactly like the scalar routine; ``blocking`` is the
    per-stage limited-preemption term (``[K]`` or ``[C, K]``, EDF only).
    """
    if policy not in ("fifo", "edf"):
        raise ValueError(f"unknown policy {policy!r}")
    b = _as_batch(base)
    C, n, K = b.shape
    periods = [t.period for t in taskset.tasks]
    deadlines = np.asarray([t.deadline for t in taskset.tasks])
    if blocking is None:
        blk = np.zeros((C, K))
    else:
        blk = np.asarray(blocking, dtype=np.float64)
        if blk.ndim == 1:
            blk = np.broadcast_to(blk, (C, K))
        if blk.shape != (C, K):
            raise ValueError("blocking must be [n_stages] or [C, n_stages]")
    e = batched_wcets(b, overhead, preemptive=(policy == "edf"))

    totals = np.zeros((C, n))
    jitters = np.zeros((C, n))
    for k in range(K):
        ek = e[:, :, k]
        if policy == "fifo":
            L = batched_busy_period(ek, periods, jitters)
            sb = np.where(ek > 0.0, L[:, None], 0.0)
        else:
            bk = blk[:, k]
            L = batched_busy_period(ek, periods, jitters, blocking=bk)
            # (d_i + J_i) + B in the scalar association order
            dl = (deadlines[None, :] + jitters) + bk[:, None]
            sb = np.minimum(np.maximum(dl, ek), L[:, None])
            sb = np.where(ek > 0.0, sb, 0.0)
            sb = np.where(
                (ek > 0.0) & np.isinf(L)[:, None], np.inf, sb
            )
        active = b[:, :, k] > 0.0
        totals = np.where(active, totals + sb, totals)
        jitters = totals.copy()
    return totals
