"""Real-time theory core for PHAROS (paper §3.3–§3.4).

Implements the task/segment model, per-accelerator utilization (Eq. 2),
the SRT-schedulability test (Eq. 3) from the guideline theory
[Dong et al., ECRTS'17], the preemption-overhead WCET model (Eqs. 4–5),
and analytical response-time bounds for FIFO and EDF on a chained
pipeline of accelerators.
"""
from repro.core.rt.task import (
    LayerDesc,
    Workload,
    Task,
    TaskSet,
    SegmentTable,
)
from repro.core.rt.schedulability import (
    stage_utilization,
    max_utilization,
    srt_schedulable,
    effective_wcets,
    stage_slacks,
    max_admissible_rate,
    task_rate_sensitivity,
    utilization_headroom,
)
from repro.core.rt.response_time import (
    busy_period,
    fifo_stage_bound,
    edf_stage_bound,
    end_to_end_bounds,
)
from repro.core.rt.batch import (
    batched_busy_period,
    batched_end_to_end_bounds,
    batched_max_utilization,
    batched_srt_schedulable,
    batched_stage_slacks,
    batched_stage_utilizations,
    batched_wcets,
)

__all__ = [
    "LayerDesc",
    "Workload",
    "Task",
    "TaskSet",
    "SegmentTable",
    "stage_utilization",
    "max_utilization",
    "srt_schedulable",
    "effective_wcets",
    "stage_slacks",
    "max_admissible_rate",
    "task_rate_sensitivity",
    "utilization_headroom",
    "busy_period",
    "fifo_stage_bound",
    "edf_stage_bound",
    "end_to_end_bounds",
    "batched_busy_period",
    "batched_end_to_end_bounds",
    "batched_max_utilization",
    "batched_srt_schedulable",
    "batched_stage_slacks",
    "batched_stage_utilizations",
    "batched_wcets",
]
