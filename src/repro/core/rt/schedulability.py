"""Utilization and SRT-schedulability tests (paper Eqs. 2–5).

The guideline theory [Dong et al., ECRTS'17] states: on a chained
pipeline of accelerators where a job must finish all execution on
``acc^k`` before any execution on ``acc^{k+1}`` (no backtracking), the
system is SRT-schedulable — every job's response time is bounded — if
and only if every accelerator's utilization is at most 1 (Eq. 3), under
both FIFO and EDF.

Preemption overhead (EDF only) is folded into the WCET per Eq. 4–5
before the test, which preserves safety of the sufficient direction:
if the inflated utilizations pass, the real system (whose overhead is
at most the model's) is schedulable.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.rt.task import SegmentTable, TaskSet

#: Strictness slack: utilizations within EPS above 1.0 are treated as 1.0
#: to absorb float roundoff in WCET accumulation.
EPS = 1e-12


def effective_wcets(
    table: SegmentTable, preemptive: bool
) -> list[list[float]]:
    """``e_i^k`` matrix with Eq. 4 applied (xi added iff preemptive)."""
    return table.wcets(preemptive)


def stage_utilization(
    table: SegmentTable, taskset: TaskSet, k: int, preemptive: bool
) -> float:
    """Eq. 2: ``u^k = sum_i e_i^k / p_i``."""
    if len(taskset) != table.n_tasks:
        raise ValueError("taskset size != segment table size")
    return sum(
        table.wcet(i, k, preemptive) / taskset.tasks[i].period
        for i in range(table.n_tasks)
    )


def stage_utilizations(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    return [
        stage_utilization(table, taskset, k, preemptive)
        for k in range(table.n_stages)
    ]


def max_utilization(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> float:
    """The DSE objective ``max_k u^k`` (paper §4.1)."""
    return max(stage_utilizations(table, taskset, preemptive))


def srt_schedulable(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> bool:
    """Eq. 3: SRT-schedulable iff ``u^k <= 1`` for every stage.

    ``preemptive=True`` applies the EDF overhead inflation first; the
    paper notes SG+EDF loses the *iff* guarantee once overhead exists —
    passing this test with inflated WCETs restores a sufficient
    condition (overhead-inclusive utilization <= 1).
    """
    return max_utilization(table, taskset, preemptive) <= 1.0 + EPS


def utilization_headroom(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> float:
    """Max proportional period *shrink* factor keeping the system
    schedulable: scaling all periods to ``x%`` scales every ``u^k`` by
    ``1/x%`` (paper §4.1), so headroom = ``1 / max_util``.
    """
    mu = max_utilization(table, taskset, preemptive)
    return float("inf") if mu <= 0 else 1.0 / mu


def stage_slacks(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    """Per-stage admission slack ``1 - u^k`` — the utilization budget an
    online admission controller may still hand out on each accelerator
    before Eq. 3 flips.

    Clamped at 0 within the same ``EPS`` band `srt_schedulable` treats
    as feasible: a stage whose utilization lands within float roundoff
    above 1.0 passes the Eq. 3 gate, so reporting a (tiny) negative
    slack for it would hand the admission layer negative headroom for a
    system the analysis just called schedulable. Genuinely infeasible
    stages (``u^k > 1 + EPS``) still report their negative slack.
    """
    out = []
    for u in stage_utilizations(table, taskset, preemptive):
        slack = 1.0 - u
        if -EPS <= slack < 0.0:
            slack = 0.0
        out.append(slack)
    return out


def max_admissible_rate(
    table: SegmentTable,
    taskset: TaskSet,
    cand_base: Sequence[float],
    preemptive: bool,
) -> float:
    """Largest release rate (jobs/s) at which a *candidate* task with
    per-stage base WCETs ``cand_base`` keeps every stage at ``u^k <= 1``.

    Eq. 2 is linear in the candidate's rate ``r``: stage k moves to
    ``u^k + r * e_cand^k``, so the bound is
    ``min_k (1 - u^k) / e_cand^k`` over the candidate's active stages.
    Returns ``inf`` for an empty candidate and ``0`` when some active
    stage is already saturated.
    """
    if len(cand_base) != table.n_stages:
        raise ValueError("candidate WCET vector length != n_stages")
    rate = float("inf")
    for k, b in enumerate(cand_base):
        if b <= 0.0:
            continue
        e = b + (table.overhead[k] if preemptive else 0.0)
        slack = 1.0 - stage_utilization(table, taskset, k, preemptive)
        rate = min(rate, max(0.0, slack) / e)
    return rate


def task_rate_sensitivity(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    """Per-task max rate *multiplier* keeping Eq. 3 satisfied.

    Scaling only task i's rate by ``s`` moves stage k to
    ``u^k + (s - 1) * u_i^k``; the largest admissible ``s`` is
    ``min_k 1 + (1 - u^k) / u_i^k`` over task i's active stages — the
    admission layer's sensitivity report ("how much more of *this*
    traffic fits"). On an already-infeasible set the multiplier drops
    below 1: the rate *reduction* that would restore Eq. 3 on the
    task's worst stage.
    """
    utils = stage_utilizations(table, taskset, preemptive)
    out = []
    for i, t in enumerate(taskset.tasks):
        s_max = float("inf")
        for k in range(table.n_stages):
            e = table.wcet(i, k, preemptive)
            if e <= 0.0:
                continue
            u_ik = e / t.period
            s_max = min(s_max, 1.0 + (1.0 - utils[k]) / u_ik)
        out.append(s_max)
    return out


def density_check(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    """Per-task chain density ``sum_k e_i^k / p_i`` — diagnostic only.

    A task whose *chain* WCET exceeds its period still admits bounded
    response times in the SRT model (jobs of the same task may overlap
    across pipeline stages), so this is not a schedulability gate; it is
    reported because density > M signals a hopeless configuration.
    """
    out = []
    for i, t in enumerate(taskset.tasks):
        chain = sum(table.wcet(i, k, preemptive) for k in range(table.n_stages))
        out.append(chain / t.period)
    return out
