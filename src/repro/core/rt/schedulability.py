"""Utilization and SRT-schedulability tests (paper Eqs. 2–5).

The guideline theory [Dong et al., ECRTS'17] states: on a chained
pipeline of accelerators where a job must finish all execution on
``acc^k`` before any execution on ``acc^{k+1}`` (no backtracking), the
system is SRT-schedulable — every job's response time is bounded — if
and only if every accelerator's utilization is at most 1 (Eq. 3), under
both FIFO and EDF.

Preemption overhead (EDF only) is folded into the WCET per Eq. 4–5
before the test, which preserves safety of the sufficient direction:
if the inflated utilizations pass, the real system (whose overhead is
at most the model's) is schedulable.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.rt.task import SegmentTable, TaskSet

#: Strictness slack: utilizations within EPS above 1.0 are treated as 1.0
#: to absorb float roundoff in WCET accumulation.
EPS = 1e-12


def effective_wcets(
    table: SegmentTable, preemptive: bool
) -> list[list[float]]:
    """``e_i^k`` matrix with Eq. 4 applied (xi added iff preemptive)."""
    return table.wcets(preemptive)


def stage_utilization(
    table: SegmentTable, taskset: TaskSet, k: int, preemptive: bool
) -> float:
    """Eq. 2: ``u^k = sum_i e_i^k / p_i``."""
    if len(taskset) != table.n_tasks:
        raise ValueError("taskset size != segment table size")
    return sum(
        table.wcet(i, k, preemptive) / taskset.tasks[i].period
        for i in range(table.n_tasks)
    )


def stage_utilizations(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    return [
        stage_utilization(table, taskset, k, preemptive)
        for k in range(table.n_stages)
    ]


def max_utilization(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> float:
    """The DSE objective ``max_k u^k`` (paper §4.1)."""
    return max(stage_utilizations(table, taskset, preemptive))


def srt_schedulable(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> bool:
    """Eq. 3: SRT-schedulable iff ``u^k <= 1`` for every stage.

    ``preemptive=True`` applies the EDF overhead inflation first; the
    paper notes SG+EDF loses the *iff* guarantee once overhead exists —
    passing this test with inflated WCETs restores a sufficient
    condition (overhead-inclusive utilization <= 1).
    """
    return max_utilization(table, taskset, preemptive) <= 1.0 + EPS


def utilization_headroom(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> float:
    """Max proportional period *shrink* factor keeping the system
    schedulable: scaling all periods to ``x%`` scales every ``u^k`` by
    ``1/x%`` (paper §4.1), so headroom = ``1 / max_util``.
    """
    mu = max_utilization(table, taskset, preemptive)
    return float("inf") if mu <= 0 else 1.0 / mu


def density_check(
    table: SegmentTable, taskset: TaskSet, preemptive: bool
) -> list[float]:
    """Per-task chain density ``sum_k e_i^k / p_i`` — diagnostic only.

    A task whose *chain* WCET exceeds its period still admits bounded
    response times in the SRT model (jobs of the same task may overlap
    across pipeline stages), so this is not a schedulability gate; it is
    reported because density > M signals a hopeless configuration.
    """
    out = []
    for i, t in enumerate(taskset.tasks):
        chain = sum(table.wcet(i, k, preemptive) for k in range(table.n_stages))
        out.append(chain / t.period)
    return out
