"""Task and workload model (paper §3.3).

A *workload* is an ordered sequence of DNN layers (the paper assumes each
task is, or can be topologically sorted into, a layer chain). A *task*
``tau_i = (workload, p_i, d_i)`` releases a job every ``p_i`` seconds
(or with minimum inter-arrival ``p_i`` when sporadic); we use the
implicit-deadline model ``d_i = p_i`` throughout, matching the paper.

Layers are described by their dominant matmul shape ``(M, K, N)`` plus
byte traffic so the TPU exec model (core/perfmodel) can price them on an
arbitrary stage. A `SegmentTable` holds the per-(task, stage) WCETs
``e_i^k`` produced by a concrete design point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class LayerDesc:
    """One layer of a workload, reduced to its dominant GEMM.

    ``M`` rows are "token-like" (batch x spatial), ``K`` the contraction
    dim, ``N`` the output features. ``flops``/``bytes`` default to the
    dense GEMM cost but may be overridden for non-GEMM layers (e.g. an
    SSM scan) whose cost was derived elsewhere.

    ``kind`` is advisory metadata ("mlp", "attn_qk", "moe", "scan", ...)
    used by reports; the exec model prices all kinds via flops/bytes.
    """

    name: str
    M: int
    K: int
    N: int
    kind: str = "mlp"
    flops: float = 0.0  # 0 -> derive as 2*M*K*N
    bytes_rw: float = 0.0  # 0 -> derive as dtype_bytes*(MK + KN + MN)
    dtype_bytes: int = 2

    def gemm_flops(self) -> float:
        return self.flops if self.flops > 0 else 2.0 * self.M * self.K * self.N

    def gemm_bytes(self) -> float:
        if self.bytes_rw > 0:
            return self.bytes_rw
        return float(self.dtype_bytes) * (
            self.M * self.K + self.K * self.N + self.M * self.N
        )


@dataclass(frozen=True)
class Workload:
    """A named ordered layer chain (one DNN truncation in the paper)."""

    name: str
    layers: tuple[LayerDesc, ...]

    def __post_init__(self) -> None:
        if not self.layers:
            raise ValueError(f"workload {self.name!r} has no layers")

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def total_flops(self) -> float:
        return sum(l.gemm_flops() for l in self.layers)

    def total_bytes(self) -> float:
        return sum(l.gemm_bytes() for l in self.layers)


@dataclass(frozen=True)
class Task:
    """Periodic/sporadic task ``tau_i = (e_i, p_i, d_i)`` over a workload.

    WCETs ``e_i^k`` are design-dependent; they live in `SegmentTable`,
    not here. Implicit deadline: ``d_i = p_i`` unless overridden.
    """

    workload: Workload
    period: float
    deadline: float = 0.0  # 0 -> implicit (= period)
    sporadic: bool = False
    name: str = ""

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError("period must be positive")
        if self.deadline == 0.0:
            object.__setattr__(self, "deadline", self.period)
        if not self.name:
            object.__setattr__(self, "name", self.workload.name)

    @property
    def num_layers(self) -> int:
        return self.workload.num_layers


@dataclass(frozen=True)
class TaskSet:
    """The task set ``tau`` executed on the PHAROS pipeline."""

    tasks: tuple[Task, ...]

    def __post_init__(self) -> None:
        if not self.tasks:
            raise ValueError("empty task set")

    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks)

    def hyperperiod(self) -> float:
        """LCM of periods (rationalised to microsecond grid)."""
        grid = 1e-6
        ints = [max(1, round(t.period / grid)) for t in self.tasks]
        lcm = ints[0]
        for v in ints[1:]:
            lcm = lcm * v // math.gcd(lcm, v)
        return lcm * grid


@dataclass
class SegmentTable:
    """Per-(task, stage) execution model of one concrete design.

    ``base[i][k]`` is ``b_i^k`` — the pure execution length of task i's
    segment on accelerator (stage) k, *excluding* preemption overhead
    (Eq. 4). ``overhead[k]`` is the per-stage preemption overhead
    ``xi^k = e_tile^k + e_store^k + e_load^k`` (Eq. 5) — a property of
    the stage's microarchitecture, not of the task. Stages a task skips
    have ``b_i^k == 0`` and contribute zero WCET (paper §3.4).
    """

    base: list[list[float]]  # [n_tasks][n_stages]
    overhead: list[float]  # [n_stages]
    layer_split: list[list[int]] = field(default_factory=list)
    # layer_split[i][k] = number of consecutive layers of task i on stage k

    @property
    def n_tasks(self) -> int:
        return len(self.base)

    @property
    def n_stages(self) -> int:
        return len(self.overhead)

    def wcet(self, i: int, k: int, preemptive: bool) -> float:
        """``e_i^k`` per Eq. 4: ``b + xi`` under EDF, ``b`` under FIFO.

        When the stage is skipped (``b == 0``) WCET is 0 regardless
        (paper: "when this accelerator is skipped, e_i^k is also 0").
        """
        b = self.base[i][k]
        if b <= 0.0:
            return 0.0
        return b + (self.overhead[k] if preemptive else 0.0)

    def wcets(self, preemptive: bool) -> list[list[float]]:
        return [
            [self.wcet(i, k, preemptive) for k in range(self.n_stages)]
            for i in range(self.n_tasks)
        ]

    def active_stages(self, i: int) -> list[int]:
        return [k for k in range(self.n_stages) if self.base[i][k] > 0.0]


def chain_wcets(table: SegmentTable, i: int, preemptive: bool) -> float:
    """Total WCET of task i across its pipeline chain."""
    return sum(table.wcet(i, k, preemptive) for k in range(table.n_stages))


def make_uniform_taskset(
    workloads: Sequence[Workload], periods: Sequence[float]
) -> TaskSet:
    if len(workloads) != len(periods):
        raise ValueError("workloads/periods length mismatch")
    return TaskSet(
        tasks=tuple(Task(workload=w, period=p) for w, p in zip(workloads, periods))
    )
