"""Throughput-guided (TG) DSE baseline — CHARM-style (paper §5.2).

CHARM composes heterogeneous accelerators by *GEMM-shape affinity*: it
clusters the workload's layers into M groups of similar shape, dedicates
one accelerator per group (sized by the group's FLOP share), and
optimizes each accelerator's microarchitecture for its group's
throughput. Task periods never enter the objective.

Because clustering ignores layer order, a task's layers generally visit
accelerators in non-monotone order — the *backtracking* the paper calls
out as incompatible with the guideline theory. TG designs therefore
cannot use Eq. 3 and are judged by simulation (paper: >100x period DES),
under three schedulings: FIFO w/o polling, FIFO w/ polling, EDF.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.dse.create_acc import LatencyCache
from repro.core.perfmodel.exec_model import (
    AccDesign,
    BLOCK_CANDIDATES,
    layer_latency,
    preemption_overheads,
    vmem_bytes_for_block,
)
from repro.core.perfmodel.hardware import TPU_V5E, Platform
from repro.core.rt.task import LayerDesc, SegmentTable, TaskSet, Workload


@dataclass(frozen=True)
class TGDesign:
    """A CHARM-style multi-accelerator design with per-layer mapping."""

    accs: tuple[AccDesign, ...]
    #: per task: ordered (stage, wcet) segment list, consecutive layers
    #: on the same stage collapsed; may revisit stages (backtracking)
    sequences: tuple[tuple[tuple[int, float], ...], ...]
    #: aggregated per-(task, stage) WCET table (for utilization reports)
    table: SegmentTable
    max_util: float


def _feat(layer: LayerDesc) -> tuple[float, float, float]:
    return (
        math.log2(max(layer.M, 1)),
        math.log2(max(layer.K, 1)),
        math.log2(max(layer.N, 1)),
    )


def _kmeans(feats: list[tuple[float, float, float]], k: int, iters: int = 25):
    """Deterministic k-means (quantile init over FLOP-sorted points)."""
    n = len(feats)
    k = min(k, n)
    order = sorted(range(n), key=lambda i: feats[i])
    centroids = [feats[order[(2 * j + 1) * n // (2 * k)]] for j in range(k)]
    assign = [0] * n
    for _ in range(iters):
        changed = False
        for i, f in enumerate(feats):
            best = min(
                range(k),
                key=lambda c: sum((f[d] - centroids[c][d]) ** 2 for d in range(3)),
            )
            if best != assign[i]:
                assign[i] = best
                changed = True
        for c in range(k):
            members = [feats[i] for i in range(n) if assign[i] == c]
            if members:
                centroids[c] = tuple(
                    sum(m[d] for m in members) / len(members) for d in range(3)
                )
        if not changed:
            break
    return assign


def throughput_guided_design(
    workloads: list[Workload],
    taskset: TaskSet,
    platform: Platform,
    n_accs: int = 4,
) -> TGDesign:
    """Build the TG design: shape clusters -> accelerators -> mapping."""
    layers: list[LayerDesc] = []
    owner: list[tuple[int, int]] = []  # (task, layer index)
    for ti, w in enumerate(workloads):
        for li, layer in enumerate(w.layers):
            layers.append(layer)
            owner.append((ti, li))

    assign = _kmeans([_feat(l) for l in layers], n_accs)
    used = sorted(set(assign))
    remap = {c: i for i, c in enumerate(used)}
    assign = [remap[a] for a in assign]
    k = len(used)

    # chips proportional to FLOP share (largest remainder, >= 1 each)
    flops = [0.0] * k
    for a, l in zip(assign, layers):
        flops[a] += l.gemm_flops()
    total = sum(flops) or 1.0
    raw = [f / total * platform.total_chips for f in flops]
    chips = [max(1, int(r)) for r in raw]
    while sum(chips) > platform.total_chips:
        j = max(range(k), key=lambda i: chips[i])
        chips[j] -= 1
    rema = sorted(range(k), key=lambda i: raw[i] - int(raw[i]), reverse=True)
    ri = 0
    while sum(chips) < platform.total_chips:
        chips[rema[ri % k]] += 1
        ri += 1

    # per-cluster block: throughput objective (min total latency)
    accs = []
    for c in range(k):
        mine = [l for a, l in zip(assign, layers) if a == c]
        best, best_t = None, float("inf")
        for block in BLOCK_CANDIDATES:
            if vmem_bytes_for_block(block) > TPU_V5E.vmem_bytes:
                continue
            acc = AccDesign(chips=chips[c], block=block)
            t = sum(layer_latency(l, acc) for l in mine)
            if t < best_t:
                best, best_t = acc, t
        accs.append(best)
    accs = tuple(accs)

    # per-task (stage, wcet) sequences with consecutive collapse
    sequences = []
    n_tasks = len(workloads)
    base = [[0.0] * k for _ in range(n_tasks)]
    split = [[0] * k for _ in range(n_tasks)]
    pos = 0
    for ti, w in enumerate(workloads):
        seq: list[list] = []
        for li, layer in enumerate(w.layers):
            c = assign[pos]
            lat = layer_latency(layer, accs[c])
            base[ti][c] += lat
            split[ti][c] += 1
            if seq and seq[-1][0] == c:
                seq[-1][1] += lat
            else:
                seq.append([c, lat])
            pos += 1
        sequences.append(tuple((s, t) for s, t in seq))

    overhead = [sum(preemption_overheads(a)) for a in accs]
    table = SegmentTable(base=base, overhead=overhead, layer_split=split)
    from repro.core.rt.schedulability import max_utilization

    return TGDesign(
        accs=accs,
        sequences=tuple(sequences),
        table=table,
        max_util=max_utilization(table, taskset, preemptive=False),
    )


def tg_simtasks(design: TGDesign, taskset: TaskSet):
    """SimTask list for the DES (preserves backtracking order)."""
    from repro.scheduler.des import SimTask

    return [
        SimTask(segments=design.sequences[i], period=t.period, name=t.name)
        for i, t in enumerate(taskset.tasks)
    ]
