"""`explore` — the unified DSE driver.

The seed repo had three disconnected entry points: `beam_search`,
`brute_force_search` (a copy of beam with ``B = +inf``) and
`throughput_guided_design` (the CHARM-style TG baseline), each with its
own result shape and hard-coded objective. `explore` makes them
**configurations of one driver**:

- ``method="beam"`` / ``method="brute"`` run the (batched) beam core —
  brute is literally ``beam_width=None`` — under a pluggable
  `Objective`/`Constraint` pair (default: the paper's SRT
  configuration, `MinMaxUtil` + `Eq3Constraint`);
- ``method="tg"`` runs the throughput-guided clustering under the
  `TotalLatency` objective. TG designs backtrack, so Eq. 3 does not
  apply to them; `ExploreResult.tg_eq2_feasible` reports the Eq. 2
  utilization gate and the DES remains their schedulability oracle
  (`benchmarks/fig6_sg_vs_tg.py`).

Every method returns an `ExploreResult` carrying the same `BeamStats`
(wall time, candidates evaluated, evaluated-candidates/sec), so
SRT-vs-TG comparisons — Fig. 6, `benchmarks/dse_bench.py` — read one
result type instead of three.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

from repro.core.dse.beam import BeamResult, BeamStats, beam_search
from repro.core.dse.objective import (
    Constraint,
    Eq3Constraint,
    MinMaxUtil,
    Objective,
    TotalLatency,
)
from repro.core.dse.space import DesignPoint
from repro.core.dse.throughput import TGDesign, throughput_guided_design
from repro.core.perfmodel.hardware import Platform
from repro.core.rt.task import TaskSet, Workload

METHODS = ("beam", "brute", "tg")


@dataclass(frozen=True)
class DSEConfig:
    """One search configuration for `explore`."""

    method: str = "beam"
    #: None -> the method's default (`MinMaxUtil` for beam/brute — the
    #: paper's SRT-guided search — and `TotalLatency` for tg)
    objective: Objective | None = None
    constraint: Constraint = field(default_factory=Eq3Constraint)
    max_m: int = 4
    beam_width: int | None = 8
    max_frontier: int = 200_000
    #: TG only: number of shape clusters / accelerators
    n_accs: int = 4
    evaluator: str = "batched"
    #: beam/brute: allow split boundaries only every this many layers
    #: (1 = the paper's exact layer-granular space; coarsen for long
    #: flattened LM chains)
    split_stride: int = 1

    def __post_init__(self) -> None:
        if self.method not in METHODS:
            raise ValueError(
                f"unknown DSE method {self.method!r}; have {METHODS}"
            )

    def resolved_objective(self) -> Objective:
        if self.objective is not None:
            return self.objective
        return TotalLatency() if self.method == "tg" else MinMaxUtil()


@dataclass
class ExploreResult:
    """Unified result of one `explore` run."""

    method: str
    objective: str
    #: every feasible complete design found (beam/brute; empty for tg)
    succ_pts: list[DesignPoint]
    #: objective-best feasible design (beam/brute; None for tg)
    best: DesignPoint | None
    stats: BeamStats
    #: the TG design (tg method only)
    tg: TGDesign | None = None
    #: `Objective.score` of the returned design, in the objective's own
    #: units for every method (None when no design was found) — the
    #: cross-configuration comparison value
    score: float | None = None

    @property
    def feasible_found(self) -> int:
        return self.stats.feasible_found

    @property
    def tg_eq2_feasible(self) -> bool:
        """Eq. 2 gate for the TG design (``max_util <= 1``); NOT an
        SRT-schedulability verdict — TG backtracks, so the guideline
        theory does not apply and the DES stays the oracle."""
        if self.tg is None:
            return False
        return self.tg.max_util <= 1.0 + 1e-12

    def as_beam_result(self) -> BeamResult:
        """Back-compat view for callers holding a `BeamResult`."""
        return BeamResult(
            succ_pts=self.succ_pts, best=self.best, stats=self.stats
        )


def explore(
    workloads: list[Workload],
    taskset: TaskSet,
    platform: Platform,
    cfg: DSEConfig | None = None,
    **overrides,
) -> ExploreResult:
    """Run one DSE configuration; keyword overrides patch ``cfg``
    (e.g. ``explore(wls, ts, plat, method="brute", max_m=3)``)."""
    cfg = cfg or DSEConfig()
    if overrides:
        cfg = replace(cfg, **overrides)
    objective = cfg.resolved_objective()

    if cfg.method in ("beam", "brute"):
        res = beam_search(
            workloads,
            taskset,
            platform,
            max_m=cfg.max_m,
            beam_width=None if cfg.method == "brute" else cfg.beam_width,
            max_frontier=cfg.max_frontier,
            objective=objective,
            constraint=cfg.constraint,
            evaluator=cfg.evaluator,
            split_stride=cfg.split_stride,
        )
        score = None
        if res.best is not None:
            from repro.core.dse.space import evaluate_design

            score = objective.score(
                evaluate_design(
                    res.best.accs, res.best.splits, workloads, taskset
                ),
                taskset,
            )
        return ExploreResult(
            method=cfg.method,
            objective=objective.name,
            succ_pts=res.succ_pts,
            best=res.best,
            stats=res.stats,
            score=score,
        )

    # -- tg: CHARM-style clustering under the throughput objective ----
    from repro.core.dse.create_acc import _VALID_BLOCKS

    t0 = time.perf_counter()
    tg = throughput_guided_design(
        workloads, taskset, platform, n_accs=cfg.n_accs
    )
    wall = time.perf_counter() - t0
    # the TG inner loop prices every (cluster, valid block) accelerator
    # candidate once — the analogue of the beam's create_acc count
    evals = len(tg.accs) * len(_VALID_BLOCKS)
    stats = BeamStats(
        create_acc_calls=evals,
        wall_time_s=wall,
        eval_seconds=wall,
        feasible_found=0,
        evaluator="scalar",
    )
    return ExploreResult(
        method="tg",
        objective=objective.name,
        succ_pts=[],
        best=None,
        stats=stats,
        tg=tg,
        score=objective.score(tg.table, taskset),
    )
