"""PHAROS beam search (paper Algorithm 1, §4.2).

Iteratively creates accelerators: each parent carries the layers/chips
already committed; extending it assigns a new accelerator some chips and
a consecutive slice of every task's remaining layers. The unassigned
remainder forms a synthetic ``remain_acc`` whose utilization (a) guides
child ranking and (b), when it drops to <= 1, turns the remainder into a
real accelerator and yields a *feasible* complete design (lines 13-14).
Children whose new accelerator already exceeds utilization 1 are pruned
(line 11); children whose remainder exceeds 1 are retained for further
partitioning (line 12). Top-``B`` children by max-utilization survive
each iteration.

``beam_width=None`` gives the brute-force BFS baseline (B = +inf,
paper §5.4) used by `repro.core.dse.brute`.

Evaluation is **batched**: each iteration enumerates every child of
every parent, then prices all the new accelerators in one
`BatchedDesignEvaluator.evaluate` call and all surviving remainders in
a second (``evaluator="scalar"`` keeps the per-child `create_acc` loop
for differential tests and the `benchmarks/dse_bench.py` baseline).
Both paths are bit-identical — the batched evaluator reproduces the
scalar floats exactly — so the search visits the same nodes, keeps the
same frontier and returns the same winner either way. Pruning,
feasibility and ranking are delegated to the `repro.core.dse.objective`
layer; the defaults reproduce the paper's SRT-guided search.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.dse.batch_eval import BatchedDesignEvaluator
from repro.core.dse.create_acc import (
    _VALID_BLOCKS,
    LatencyCache,
    create_acc,
)
from repro.core.dse.objective import Constraint, Eq3Constraint, MinMaxUtil, Objective
from repro.core.dse.space import DesignPoint, evaluate_design
from repro.core.perfmodel.exec_model import AccDesign
from repro.core.perfmodel.hardware import Platform
from repro.core.rt.task import TaskSet, Workload

_EVALUATORS = ("batched", "scalar")


@dataclass
class BeamStats:
    create_acc_calls: int = 0
    children_generated: int = 0
    parents_expanded: int = 0
    wall_time_s: float = 0.0
    first_feasible_time_s: float | None = None
    feasible_found: int = 0
    #: wall seconds spent inside the candidate evaluator (batched or
    #: scalar) — the denominator of `candidates_per_sec`
    eval_seconds: float = 0.0
    evaluator: str = "batched"

    @property
    def candidates_evaluated(self) -> int:
        """Accelerator candidates priced (alias of `create_acc_calls`:
        the batched evaluator performs the same per-candidate work in
        bulk)."""
        return self.create_acc_calls

    @property
    def candidates_per_sec(self) -> float:
        """Evaluated-candidates/sec throughput of the evaluator core."""
        if self.eval_seconds <= 0.0:
            return 0.0
        return self.create_acc_calls / self.eval_seconds


@dataclass
class BeamResult:
    succ_pts: list[DesignPoint]
    best: DesignPoint | None
    stats: BeamStats = field(default_factory=BeamStats)


@dataclass(frozen=True)
class _Node:
    assigned: tuple[int, ...]  # layers committed per task (paper's l)
    chips_used: int  # paper's r
    accs: tuple[AccDesign, ...]
    splits: tuple[tuple[int, ...], ...]  # per stage: layer counts per task
    created_max_util: float  # max util among committed accelerators
    guide: float  # ranking key: objective.guide(created, remain)


class _ScalarEvaluator:
    """Per-candidate `create_acc` loop with the batched call signature —
    the pre-refactor inner loop, kept as the differential baseline."""

    def __init__(self, workloads, taskset, cache: LatencyCache):
        self.taskset = taskset
        self.cache = cache
        self._block_index = {b: i for i, b in enumerate(_VALID_BLOCKS)}

    def evaluate(self, spans, chips):
        C = len(chips)
        util = np.empty(C)
        block_idx = np.empty(C, dtype=np.int64)
        for j in range(C):
            acc, u, _lats = create_acc(
                tuple((int(a), int(b)) for a, b in spans[j]),
                int(chips[j]),
                self.taskset,
                self.cache,
            )
            util[j] = u
            block_idx[j] = self._block_index.get(acc.block, 0)
        return util, block_idx, None


def beam_search(
    workloads: list[Workload],
    taskset: TaskSet,
    platform: Platform,
    max_m: int = 4,
    beam_width: int | None = 8,
    max_frontier: int = 200_000,
    *,
    objective: Objective | None = None,
    constraint: Constraint | None = None,
    evaluator: str = "batched",
    split_stride: int = 1,
) -> BeamResult:
    """Algorithm 1. Returns every feasible design found plus the best.

    ``split_stride`` coarsens the split grid for long layer chains:
    slice boundaries are only allowed every ``split_stride`` layers
    from each parent's frontier (a task's full remainder is always
    takeable). ``1`` (default) is the paper's exact layer-granular
    space; an LM chain of hundreds of flattened layers needs a coarser
    grid to keep the child frontier tractable (`examples/dse_pipeline.py`).
    """
    if len(workloads) != len(taskset):
        raise ValueError("workloads/taskset mismatch")
    if split_stride < 1:
        raise ValueError("split_stride must be >= 1")
    if evaluator not in _EVALUATORS:
        raise ValueError(
            f"unknown evaluator {evaluator!r}; have {_EVALUATORS}"
        )
    objective = objective or MinMaxUtil()
    constraint = constraint or Eq3Constraint()
    t0 = time.perf_counter()
    n = len(workloads)
    L = tuple(w.num_layers for w in workloads)
    R = platform.total_chips
    cache = LatencyCache(workloads)
    ev = (
        BatchedDesignEvaluator(workloads, taskset, cache=cache)
        if evaluator == "batched"
        else _ScalarEvaluator(workloads, taskset, cache)
    )
    stats = BeamStats(evaluator=evaluator)
    succ: list[DesignPoint] = []
    best: DesignPoint | None = None

    def eval_batch(spans: np.ndarray, chips: np.ndarray):
        te = time.perf_counter()
        util, block_idx, _lats = ev.evaluate(spans, chips)
        stats.eval_seconds += time.perf_counter() - te
        stats.create_acc_calls += len(chips)
        return util, block_idx

    best_rank = float("inf")

    def accept(dp: DesignPoint, rank_val: float) -> None:
        """Feasibility gate + objective-ranked best tracking.
        ``rank_val`` is `Objective.rank` over the design's two batched
        metrics — max_util for the SRT objective, summed chain latency
        for the throughput objective."""
        nonlocal best, best_rank
        if not constraint.accepts(dp.max_util):
            return
        succ.append(dp)
        stats.feasible_found += 1
        if stats.first_feasible_time_s is None:
            stats.first_feasible_time_s = time.perf_counter() - t0
        if best is None or rank_val < best_rank:
            best = dp
            best_rank = rank_val

    # feasible completions are collected during the walk and scored in
    # one batched `design_metrics` call per iteration (bit-identical
    # to the scalar `evaluate_design` path, which the scalar evaluator
    # still runs inline as the differential baseline)
    pending_feasible: list[tuple[tuple[AccDesign, ...], tuple]] = []

    def note_feasible(
        accs: tuple[AccDesign, ...], splits: tuple[tuple[int, ...], ...]
    ) -> None:
        if evaluator == "batched":
            pending_feasible.append((accs, splits))
            return
        from repro.core.rt.schedulability import max_utilization

        table = evaluate_design(accs, splits, workloads, taskset)
        mu = max_utilization(table, taskset, preemptive=False)
        total = sum(sum(row) for row in table.base)
        accept(
            DesignPoint(accs=accs, splits=splits, max_util=mu),
            objective.rank(mu, total),
        )

    def flush_feasible() -> None:
        if not pending_feasible:
            return
        te = time.perf_counter()
        mus, totals = ev.design_metrics(pending_feasible)
        stats.eval_seconds += time.perf_counter() - te
        for (accs, splits), mu, total in zip(pending_feasible, mus, totals):
            accept(
                DesignPoint(accs=accs, splits=splits, max_util=float(mu)),
                objective.rank(float(mu), float(total)),
            )
        pending_feasible.clear()

    # AccDesign is frozen; share one instance per (chips, block) so the
    # walk does not rebuild ~10^5 identical dataclasses on brute runs
    acc_cache: dict[tuple[int, int], AccDesign] = {}

    def make_acc(chips: int, block_idx: int) -> AccDesign:
        key = (chips, block_idx)
        acc = acc_cache.get(key)
        if acc is None:
            acc = AccDesign(chips=chips, block=_VALID_BLOCKS[block_idx])
            acc_cache[key] = acc
        return acc

    root = _Node(
        assigned=(0,) * n,
        chips_used=0,
        accs=(),
        splits=(),
        created_max_util=0.0,
        guide=float("inf"),
    )
    parents: list[_Node] = [root]

    L_arr = np.asarray(L, dtype=np.int64)

    for _m in range(2, max_m + 1):
        # -- enumerate every child of every parent as arrays (same
        # nested order as the scalar seed loop: parent, then chip
        # budget, then the per-task slice product — `np.meshgrid`
        # with ``indexing="ij"`` reshapes to exactly
        # `itertools.product`'s last-range-fastest order, and the
        # budget cross is budget-major, slices within). Building the
        # candidate set as array blocks instead of one Python tuple
        # per child is what keeps enumeration off the profile now
        # that evaluation itself is batched. ---------------------------
        blk_nvec: list[np.ndarray] = []  # [C_p, n] slice frontiers
        blk_chips: list[np.ndarray] = []  # [C_p] new-acc budgets
        blk_left_sum: list[np.ndarray] = []  # [C_p] remainder sizes
        blk_parent: list[np.ndarray] = []  # [C_p] parent index
        blk_spans: list[np.ndarray] = []  # [C_p, n, 2] eval spans
        for pi, parent in enumerate(parents):
            stats.parents_expanded += 1
            l, r = parent.assigned, parent.chips_used
            remaining = tuple(L[i] - l[i] for i in range(n))
            if sum(remaining) == 0:
                continue
            budget = R - r
            if budget < 1:
                continue  # no chips left: the seed's empty budget range
            # the consecutive-slice takes per task do not depend on the
            # chip budget — enumerate them once per parent, then cross
            # with every budget in the seed's (chips, nvec) order
            if split_stride == 1:
                ranges = [range(l[i], L[i] + 1) for i in range(n)]
            else:
                ranges = [
                    list(range(l[i], L[i] + 1, split_stride))
                    + ([L[i]] if (L[i] - l[i]) % split_stride else [])
                    for i in range(n)
                ]
            grids = np.meshgrid(
                *[np.asarray(rg, dtype=np.int64) for rg in ranges],
                indexing="ij",
            )
            nvec_grid = np.stack(
                [g.reshape(-1) for g in grids], axis=1
            )  # [S, n], product order
            l_row = np.asarray(l, dtype=np.int64)
            nvec_grid = nvec_grid[(nvec_grid - l_row).sum(axis=1) > 0]
            if not len(nvec_grid):
                continue
            left_sum_grid = (L_arr - nvec_grid).sum(axis=1)
            # budgets 1..budget-1 keep >= 1 chip for the remainder, so
            # every slice passes the seed's resource filter; at the
            # full budget (chips_left == 0) only complete slices
            # (left_sum == 0) survive it
            S = len(nvec_grid)
            parts_nvec, parts_chips, parts_ls = [], [], []
            if budget > 1:
                parts_nvec.append(np.tile(nvec_grid, (budget - 1, 1)))
                parts_chips.append(
                    np.repeat(np.arange(1, budget, dtype=np.int64), S)
                )
                parts_ls.append(np.tile(left_sum_grid, budget - 1))
            complete = np.flatnonzero(left_sum_grid == 0)
            if len(complete):
                parts_nvec.append(nvec_grid[complete])
                parts_chips.append(
                    np.full(len(complete), budget, dtype=np.int64)
                )
                parts_ls.append(np.zeros(len(complete), dtype=np.int64))
            if not parts_nvec:
                continue
            nvec_p = np.concatenate(parts_nvec, axis=0)
            spans_p = np.empty((len(nvec_p), n, 2), dtype=np.int64)
            spans_p[:, :, 0] = l_row
            spans_p[:, :, 1] = nvec_p
            blk_nvec.append(nvec_p)
            blk_chips.append(np.concatenate(parts_chips))
            blk_left_sum.append(np.concatenate(parts_ls))
            blk_parent.append(
                np.full(len(nvec_p), pi, dtype=np.int64)
            )
            blk_spans.append(spans_p)

        children: dict[tuple, _Node] = {}
        if blk_nvec:
            nvec_all = np.concatenate(blk_nvec, axis=0)
            chips_all = np.concatenate(blk_chips)
            left_sum_all = np.concatenate(blk_left_sum)
            parent_all = np.concatenate(blk_parent)
            spans_new = np.concatenate(blk_spans, axis=0)
            # chips_used is constant per parent block, so the leftover
            # budget is recoverable without a per-candidate walk
            used_by_parent = np.asarray(
                [p.chips_used for p in parents], dtype=np.int64
            )
            chips_left_all = R - used_by_parent[parent_all] - chips_all

            # -- batch 1: price every child's new accelerator ----------
            utils_new, blocks_new = eval_batch(spans_new, chips_all)
            surv = ~constraint.prunes_batch(utils_new)  # line 11: prune

            # -- batch 2: price the remainders of surviving children ---
            rem_of = np.full(len(chips_all), -1, dtype=np.int64)
            rem_sel = np.flatnonzero(surv & (left_sum_all > 0))
            if len(rem_sel):
                spans_rem = np.empty(
                    (len(rem_sel), n, 2), dtype=np.int64
                )
                spans_rem[:, :, 0] = nvec_all[rem_sel]
                spans_rem[:, :, 1] = L_arr
                chips_rem = chips_left_all[rem_sel]
                rem_of[rem_sel] = np.arange(len(rem_sel))
                utils_rem, blocks_rem = eval_batch(spans_rem, chips_rem)

            # -- walk the *surviving* candidates in enumeration order
            # (identical feasibility / dedup / frontier bookkeeping to
            # the seed — the pruned majority is never touched) ---------
            for j in np.flatnonzero(surv):
                parent = parents[int(parent_all[j])]
                chips_new = int(chips_all[j])
                chips_left = int(chips_left_all[j])
                nvec = tuple(int(x) for x in nvec_all[j])
                take = tuple(
                    v - a for v, a in zip(nvec, parent.assigned)
                )
                left = tuple(int(x) for x in L_arr - nvec_all[j])
                left_sum = int(left_sum_all[j])
                new_acc = make_acc(chips_new, int(blocks_new[j]))
                accs = parent.accs + (new_acc,)
                splits = parent.splits + (take,)
                cmax = max(parent.created_max_util, float(utils_new[j]))
                if left_sum == 0:
                    # new accelerator consumed everything: complete
                    note_feasible(accs, splits)
                    continue
                t = int(rem_of[j])
                rem_util = float(utils_rem[t])
                if constraint.completes(rem_util):
                    # lines 13-14: feasible completion
                    rem_acc = make_acc(chips_left, int(blocks_rem[t]))
                    note_feasible(accs + (rem_acc,), splits + (left,))
                # line 12: retain for further partitioning. Guide =
                # objective's admissible balance estimate over the
                # stages still available (scoring the remainder as ONE
                # accelerator systematically prunes children whose
                # remainder is heavy but splittable).
                stages_left = max(1, max_m - len(accs))
                node = _Node(
                    assigned=nvec,
                    chips_used=parent.chips_used + chips_new,
                    accs=accs,
                    splits=splits,
                    created_max_util=cmax,
                    guide=objective.guide(cmax, rem_util, stages_left),
                )
                key = (nvec, parent.chips_used + chips_new, splits)
                prev = children.get(key)
                if prev is None or node.guide < prev.guide:
                    children[key] = node
                stats.children_generated += 1
                if len(children) > max_frontier:
                    raise RuntimeError(
                        "frontier exceeded max_frontier; "
                        "use a beam width for this problem size"
                    )
        flush_feasible()
        ranked = sorted(children.values(), key=lambda c: c.guide)
        if beam_width is None:
            parents = ranked
        else:
            # diverse top-B: prefer distinct layer frontiers (siblings
            # that differ only in chip split crowd out genuinely
            # different partitions otherwise), then fill remaining slots
            # with the best leftovers.
            picked, seen_assigned, leftovers = [], set(), []
            for node in ranked:
                if len(picked) >= beam_width:
                    break
                if node.assigned in seen_assigned:
                    leftovers.append(node)
                else:
                    seen_assigned.add(node.assigned)
                    picked.append(node)
            for node in leftovers:
                if len(picked) >= beam_width:
                    break
                picked.append(node)
            parents = picked
        if not parents:
            break

    stats.wall_time_s = time.perf_counter() - t0
    # deduplicate succ_pts (same splits + chips allocation)
    seen, unique = set(), []
    for dp in sorted(succ, key=lambda d: d.max_util):
        key = (dp.splits, tuple(a.chips for a in dp.accs))
        if key not in seen:
            seen.add(key)
            unique.append(dp)
    return BeamResult(succ_pts=unique, best=best, stats=stats)
