"""PHAROS beam search (paper Algorithm 1, §4.2).

Iteratively creates accelerators: each parent carries the layers/chips
already committed; extending it assigns a new accelerator some chips and
a consecutive slice of every task's remaining layers. The unassigned
remainder forms a synthetic ``remain_acc`` whose utilization (a) guides
child ranking and (b), when it drops to <= 1, turns the remainder into a
real accelerator and yields a *feasible* complete design (lines 13-14).
Children whose new accelerator already exceeds utilization 1 are pruned
(line 11); children whose remainder exceeds 1 are retained for further
partitioning (line 12). Top-``B`` children by max-utilization survive
each iteration.

``beam_width=None`` gives the brute-force BFS baseline (B = +inf,
paper §5.4) used by `repro.core.dse.brute`.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field

from repro.core.dse.create_acc import LatencyCache, Span, create_acc
from repro.core.dse.space import DesignPoint, design_from_splits
from repro.core.perfmodel.exec_model import AccDesign
from repro.core.perfmodel.hardware import Platform
from repro.core.rt.task import TaskSet, Workload


@dataclass
class BeamStats:
    create_acc_calls: int = 0
    children_generated: int = 0
    parents_expanded: int = 0
    wall_time_s: float = 0.0
    first_feasible_time_s: float | None = None
    feasible_found: int = 0


@dataclass
class BeamResult:
    succ_pts: list[DesignPoint]
    best: DesignPoint | None
    stats: BeamStats = field(default_factory=BeamStats)


@dataclass(frozen=True)
class _Node:
    assigned: tuple[int, ...]  # layers committed per task (paper's l)
    chips_used: int  # paper's r
    accs: tuple[AccDesign, ...]
    splits: tuple[tuple[int, ...], ...]  # per stage: layer counts per task
    created_max_util: float  # max util among committed accelerators
    guide: float  # ranking key: max(created, remain) util


def beam_search(
    workloads: list[Workload],
    taskset: TaskSet,
    platform: Platform,
    max_m: int = 4,
    beam_width: int | None = 8,
    max_frontier: int = 200_000,
) -> BeamResult:
    """Algorithm 1. Returns every feasible design found plus the best."""
    if len(workloads) != len(taskset):
        raise ValueError("workloads/taskset mismatch")
    t0 = time.perf_counter()
    n = len(workloads)
    L = tuple(w.num_layers for w in workloads)
    R = platform.total_chips
    cache = LatencyCache(workloads)
    stats = BeamStats()
    succ: list[DesignPoint] = []
    best: DesignPoint | None = None

    def note_feasible(
        accs: tuple[AccDesign, ...], splits: tuple[tuple[int, ...], ...]
    ) -> None:
        nonlocal best
        dp = design_from_splits(accs, splits, workloads, taskset)
        if dp.max_util > 1.0 + 1e-12:
            return
        succ.append(dp)
        stats.feasible_found += 1
        if stats.first_feasible_time_s is None:
            stats.first_feasible_time_s = time.perf_counter() - t0
        if best is None or dp.max_util < best.max_util:
            best = dp

    root = _Node(
        assigned=(0,) * n,
        chips_used=0,
        accs=(),
        splits=(),
        created_max_util=0.0,
        guide=float("inf"),
    )
    parents: list[_Node] = [root]

    for _m in range(2, max_m + 1):
        children: dict[tuple, _Node] = {}
        for parent in parents:
            stats.parents_expanded += 1
            l, r = parent.assigned, parent.chips_used
            remaining = tuple(L[i] - l[i] for i in range(n))
            if sum(remaining) == 0:
                continue
            # enumerate the new accelerator's chip budget
            for chips_new in range(1, R - r + 1):
                chips_left = R - r - chips_new
                # enumerate consecutive-slice takes per task
                ranges = [range(l[i], L[i] + 1) for i in range(n)]
                for nvec in itertools.product(*ranges):
                    take = tuple(nvec[i] - l[i] for i in range(n))
                    if sum(take) == 0:
                        continue
                    left = tuple(L[i] - nvec[i] for i in range(n))
                    if sum(left) > 0 and chips_left < 1:
                        continue  # remainder would have no resources
                    spans = tuple((l[i], nvec[i]) for i in range(n))
                    new_acc, new_util, _ = create_acc(
                        spans, chips_new, taskset, cache
                    )
                    stats.create_acc_calls += 1
                    if new_util > 1.0:  # line 11: prune
                        continue
                    accs = parent.accs + (new_acc,)
                    splits = parent.splits + (take,)
                    cmax = max(parent.created_max_util, new_util)
                    if sum(left) == 0:
                        # new accelerator consumed everything: complete
                        note_feasible(accs, splits)
                        continue
                    rem_spans = tuple((nvec[i], L[i]) for i in range(n))
                    rem_acc, rem_util, _ = create_acc(
                        rem_spans, chips_left, taskset, cache
                    )
                    stats.create_acc_calls += 1
                    if rem_util <= 1.0:  # lines 13-14: feasible completion
                        note_feasible(accs + (rem_acc,), splits + (left,))
                    # line 12: retain for further partitioning. Guide =
                    # utilization the completed design could reach if the
                    # remainder split perfectly over the stages still
                    # available (admissible balance estimate — scoring the
                    # remainder as ONE accelerator systematically prunes
                    # children whose remainder is heavy but splittable).
                    stages_left = max(1, max_m - len(accs))
                    node = _Node(
                        assigned=nvec,
                        chips_used=r + chips_new,
                        accs=accs,
                        splits=splits,
                        created_max_util=cmax,
                        guide=max(cmax, rem_util / stages_left),
                    )
                    key = (nvec, r + chips_new, splits)
                    prev = children.get(key)
                    if prev is None or node.guide < prev.guide:
                        children[key] = node
                    stats.children_generated += 1
                    if len(children) > max_frontier:
                        raise RuntimeError(
                            "frontier exceeded max_frontier; "
                            "use a beam width for this problem size"
                        )
        ranked = sorted(children.values(), key=lambda c: c.guide)
        if beam_width is None:
            parents = ranked
        else:
            # diverse top-B: prefer distinct layer frontiers (siblings
            # that differ only in chip split crowd out genuinely
            # different partitions otherwise), then fill remaining slots
            # with the best leftovers.
            picked, seen_assigned, leftovers = [], set(), []
            for node in ranked:
                if len(picked) >= beam_width:
                    break
                if node.assigned in seen_assigned:
                    leftovers.append(node)
                else:
                    seen_assigned.add(node.assigned)
                    picked.append(node)
            for node in leftovers:
                if len(picked) >= beam_width:
                    break
                picked.append(node)
            parents = picked
        if not parents:
            break

    stats.wall_time_s = time.perf_counter() - t0
    # deduplicate succ_pts (same splits + chips allocation)
    seen, unique = set(), []
    for dp in sorted(succ, key=lambda d: d.max_util):
        key = (dp.splits, tuple(a.chips for a in dp.accs))
        if key not in seen:
            seen.add(key)
            unique.append(dp)
    return BeamResult(succ_pts=unique, best=best, stats=stats)
