"""Unified objective / constraint layer for the DSE.

The seed code had three parallel search paths — beam, brute, TG — each
with its own hard-coded notion of "good" and "feasible". This module
factors those notions out:

- an `Objective` scores a complete design (lower is better) and supplies
  the beam's child-ranking guide;
- a `Constraint` decides which candidates are pruned mid-search and
  which complete designs count as feasible.

`beam_search` / `explore` take both as parameters; the defaults
(`MinMaxUtil` + `Eq3Constraint`) reproduce the paper's SRT-guided
search decision-for-decision, and `TotalLatency` is the CHARM-style
throughput objective the TG configuration reports. The constants here
are the exact literals the scalar seed code used, so the default
configuration is bit-compatible with the pre-refactor search.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover - typing only
    import numpy as np

    from repro.core.dse.space import DesignPoint
    from repro.core.rt.task import SegmentTable, TaskSet

#: feasibility float tolerance on the objective cap (the seed's
#: ``max_util <= 1.0 + 1e-12`` accept gate in ``note_feasible``)
FEASIBLE_EPS = 1e-12


@runtime_checkable
class Objective(Protocol):
    """Scores designs; lower is better."""

    name: str

    def score(self, table: "SegmentTable", taskset: "TaskSet") -> float:
        """Score a materialized design from its WCET table — the
        authoritative objective value, in the objective's own units."""
        ...

    def rank(self, max_util: float, total_latency: float) -> float:
        """Best-design selection key from the two batched per-design
        metrics the search computes for every feasible completion
        (max stage utilization and summed chain latency)."""
        ...

    def guide(
        self, created_max: float, rem_util: float, stages_left: int
    ) -> float:
        """Beam ranking key for a partial design (lower expands first)."""
        ...


@runtime_checkable
class Constraint(Protocol):
    """Feasibility gates applied during and after the search."""

    name: str

    def prunes(self, util: float) -> bool:
        """Drop a child whose new accelerator reached this utilization."""
        ...

    def prunes_batch(self, utils: "np.ndarray") -> "np.ndarray":
        """Vectorized `prunes` over a candidate batch."""
        ...

    def completes(self, rem_util: float) -> bool:
        """May the remainder close out a feasible design at this util?"""
        ...

    def accepts(self, max_util: float) -> bool:
        """Is a complete design with this max utilization feasible?"""
        ...


@dataclass(frozen=True)
class MinMaxUtil:
    """The paper's SRT objective (§4.1): minimize ``max_k u^k``.

    The guide is the seed beam's admissible balance estimate — the
    utilization the completed design could reach if the remainder split
    perfectly over the stages still available.
    """

    name: str = "min_max_util"

    def score(self, table, taskset) -> float:
        from repro.core.rt.schedulability import max_utilization

        return max_utilization(table, taskset, preemptive=False)

    def rank(self, max_util: float, total_latency: float) -> float:
        return max_util

    def guide(
        self, created_max: float, rem_util: float, stages_left: int
    ) -> float:
        return max(created_max, rem_util / stages_left)


@dataclass(frozen=True)
class TotalLatency:
    """CHARM-style throughput objective: minimize the summed chain
    latency ``sum_i sum_k b_i^k`` (periods never enter — that is the
    point of the TG baseline). As a beam guide it still ranks by the
    balance estimate: latency alone cannot order partial designs whose
    remainders differ in splittability.
    """

    name: str = "total_latency"

    def score(self, table, taskset) -> float:
        return sum(sum(row) for row in table.base)

    def rank(self, max_util: float, total_latency: float) -> float:
        return total_latency

    def guide(
        self, created_max: float, rem_util: float, stages_left: int
    ) -> float:
        return max(created_max, rem_util / stages_left)


@dataclass(frozen=True)
class Eq3Constraint:
    """Per-stage utilization cap (paper Eq. 3 at ``cap == 1.0``).

    ``prunes``/``completes`` use the strict seed literals (``> cap`` /
    ``<= cap``); ``accepts`` allows the seed's ``FEASIBLE_EPS`` float
    slack on complete designs. A deployment wanting analysis margin can
    search at e.g. ``cap=0.9`` — every claimed-feasible design then
    arrives with 10% of Eq. 2 budget still unspent on every stage.
    """

    cap: float = 1.0
    name: str = "eq3"

    def prunes(self, util: float) -> bool:
        return util > self.cap

    def prunes_batch(self, utils):
        return utils > self.cap

    def completes(self, rem_util: float) -> bool:
        return rem_util <= self.cap

    def accepts(self, max_util: float) -> bool:
        return max_util <= self.cap + FEASIBLE_EPS


#: the default (paper) configuration
SRT_OBJECTIVE = MinMaxUtil()
TG_OBJECTIVE = TotalLatency()
EQ3 = Eq3Constraint()
