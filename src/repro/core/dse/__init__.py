"""PHAROS design-space exploration (paper §4)."""
from repro.core.dse.space import (
    DesignPoint,
    design_from_splits,
    evaluate_design,
    fixed_design,
)
from repro.core.dse.create_acc import LatencyCache, create_acc
from repro.core.dse.beam import BeamResult, BeamStats, beam_search
from repro.core.dse.brute import brute_force_search
from repro.core.dse.throughput import (
    TGDesign,
    throughput_guided_design,
    tg_simtasks,
)

__all__ = [
    "DesignPoint",
    "design_from_splits",
    "evaluate_design",
    "fixed_design",
    "LatencyCache",
    "create_acc",
    "BeamResult",
    "BeamStats",
    "beam_search",
    "brute_force_search",
    "TGDesign",
    "throughput_guided_design",
    "tg_simtasks",
]
