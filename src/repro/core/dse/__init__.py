"""PHAROS design-space exploration (paper §4).

`explore` is the unified driver (SRT-guided beam/brute and the TG
baseline as configurations of one entry point); `provision` bridges a
search result into the serving stack (scenario + sharded gateway).
"""
from repro.core.dse.space import (
    DesignPoint,
    design_from_splits,
    evaluate_design,
    fixed_design,
)
from repro.core.dse.create_acc import LatencyCache, create_acc
from repro.core.dse.batch_eval import BatchedDesignEvaluator, resolve_acc
from repro.core.dse.objective import (
    Constraint,
    Eq3Constraint,
    MinMaxUtil,
    Objective,
    TotalLatency,
)
from repro.core.dse.beam import BeamResult, BeamStats, beam_search
from repro.core.dse.brute import brute_force_search
from repro.core.dse.explore import DSEConfig, ExploreResult, explore
from repro.core.dse.provision import ProvisionPlan, provision
from repro.core.dse.throughput import (
    TGDesign,
    throughput_guided_design,
    tg_simtasks,
)

__all__ = [
    "DesignPoint",
    "design_from_splits",
    "evaluate_design",
    "fixed_design",
    "LatencyCache",
    "create_acc",
    "BatchedDesignEvaluator",
    "resolve_acc",
    "Objective",
    "Constraint",
    "MinMaxUtil",
    "TotalLatency",
    "Eq3Constraint",
    "BeamResult",
    "BeamStats",
    "beam_search",
    "brute_force_search",
    "DSEConfig",
    "ExploreResult",
    "explore",
    "ProvisionPlan",
    "provision",
    "TGDesign",
    "throughput_guided_design",
    "tg_simtasks",
]
