"""DSE -> serving provisioning bridge.

Before this module, a DSE result was a dead end: `beam_search` returned
`DesignPoint`s, and the serving stack (`repro.traffic`) re-ran its own
search inside `traffic.scenarios.build` — DSE output never reached the
gateway, the shards, or the conformance harness. `provision` closes the
loop:

    DSE-chosen design  ->  segment table + admission contracts
                       ->  tenant -> shard plan (per-shard Eq. 3)
                       ->  `ShardedGateway` ready to serve

A `ProvisionPlan` is the deployable artifact: the materialized
`BuiltScenario` (same traffic seeds `build` would have used), the
tenant->shard `ShardPlan` (the *same* `plan_shards` path the gateway
constructor uses, so what is checked is what runs), and the per-shard
admission contracts — one `TaskRequest` tuple per shard, each of which
a per-shard `AdmissionController` re-verifies bit-exactly at `open`.

`repro.conformance.run_dse_case` drives this bridge differentially:
every DSE-claimed-feasible design must also be feasible under the DES
and the executing runtime, and the provisioned `ShardedGateway` must
serve the scenario's traffic with zero violations.

Imports from `repro.traffic` stay inside functions: `core` is the
bottom layer and `traffic` imports it at module scope.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.dse.explore import DSEConfig, ExploreResult, explore
from repro.core.dse.space import DesignPoint


@dataclass(frozen=True)
class ProvisionPlan:
    """A DSE-chosen design wired to a concrete serving deployment."""

    #: the materialized scenario (design, table, contracts, traffic)
    built: object  # BuiltScenario
    design: DesignPoint
    #: tenant -> shard assignment (`repro.traffic.shard.ShardPlan`)
    plan: object
    placement: str
    policy: str
    #: per-shard admission contracts: `TaskRequest`s each shard's
    #: controller re-admits (original tenant order within the shard)
    contracts: tuple[tuple, ...]

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    def shard_utilizations(self) -> tuple[tuple[float, ...], ...]:
        """Per-shard post-admission Eq. 2 stage utilizations — the
        capacity ledger the plan hands each replica."""
        preemptive = self.policy == "edf"
        out = []
        for members in self.plan.members:
            util = [0.0] * self.design.n_stages
            for i in members:
                du = self.built.requests[i].utilization(
                    (0.0,) * self.design.n_stages, preemptive
                )
                for k in range(self.design.n_stages):
                    util[k] += du[k]
            out.append(tuple(util))
        return tuple(out)

    def admission_controllers(self):
        """One freshly-seeded `AdmissionController` per shard, loaded
        with this plan's contracts (raises if any contract does not
        fit — a provisioned plan must admit its own tenants)."""
        from repro.traffic.admission import AdmissionController

        controllers = []
        for contract in self.contracts:
            ctl = AdmissionController(
                [0.0] * self.design.n_stages,
                preemptive=(self.policy == "edf"),
            )
            for req in contract:
                dec = ctl.admit(req)
                if not dec.admitted:
                    raise ValueError(
                        f"provisioned contract rejects {req.name!r}: "
                        f"{dec.reason}"
                    )
            controllers.append(ctl)
        return controllers

    def sharded_gateway(self, **kwargs):
        """Build the `ShardedGateway` this plan describes (same
        placement, same per-shard constructor path)."""
        from repro.traffic.shard import ShardedGateway

        return ShardedGateway.from_built(
            self.built,
            shards=self.plan.n_shards,
            placement=self.placement,
            policy=self.policy,
            **kwargs,
        )


def provision(
    scenario,
    platform=None,
    *,
    design: DesignPoint | None = None,
    result: ExploreResult | None = None,
    cfg: DSEConfig | None = None,
    shards: int = 1,
    placement="least_loaded",
    policy: str | None = None,
    seed: int = 0,
) -> ProvisionPlan:
    """Provision a scenario from a DSE result.

    ``scenario`` is a `TrafficScenario` or registry name. The design
    comes from (in priority order) ``design``, ``result.best``, or a
    fresh `explore` run under ``cfg``. Returns the `ProvisionPlan`
    binding that design to a tenant->shard assignment and per-shard
    Eq. 3 admission contracts.
    """
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import (
        get_scenario,
        materialize,
        resolve_problem,
    )
    from repro.traffic.shard import plan_shards

    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    platform = platform or paper_platform(16)
    workloads, taskset = resolve_problem(scenario, platform)
    if design is None:
        if result is None:
            result = explore(workloads, taskset, platform, cfg)
        design = result.best
        if design is None:
            raise ValueError(
                f"scenario {scenario.name!r}: the DSE found no feasible "
                "design to provision"
            )
    built = materialize(scenario, workloads, taskset, design, seed=seed)
    policy = policy or scenario.policy
    placement_obj, plan = plan_shards(
        built.requests,
        shards,
        placement,
        n_stages=design.n_stages,
        preemptive=(policy == "edf"),
    )
    contracts = tuple(
        tuple(built.requests[i] for i in members)
        for members in plan.members
    )
    return ProvisionPlan(
        built=built,
        design=design,
        plan=plan,
        placement=placement_obj.name,
        policy=policy,
        contracts=contracts,
    )
