"""Brute-force DSE baseline (paper §5.4): beam search with B = +inf.

The paper implements brute force as BFS over the same space; setting
``beam_width=None`` keeps every child each iteration. Exponential — use
only for the Fig. 9 quality/time comparison on small problems.
"""
from __future__ import annotations

from repro.core.dse.beam import BeamResult, beam_search
from repro.core.perfmodel.hardware import Platform
from repro.core.rt.task import TaskSet, Workload


def brute_force_search(
    workloads: list[Workload],
    taskset: TaskSet,
    platform: Platform,
    max_m: int = 4,
    max_frontier: int = 2_000_000,
    **kwargs,
) -> BeamResult:
    """Equivalent to ``explore(..., method="brute")``; extra keyword
    arguments (``objective``, ``constraint``, ``evaluator``) pass
    through to `beam_search`."""
    return beam_search(
        workloads,
        taskset,
        platform,
        max_m=max_m,
        beam_width=None,
        max_frontier=max_frontier,
        **kwargs,
    )
