"""PHAROS design space (paper §4.1).

A design point partitions the platform's chips into ``M`` pipelined
accelerators and maps each task's layers onto them *consecutively* (the
pipelined-topology constraint): ``splits[k][i]`` = number of consecutive
layers of task i on accelerator k, with ``sum_k splits[k][i] == L_i``.

Evaluation produces the `SegmentTable` consumed by the RT core and the
DES, so schedulability tests / response bounds / simulation all see the
same WCETs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.perfmodel.exec_model import (
    AccDesign,
    preemption_overheads,
    segment_latency,
)
from repro.core.rt.task import SegmentTable, TaskSet, Workload


@dataclass(frozen=True)
class DesignPoint:
    """A complete PHAROS system design."""

    accs: tuple[AccDesign, ...]
    splits: tuple[tuple[int, ...], ...]  # [n_stages][n_tasks]
    max_util: float  # objective value (preemptive=False, Eq. 2)

    @property
    def n_stages(self) -> int:
        return len(self.accs)

    def chips_used(self) -> int:
        return sum(a.chips for a in self.accs)


def task_segments(
    workload: Workload, counts_per_stage: list[int]
) -> list[tuple]:
    """Slice a workload's layer chain by per-stage counts."""
    out, pos = [], 0
    for c in counts_per_stage:
        out.append(tuple(workload.layers[pos : pos + c]))
        pos += c
    if pos != workload.num_layers:
        raise ValueError("split does not cover all layers")
    return out


def evaluate_design(
    accs: tuple[AccDesign, ...],
    splits: tuple[tuple[int, ...], ...],
    workloads: list[Workload],
    taskset: TaskSet,
) -> SegmentTable:
    """Build the SegmentTable (b_i^k matrix + xi^k vector) of a design."""
    n_stages, n_tasks = len(accs), len(workloads)
    base = [[0.0] * n_stages for _ in range(n_tasks)]
    layer_split = [[0] * n_stages for _ in range(n_tasks)]
    for i, w in enumerate(workloads):
        counts = [splits[k][i] for k in range(n_stages)]
        segs = task_segments(w, counts)
        for k, seg in enumerate(segs):
            layer_split[i][k] = len(seg)
            if seg:
                base[i][k] = segment_latency(seg, accs[k])
    overhead = [sum(preemption_overheads(a)) for a in accs]
    return SegmentTable(base=base, overhead=overhead, layer_split=layer_split)


def design_from_splits(
    accs: tuple[AccDesign, ...],
    splits: tuple[tuple[int, ...], ...],
    workloads: list[Workload],
    taskset: TaskSet,
) -> DesignPoint:
    from repro.core.rt.schedulability import max_utilization

    table = evaluate_design(accs, splits, workloads, taskset)
    return DesignPoint(
        accs=accs,
        splits=splits,
        max_util=max_utilization(table, taskset, preemptive=False),
    )


def fixed_design(
    workloads: list[Workload], taskset: TaskSet, platform
) -> DesignPoint:
    """Paper Fig. 1 baseline: one accelerator with all resources."""
    from repro.core.dse.create_acc import LatencyCache, create_acc

    cache = LatencyCache(workloads)
    spans = tuple((0, w.num_layers) for w in workloads)
    acc, _util, _lat = create_acc(spans, platform.total_chips, taskset, cache)
    splits = (tuple(w.num_layers for w in workloads),)
    return design_from_splits((acc,), splits, workloads, taskset)
