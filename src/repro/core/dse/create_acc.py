"""``create_acc`` — inner microarchitecture search (paper Alg. 1, line 9).

Given per-task *spans* of consecutive layers assigned to one accelerator
and its chip budget, brute-force the block-shape candidates (the TPU
analogue of the paper's fixed A..Z sweep; constant complexity per call)
and return the configuration minimizing this accelerator's utilization
``sum_i lat_i / p_i``.

Performance: the beam search calls this O(B * R * prod L_i) times, so
segment latency is served from per-(workload, chips, block) *prefix-sum
caches* — latency of ``layers[a:b]`` is ``prefix[b] - prefix[a]`` — and
each cache line is built once lazily.
"""
from __future__ import annotations

from repro.core.perfmodel.exec_model import (
    AccDesign,
    BLOCK_CANDIDATES,
    layer_latency,
    vmem_bytes_for_block,
)
from repro.core.perfmodel.hardware import TPU_V5E
from repro.core.rt.task import TaskSet, Workload

Span = tuple[int, int]  # half-open [start, end) layer range


class LatencyCache:
    """Prefix-sum latency tables keyed by (workload, chips, block)."""

    def __init__(self, workloads: list[Workload]):
        self.workloads = workloads
        self._prefix: dict[tuple[int, int, tuple[int, int, int]], list[float]] = {}

    def prefix(
        self, task_i: int, chips: int, block: tuple[int, int, int]
    ) -> list[float]:
        """The full prefix-sum row for (workload, chips, block) — the
        accumulation the batched evaluator copies verbatim so its
        latencies are bit-identical to the scalar path."""
        key = (task_i, chips, block)
        pre = self._prefix.get(key)
        if pre is None:
            acc = AccDesign(chips=chips, block=block)
            pre = [0.0]
            for layer in self.workloads[task_i].layers:
                pre.append(pre[-1] + layer_latency(layer, acc))
            self._prefix[key] = pre
        return pre

    def segment(
        self, task_i: int, span: Span, chips: int, block: tuple[int, int, int]
    ) -> float:
        a, b = span
        if a == b:
            return 0.0
        pre = self.prefix(task_i, chips, block)
        return pre[b] - pre[a]


_VALID_BLOCKS = tuple(
    b for b in BLOCK_CANDIDATES if vmem_bytes_for_block(b) <= TPU_V5E.vmem_bytes
)


def create_acc(
    spans: tuple[Span, ...],
    chips: int,
    taskset: TaskSet,
    cache: LatencyCache,
) -> tuple[AccDesign, float, tuple[float, ...]]:
    """Best (acc, utilization, per-task latencies) for this assignment.

    Empty assignment -> trivial design, utilization 0. ``chips <= 0``
    with non-empty work -> utilization ``inf`` (the paper's synthetic
    remain_acc with no resources can never pass the u <= 1 gate).
    """
    total_layers = sum(b - a for a, b in spans)
    if total_layers == 0:
        return AccDesign(chips=max(chips, 1)), 0.0, tuple(0.0 for _ in spans)
    if chips <= 0:
        return (
            AccDesign(chips=1),
            float("inf"),
            tuple(float("inf") if b > a else 0.0 for a, b in spans),
        )

    inv_periods = [1.0 / t.period for t in taskset.tasks]
    best_util = float("inf")
    best_block = _VALID_BLOCKS[0]
    best_lats: tuple[float, ...] = ()
    for block in _VALID_BLOCKS:
        util = 0.0
        lats = []
        for i, span in enumerate(spans):
            lat = cache.segment(i, span, chips, block)
            lats.append(lat)
            util += lat * inv_periods[i]
        if util < best_util:
            best_util, best_block, best_lats = util, block, tuple(lats)
    return AccDesign(chips=chips, block=best_block), best_util, best_lats
