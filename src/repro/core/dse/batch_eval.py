"""Vectorized ``create_acc`` — the DSE's batched inner evaluator.

`repro.core.dse.create_acc.create_acc` prices ONE candidate accelerator
(a per-task span assignment plus a chip budget) by sweeping the valid
block shapes and picking the utilization-minimizing one. The beam
search calls it once per child, twice per retained child — hundreds of
thousands of times on the brute-force problems — and every call pays
Python interpreter overhead for ~10 blocks x n tasks of float work.

`BatchedDesignEvaluator.evaluate` does the same computation for an
**array of candidates** in a handful of numpy operations: per distinct
chip budget it materializes a ``[n_blocks, n_tasks, L+1]`` prefix-sum
tensor (copied row-for-row from the scalar `LatencyCache`, so every
latency is the *same float* the scalar path sees), gathers segment
latencies for the whole batch with fancy indexing, and reduces to the
best block per candidate with the scalar code's exact first-wins
strict-``<`` tie-breaking.

Bit-compatibility contract (asserted by the property suite): for every
candidate, ``evaluate`` returns the same utilization, the same chosen
block, and the same per-task latencies as `create_acc` — including the
degenerate cases (empty assignment -> trivial design, ``chips <= 0``
with work -> ``inf``). The task-order utilization accumulation runs as
an explicit loop (float addition is not associative); only the
candidate axis is vectorized.
"""
from __future__ import annotations

import numpy as np

from repro.core.dse.create_acc import _VALID_BLOCKS, LatencyCache
from repro.core.perfmodel.exec_model import AccDesign, layer_latency
from repro.core.rt.task import TaskSet, Workload

#: sentinel block indices for the degenerate `create_acc` branches
TRIVIAL_BLOCK = -2  # empty assignment: AccDesign(chips=max(chips, 1))
NO_CHIP_BLOCK = -1  # chips <= 0 with work: AccDesign(chips=1), util inf


def resolve_acc(chips: int, block_idx: int) -> AccDesign:
    """The `AccDesign` the scalar `create_acc` would have returned."""
    if block_idx == TRIVIAL_BLOCK:
        return AccDesign(chips=max(chips, 1))
    if block_idx == NO_CHIP_BLOCK:
        return AccDesign(chips=1)
    return AccDesign(chips=chips, block=_VALID_BLOCKS[block_idx])


class BatchedDesignEvaluator:
    """Evaluate arrays of (spans, chips) accelerator candidates at once.

    Shares (or owns) a scalar `LatencyCache`; prefix tensors are built
    lazily per chip count and cached for the life of the evaluator, so
    a beam search touches each (chips, block, workload) latency row
    exactly once no matter how many candidates reference it.
    """

    def __init__(
        self,
        workloads: list[Workload],
        taskset: TaskSet,
        *,
        cache: LatencyCache | None = None,
    ):
        if len(workloads) != len(taskset):
            raise ValueError("workloads/taskset mismatch")
        self.workloads = workloads
        self.taskset = taskset
        self.cache = cache or LatencyCache(workloads)
        # same per-call constant the scalar create_acc derives
        self.inv_periods = [1.0 / t.period for t in taskset.tasks]
        self._max_layers = max(w.num_layers for w in workloads)
        self._tensors: dict[int, np.ndarray] = {}
        self._segsums: dict[tuple[int, tuple[int, int, int]], np.ndarray] = {}

    @property
    def n_tasks(self) -> int:
        return len(self.workloads)

    def prefix_tensor(self, chips: int) -> np.ndarray:
        """``[n_blocks, n_tasks, L_max + 1]`` prefix-sum latencies for
        one chip budget (rows shorter than ``L_max`` pad with their
        final value; spans never index past a workload's own length)."""
        P = self._tensors.get(chips)
        if P is None:
            P = np.empty(
                (len(_VALID_BLOCKS), self.n_tasks, self._max_layers + 1)
            )
            for bi, block in enumerate(_VALID_BLOCKS):
                for i in range(self.n_tasks):
                    pre = self.cache.prefix(i, chips, block)
                    P[bi, i, : len(pre)] = pre
                    P[bi, i, len(pre) :] = pre[-1]
            self._tensors[chips] = P
        return P

    def segment_sums(
        self, chips: int, block: tuple[int, int, int]
    ) -> np.ndarray:
        """``[n_tasks, L+1, L+1]`` table of exact `segment_latency`
        values: entry ``[i, a, b]`` is the latency of task i's layers
        ``[a, b)`` on an ``AccDesign(chips, block)`` stage, accumulated
        from zero in layer order — the *same float* the scalar
        `evaluate_design` computes (which is NOT the prefix-sum
        difference `evaluate` uses; `create_acc` and `evaluate_design`
        have always disagreed in the last ulp, and the batched paths
        reproduce each one exactly)."""
        key = (chips, block)
        T = self._segsums.get(key)
        if T is None:
            T = np.zeros(
                (self.n_tasks, self._max_layers + 1, self._max_layers + 1)
            )
            acc = AccDesign(chips=chips, block=block)
            for i, w in enumerate(self.workloads):
                lats = [layer_latency(l, acc) for l in w.layers]
                for a in range(len(lats) + 1):
                    s = 0.0
                    for b in range(a + 1, len(lats) + 1):
                        s = s + lats[b - 1]
                        T[i, a, b] = s
            self._segsums[key] = T
        return T

    def design_max_utils(self, designs) -> np.ndarray:
        """Batched `design_from_splits` objective: ``max_k u^k``
        (``preemptive=False``) for a list of complete designs, each a
        ``(accs, splits)`` pair. Bit-identical to `evaluate_design` +
        `max_utilization` on every design."""
        return self.design_metrics(designs)[0]

    def design_metrics(self, designs) -> tuple[np.ndarray, np.ndarray]:
        """Both per-design objective metrics in one pass:
        ``(max_utils, total_latencies)``. ``total_latencies[c]`` is the
        summed chain latency ``sum_i sum_k b_i^k`` — the `TotalLatency`
        objective — accumulated in the scalar score's order (stages
        within a task, then tasks)."""
        C = len(designs)
        n = self.n_tasks
        if C == 0:
            return np.empty(0), np.empty(0)
        K_max = max(len(accs) for accs, _splits in designs)
        base = np.zeros((C, n, K_max))
        # group (candidate, stage) entries by stage microarchitecture so
        # each (chips, block) segment table is gathered once; span
        # bounds go into flat buffers (list-of-list asarray is slow)
        groups: dict[
            tuple[int, tuple[int, int, int]],
            tuple[list[int], list[int], list[int], list[int]],
        ] = {}
        for c, (accs, splits) in enumerate(designs):
            pos = [0] * n
            for k, acc in enumerate(accs):
                g = groups.setdefault(
                    (acc.chips, acc.block), ([], [], [], [])
                )
                g[0].append(c)
                g[1].append(k)
                g[2].extend(pos)
                row = splits[k]
                for i in range(n):
                    pos[i] += row[i]
                g[3].extend(pos)
        ar = np.arange(n)
        # rtlint: disable=determinism -- insertion order is pinned by the
        # candidate list; results scatter back by index, order-free
        for (chips, block), (cs, ks, flat_lo, flat_hi) in groups.items():
            T = self.segment_sums(chips, block)
            a = np.array(flat_lo, dtype=np.int64).reshape(len(cs), n)
            b = np.array(flat_hi, dtype=np.int64).reshape(len(cs), n)
            base[np.array(cs), :, np.array(ks)] = T[ar[None, :], a, b]
        util = np.zeros((C, K_max))
        total = np.zeros(C)
        for i, t in enumerate(self.taskset.tasks):  # task-order, like Eq. 2
            row = base[:, i, :]
            util += row / t.period
            # stage-order accumulation matches the scalar per-task
            # left-to-right sum (padded stages add exact 0.0)
            row_sum = np.zeros(C)
            for k in range(K_max):
                row_sum += row[:, k]
            total += row_sum
        # stages past a design's own count contribute util 0.0, which
        # cannot win the max (every real design has a positive stage)
        return util.max(axis=1), total

    def evaluate(
        self, spans: np.ndarray, chips: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched `create_acc`.

        ``spans`` is ``[C, n_tasks, 2]`` (half-open layer ranges),
        ``chips`` ``[C]``. Returns ``(util [C], block_idx [C],
        lats [C, n_tasks])`` where ``block_idx`` indexes
        ``_VALID_BLOCKS`` (or a sentinel for the degenerate branches);
        `resolve_acc` turns it back into the scalar `AccDesign`.
        """
        spans = np.asarray(spans, dtype=np.int64)
        chips = np.asarray(chips, dtype=np.int64)
        if spans.ndim != 3 or spans.shape[1] != self.n_tasks:
            raise ValueError(
                f"spans must be [C, {self.n_tasks}, 2], got {spans.shape}"
            )
        C, n = spans.shape[0], self.n_tasks
        util = np.empty(C)
        block_idx = np.empty(C, dtype=np.int64)
        lats = np.zeros((C, n))

        seg_layers = spans[:, :, 1] - spans[:, :, 0]
        empty = seg_layers.sum(axis=1) == 0
        nochip = ~empty & (chips <= 0)
        util[empty] = 0.0
        block_idx[empty] = TRIVIAL_BLOCK
        util[nochip] = np.inf
        block_idx[nochip] = NO_CHIP_BLOCK
        lats[nochip] = np.where(seg_layers[nochip] > 0, np.inf, 0.0)

        normal = ~empty & (chips > 0)
        ar = np.arange(n)
        for c in np.unique(chips[normal]):
            m = normal & (chips == c)
            P = self.prefix_tensor(int(c))
            a = spans[m, :, 0]
            b = spans[m, :, 1]
            # lat[bi, mi, i] = P[bi, i, b[mi, i]] - P[bi, i, a[mi, i]]
            lat = P[:, ar[None, :], b] - P[:, ar[None, :], a]
            u = np.zeros(lat.shape[:2])
            for i in range(n):  # task-order accumulation (see module doc)
                u += lat[:, :, i] * self.inv_periods[i]
            best_u = np.full(lat.shape[1], np.inf)
            best_b = np.zeros(lat.shape[1], dtype=np.int64)
            for bi in range(len(_VALID_BLOCKS)):  # first-wins strict <
                better = u[bi] < best_u
                best_u[better] = u[bi][better]
                best_b[better] = bi
            util[m] = best_u
            block_idx[m] = best_b
            lats[m] = lat[best_b, np.arange(lat.shape[1]), :]
        return util, block_idx, lats
