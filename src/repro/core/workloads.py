"""The paper's evaluation workloads (§5.1) as layer chains.

Five applications, truncated exactly as in the paper (blocks repeat but
layers within a block differ, preserving layer heterogeneity):

- PointNet (full model)                 [Qi et al., CVPR'17]
- Point Transformer (2 blocks)          [Wu et al., PTv3]
- MLP-Mixer (2 blocks, Mixer-B/16)      [Tolstikhin et al.]
- Res-MLP (4 blocks, ResMLP-S24/384)    [Touvron et al.]
- DeiT-T (2 blocks)                     [Touvron et al.]

Layer shapes are the dominant GEMMs of each published architecture
(1x1 convs and per-point MLPs are GEMMs with M = #points/#tokens).
Attention score/value products are folded into explicit-FLOP layers.

The paper reports single-accelerator latencies P' = (0.23, 0.99, 0.30,
0.38, 0.14) ms on VCK5000; our platform is faster, so — exactly like the
paper — taskset periods are generated *relative to our own* measured P'
via ratio grids (`period_grid`), which preserves every claim expressed
as a ratio.
"""
from __future__ import annotations

from repro.core.rt.task import LayerDesc, Task, TaskSet, Workload

_L = LayerDesc

#: Each job is a small batch of inferences (embedded pipelines batch
#: sensor frames); keeps the paper workloads compute-relevant on TPU
#: chips instead of dispatch-bound, preserving the paper's
#: resource/utilization trade-off regime.
JOB_BATCH = 8


def _attn(name: str, tokens: int, heads: int, head_dim: int) -> LayerDesc:
    """Score + AV GEMM pair folded into one explicit-FLOP layer."""
    flops = 2.0 * 2.0 * tokens * tokens * heads * head_dim
    byts = 2.0 * (2 * tokens * heads * head_dim + heads * tokens * tokens)
    return _L(
        name,
        M=tokens,
        K=head_dim * heads,
        N=tokens,
        kind="attn",
        flops=flops,
        bytes_rw=byts,
    )


def pointnet() -> Workload:
    """Full PointNet classification trunk, 1024 points (per-point MLPs
    are (points x Cin x Cout) GEMMs; T-Nets folded into the trunk)."""
    P = 1024 * JOB_BATCH
    layers = (
        _L("mlp1_3_64", P, 64, 64),  # 3->64 padded to lane width
        _L("mlp2_64_64", P, 64, 64),
        _L("mlp3_64_64", P, 64, 64),
        _L("mlp4_64_128", P, 64, 128),
        _L("mlp5_128_1024", P, 128, 1024),
        _L("fc1_1024_512", 8 * JOB_BATCH, 1024, 512),
        _L("fc2_512_256", 8 * JOB_BATCH, 512, 256),
        _L("fc3_256_40", 8 * JOB_BATCH, 256, 64),
    )
    return Workload("pointnet", layers)


def _windowed_attn(name: str, tokens: int, window: int, d: int) -> LayerDesc:
    """PTv3 serialized-patch attention: scores+AV within windows only."""
    flops = 2.0 * 2.0 * tokens * window * d
    byts = 2.0 * (2 * tokens * d + tokens * window)
    return _L(
        name, M=tokens, K=d, N=window, kind="attn", flops=flops, bytes_rw=byts
    )


def point_transformer(blocks: int = 2) -> Workload:
    """Point Transformer v3: serialized windowed attention, 4096 points,
    d=256, patch window 1024."""
    P, D, H = 4096 * JOB_BATCH, 256, 4
    block = lambda i: (
        _L(f"b{i}_qkv", P, D, 3 * D, kind="attn_proj"),
        _windowed_attn(f"b{i}_attn", P, 1024, D),
        _L(f"b{i}_proj", P, D, D),
        _L(f"b{i}_ffn_up", P, D, 4 * D),
        _L(f"b{i}_ffn_dn", P, 4 * D, D),
    )
    layers = tuple(l for i in range(blocks) for l in block(i))
    return Workload("point_transformer", layers)


def mlp_mixer(blocks: int = 2) -> Workload:
    """Mixer-B/16: 196 tokens, d=768, token-MLP 384, channel-MLP 3072."""
    T, D, DS, DC = 196 * JOB_BATCH, 768, 384, 3072
    block = lambda i: (
        _L(f"b{i}_tok_up", D, T, DS, kind="token_mix"),
        _L(f"b{i}_tok_dn", D, DS, T, kind="token_mix"),
        _L(f"b{i}_ch_up", T, D, DC),
        _L(f"b{i}_ch_dn", T, DC, D),
    )
    layers = tuple(l for i in range(blocks) for l in block(i))
    return Workload("mlp_mixer", layers)


def resmlp(blocks: int = 4) -> Workload:
    """ResMLP-S24: 196 tokens, d=384, cross-patch + cross-channel."""
    T, D = 196 * JOB_BATCH, 384
    block = lambda i: (
        _L(f"b{i}_xpatch", D, T, T, kind="token_mix"),
        _L(f"b{i}_ch_up", T, D, 4 * D),
        _L(f"b{i}_ch_dn", T, 4 * D, D),
    )
    layers = tuple(l for i in range(blocks) for l in block(i))
    return Workload("resmlp", layers)


def deit_t(blocks: int = 2) -> Workload:
    """DeiT-Tiny: 197 tokens, d=192, 3 heads."""
    T, D, H = 197 * JOB_BATCH, 192, 3
    block = lambda i: (
        _L(f"b{i}_qkv", T, D, 3 * D, kind="attn_proj"),
        _attn(f"b{i}_attn", T, H, D // H),
        _L(f"b{i}_proj", T, D, D),
        _L(f"b{i}_ffn_up", T, D, 4 * D),
        _L(f"b{i}_ffn_dn", T, 4 * D, D),
    )
    layers = tuple(l for i in range(blocks) for l in block(i))
    return Workload("deit_t", layers)


PAPER_WORKLOADS: dict[str, Workload] = {
    w.name: w
    for w in (pointnet(), point_transformer(), mlp_mixer(), resmlp(), deit_t())
}

#: paper's application pairings: one point-cloud app x one image app
PAPER_COMBOS: tuple[tuple[str, str], ...] = (
    ("pointnet", "mlp_mixer"),
    ("pointnet", "resmlp"),
    ("pointnet", "deit_t"),
    ("point_transformer", "mlp_mixer"),
    ("point_transformer", "resmlp"),
    ("point_transformer", "deit_t"),
)


def single_acc_reference_latency(workload: Workload, platform) -> float:
    """P': workload latency on one full-platform accelerator (paper §5.1).

    Periods are then generated as ``P' / ratio`` — larger ratio = smaller
    period = heavier workload, exactly the paper's knob.
    """
    from repro.core.perfmodel.exec_model import AccDesign, segment_latency

    best = float("inf")
    from repro.core.perfmodel.exec_model import BLOCK_CANDIDATES

    for block in BLOCK_CANDIDATES:
        try:
            acc = AccDesign(chips=platform.total_chips, block=block)
        except ValueError:
            continue
        best = min(best, segment_latency(workload.layers, acc))
    return best


def make_taskset(
    combo: tuple[str, str],
    ratios: tuple[float, float],
    platform,
) -> TaskSet:
    """Build the paper's 2-task taskset: periods = P'_app / ratio."""
    tasks = []
    for app, ratio in zip(combo, ratios):
        w = PAPER_WORKLOADS[app]
        p_ref = single_acc_reference_latency(w, platform)
        tasks.append(Task(workload=w, period=p_ref / ratio))
    return TaskSet(tasks=tuple(tasks))


def period_grid(n: int = 7, lo: float = 0.5, hi: float = 6.0):
    """Ratio grid for (P'/P1, P'/P2) sweeps (paper Figs. 1, 6, 7)."""
    step = (hi - lo) / (n - 1)
    vals = [lo + i * step for i in range(n)]
    return [(a, b) for a in vals for b in vals]
