"""TPU-adapted performance model (paper Eq. 1, CHARM-style -> roofline).

The paper prices layer latency via the CHARM analytical model
``Exec(l, A, B, C, X, Y, Z)`` on a Versal AIE array. Our target is a TPU
v5e pod: an accelerator ("stage") is a set of chips plus a Pallas block
shape ``(bm, bk, bn)``. Latency is the roofline max of compute, HBM and
ICI terms, with MXU-alignment efficiency and a fixed dispatch overhead,
so the DSE sees the same resource/utilization trade-offs the paper's
model exposes (over-allocation floors, shape mismatch penalties).
"""
from repro.core.perfmodel.hardware import TPUChip, Platform, TPU_V5E, paper_platform
from repro.core.perfmodel.exec_model import (
    AccDesign,
    BLOCK_CANDIDATES,
    layer_latency,
    segment_latency,
    preemption_overheads,
    vmem_bytes_for_block,
)

__all__ = [
    "TPUChip",
    "Platform",
    "TPU_V5E",
    "paper_platform",
    "AccDesign",
    "BLOCK_CANDIDATES",
    "layer_latency",
    "segment_latency",
    "preemption_overheads",
    "vmem_bytes_for_block",
]
