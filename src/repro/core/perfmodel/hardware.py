"""Hardware constants for the target platform (TPU v5e).

The same constants feed (a) the DSE/scheduling latency model and (b) the
roofline analysis in EXPERIMENTS.md §Roofline, so the two are consistent
by construction.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TPUChip:
    """One TPU chip (v5e numbers per the assignment brief)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9  # capacity
    vmem_bytes: float = 64 * 2**20  # usable VMEM budget for kernel tiling
    mxu_dim: int = 128  # systolic array edge
    #: sustained fraction of peak for well-shaped GEMMs (MXU pipeline,
    #: weight-stationary refill, XLA overheads)
    mxu_eff: float = 0.85
    #: fixed per-layer dispatch/launch overhead, seconds
    dispatch_s: float = 2e-6


@dataclass(frozen=True)
class Platform:
    """A partitionable pool of identical chips (the DSE resource budget).

    The paper's resource vector R = (AIE, on-chip mem, on-chip BW, DDR BW)
    collapses on TPU to whole chips (each chip brings its own HBM/VMEM
    bandwidth) plus the per-stage block-shape choice; `DESIGN.md` §2
    records this adaptation.
    """

    name: str
    total_chips: int
    chip: TPUChip = TPUChip()

    def __post_init__(self) -> None:
        if self.total_chips < 1:
            raise ValueError("platform needs at least one chip")


TPU_V5E = TPUChip()

#: Full production pod — the multi-pod dry-run target (16x16 per pod).
POD_PLATFORM = Platform(name="v5e-pod", total_chips=256)


def paper_platform(total_chips: int = 16) -> Platform:
    """Small slice used for the paper-reproduction benchmarks.

    The paper's VCK5000 hosts <=4 accelerators; a 16-chip slice with
    max_M=4 reproduces the same partition-granularity regime.
    """
    return Platform(name=f"v5e-slice-{total_chips}", total_chips=total_chips)
