"""Layer/stage latency model — the TPU analogue of CHARM's Exec() (Eq. 1).

An accelerator (stage) is ``AccDesign(chips, block)``. A GEMM layer
``(M, K, N)`` executes output-stationary: the ``M x N`` output is tiled
into ``(bm, bn)`` tiles, each accumulated over ``ceil(K/bk)`` k-steps;
tiles are distributed across the stage's chips. Latency is

    max(compute, hbm, ici) + dispatch

where compute includes MXU-alignment efficiency (padding waste when a
dimension does not fill the block/MXU) — this is what penalizes
shape-mismatched accelerators in the DSE exactly like the paper's
"inefficient partition" children (paper Fig. 5C/D discussion).

Preemption overhead terms (Eq. 5) come from the same block shape:
``e_tile`` = one k-step of one tile, ``e_store`` = spilling the fp32
partial tile to HBM, ``e_load`` = reloading operand + partial buffers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache

from repro.core.perfmodel.hardware import TPUChip, TPU_V5E
from repro.core.rt.task import LayerDesc

#: candidate Pallas block shapes (bm, bk, bn); all K/N are lane-aligned
#: (multiples of 128), bm may drop to sublane granularity for small-M
#: workloads at proportional MXU-efficiency cost.
BLOCK_CANDIDATES: tuple[tuple[int, int, int], ...] = (
    (32, 128, 128),
    (64, 128, 128),
    (128, 128, 128),
    (128, 128, 256),
    (128, 256, 128),
    (256, 128, 128),
    (256, 128, 256),
    (256, 256, 256),
    (512, 128, 256),
    (512, 256, 512),
)

_ACC_BYTES = 4  # fp32 partial accumulator


def vmem_bytes_for_block(
    block: tuple[int, int, int], dtype_bytes: int = 2
) -> int:
    """Double-buffered operand tiles + fp32 accumulator tile."""
    bm, bk, bn = block
    return 2 * dtype_bytes * (bm * bk + bk * bn) + _ACC_BYTES * bm * bn


@dataclass(frozen=True)
class AccDesign:
    """One PHAROS accelerator realized as a TPU stage."""

    chips: int
    block: tuple[int, int, int] = (128, 128, 128)
    chip: TPUChip = TPU_V5E

    def __post_init__(self) -> None:
        if self.chips < 1:
            raise ValueError("stage needs >= 1 chip")
        if vmem_bytes_for_block(self.block) > self.chip.vmem_bytes:
            raise ValueError(f"block {self.block} exceeds VMEM budget")


def _mxu_eff(block: tuple[int, int, int], chip: TPUChip) -> float:
    """Fraction of MXU peak a (bm,bk,bn)-blocked GEMM can sustain."""
    bm, bk, bn = block
    d = chip.mxu_dim
    fill = min(bm, d) / d * min(bk, d) / d * min(bn, d) / d
    return chip.mxu_eff * fill


@lru_cache(maxsize=1 << 20)
def _latency_cached(
    M: int,
    K: int,
    N: int,
    flops: float,
    bytes_rw: float,
    dtype_bytes: int,
    chips: int,
    block: tuple[int, int, int],
) -> float:
    chip = TPU_V5E
    bm, bk, bn = block
    m_tiles = math.ceil(M / bm)
    n_tiles = math.ceil(N / bn)
    k_steps = math.ceil(K / bk)
    tiles = m_tiles * n_tiles
    tiles_per_chip = math.ceil(tiles / chips)

    # --- compute term: padded-tile flops at block-limited MXU rate ---
    eff = _mxu_eff(block, chip)
    tile_step_flops = 2.0 * bm * bk * bn
    compute = tiles_per_chip * k_steps * tile_step_flops / (chip.peak_flops * eff)
    # non-GEMM extra flops (e.g. softmax/scan) ride on the vector unit at
    # ~1/8 of MXU peak; LayerDesc.flops overrides account for them.
    gemm_flops = 2.0 * M * K * N
    if flops > gemm_flops:
        compute += (flops - gemm_flops) / (chips * chip.peak_flops * 0.125)

    # --- HBM term: per-chip operand/result traffic ---
    if bytes_rw > 0:
        hbm = bytes_rw / (chips * chip.hbm_bw)
    else:
        per_chip = dtype_bytes * (
            tiles_per_chip * k_steps * (bm * bk + bk * bn)
            + tiles_per_chip * bm * bn
        )
        hbm = per_chip / chip.hbm_bw

    # --- ICI term: activation scatter/gather across the stage ---
    ici = 0.0
    if chips > 1:
        moved = dtype_bytes * (M * K + M * N) * (chips - 1) / chips
        ici = moved / (chips * chip.ici_bw)

    return max(compute, hbm, ici) + chip.dispatch_s


def layer_latency(layer: LayerDesc, acc: AccDesign) -> float:
    """``bl_{i,j} = Exec(l_{i,j}, acc)`` in seconds (paper Eq. 1)."""
    return _latency_cached(
        layer.M,
        layer.K,
        layer.N,
        layer.gemm_flops(),
        layer.bytes_rw,
        layer.dtype_bytes,
        acc.chips,
        acc.block,
    )


def segment_latency(layers: tuple[LayerDesc, ...], acc: AccDesign) -> float:
    """``b_i^k``: a task segment runs its layers back-to-back."""
    return sum(layer_latency(l, acc) for l in layers)


def preemption_overheads(acc: AccDesign) -> tuple[float, float, float]:
    """``(e_tile, e_store, e_load)`` for the stage (paper Eq. 5).

    Tile-granular preemption: the preemptor waits one k-step of the
    in-flight tile, the fp32 partial tile spills to HBM, and resume
    reloads both operand tiles plus the partial tile.
    """
    chip = acc.chip
    bm, bk, bn = acc.block
    eff = _mxu_eff(acc.block, chip)
    e_tile = 2.0 * bm * bk * bn / (chip.peak_flops * eff)
    e_store = _ACC_BYTES * bm * bn / chip.hbm_bw + chip.dispatch_s
    e_load = (
        2 * (bm * bk + bk * bn) + _ACC_BYTES * bm * bn
    ) / chip.hbm_bw + chip.dispatch_s
    return (e_tile, e_store, e_load)


def xi(acc: AccDesign) -> float:
    """Total preemption overhead ``xi^k`` (Eq. 5)."""
    return sum(preemption_overheads(acc))
