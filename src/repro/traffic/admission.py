"""Online SRT admission control over the paper's static analysis.

The DSE uses Eq. 3 (`srt_schedulable`) once, at design time. A serving
deployment faces a *stream* of tenancy changes: new tasks asking for
capacity, old ones leaving, traffic models being re-provisioned. The
`AdmissionController` answers admit/reject **online** against the same
analysis:

- It caches each stage's utilization sum (Eq. 2). An admit check adds
  the candidate's per-stage contribution and compares against the cap —
  O(n_stages), not a full re-analysis over all admitted tasks.
- The cache is *exact*, not approximate: contributions are accumulated
  left-to-right in admission order, and every removal triggers a full
  recompute in the surviving order — so a cached verdict equals the
  verdict of rebuilding the `SegmentTable` and re-running
  `srt_schedulable` bit-for-bit (asserted by `verify`, and by the test
  suite on every decision).
- `headroom_report` exposes the sensitivity side: per-stage slack, the
  max admissible rate for a probe WCET vector (`max_admissible_rate`
  semantics), and per-tenant rate multipliers.

Guaranteed vs best-effort: only *guaranteed* requests consume Eq. 2
budget. A ``best_effort=True`` request is always admitted but carries no
response-time guarantee (its jobs run at infinite deadline in the
serving runtime) and contributes nothing to the cached utilization.

Calibrated-admission mode: `calibrated_requests` /
`AdmissionController.from_cost_model` swap every contract's modeled
per-stage WCETs for a `repro.conformance.CostModel`'s — typically a
`CostModel.calibrate` measurement of the serving host — so admission
runs against what the host actually does instead of what the TPU exec
model predicts (`run_wallclock_case` exercises the mode end to end).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.rt.batch import (
    batched_admission_check,
    batched_tenant_utilizations,
)
from repro.core.rt.response_time import end_to_end_bounds
from repro.core.rt.schedulability import EPS, srt_schedulable
from repro.core.rt.task import LayerDesc, SegmentTable, Task, TaskSet, Workload

#: criticality levels a tenant contract may carry, most critical first
#: (Vestal-style, extensible: the overload `ModeController` in
#: `repro.traffic.modes` guarantees every level strictly above its
#: configured shed threshold). "HI" is safety-critical — survives an
#: overload mode switch with a re-proved Eq. 3 contract; "LO" is
#: mission/best-effort work the switch sheds or demotes.
CRITICALITY_HI = "HI"
CRITICALITY_LO = "LO"
CRITICALITY_LEVELS = (CRITICALITY_HI, CRITICALITY_LO)


@dataclass(frozen=True)
class TaskRequest:
    """A candidate tenant: per-stage base WCETs + traffic contract.

    ``base[k]`` is ``b^k`` (pure segment length on stage k, 0 when the
    stage is skipped) — one row of a `SegmentTable`. ``period`` is the
    analysis period: the minimum inter-arrival for (spo)radic traffic or
    the provisioned period (`ArrivalProcess.analysis_period`) for
    stochastic traffic. ``value`` feeds the shed-by-value policy;
    ``criticality`` (one of `CRITICALITY_LEVELS`) feeds the overload
    `ModeController` — "HI" tenants keep their guarantee through a mode
    switch, "LO" tenants are shed or demoted.
    """

    name: str
    base: tuple[float, ...]
    period: float
    deadline: float = 0.0  # 0 -> implicit (= period)
    value: float = 1.0
    best_effort: bool = False
    criticality: str = CRITICALITY_LO

    def __post_init__(self) -> None:
        if self.period <= 0 or not math.isfinite(self.period):
            raise ValueError("analysis period must be positive and finite")
        if any(b < 0 for b in self.base):
            raise ValueError("negative WCET")
        if not any(b > 0 for b in self.base):
            raise ValueError("request has no active stage")
        if self.criticality not in CRITICALITY_LEVELS:
            raise ValueError(
                f"unknown criticality {self.criticality!r}; "
                f"expected one of {CRITICALITY_LEVELS}"
            )
        if self.deadline == 0.0:
            object.__setattr__(self, "deadline", self.period)

    def wcet(self, k: int, overhead: float, preemptive: bool) -> float:
        b = self.base[k]
        if b <= 0.0:
            return 0.0
        return b + (overhead if preemptive else 0.0)

    def utilization(self, overheads: Sequence[float], preemptive: bool):
        return tuple(
            self.wcet(k, overheads[k], preemptive) / self.period
            for k in range(len(self.base))
        )


@dataclass(frozen=True)
class AdmissionDecision:
    request: TaskRequest
    admitted: bool
    reason: str
    #: Eq. 2 per-stage utilization had/has the request been admitted
    stage_utils: tuple[float, ...]
    #: argmax stage of ``stage_utils`` — the bottleneck accelerator
    bottleneck: int
    guaranteed: bool = True

    @property
    def max_util(self) -> float:
        return max(self.stage_utils)


@dataclass(frozen=True)
class StageHeadroom:
    stage: int
    utilization: float
    slack: float
    #: max extra jobs/s of the probe WCET through this stage (inf if
    #: the probe skips it)
    probe_rate: float


@dataclass(frozen=True)
class HeadroomReport:
    """Sensitivity snapshot of the admitted set (see `headroom_report`)."""

    stages: tuple[StageHeadroom, ...]
    #: max admissible release rate of the probe task (min over stages)
    probe_max_rate: float
    #: per admitted tenant: max rate multiplier keeping Eq. 3
    tenant_rate_multipliers: dict[str, float]

    @property
    def bottleneck(self) -> int:
        return max(self.stages, key=lambda s: s.utilization).stage


def calibrated_requests(
    cost_model, requests: Sequence[TaskRequest]
) -> tuple[TaskRequest, ...]:
    """The same tenant contracts with measured per-stage WCETs.

    ``cost_model`` is a `repro.conformance.CostModel` whose task order
    matches ``requests`` (both come from the scenario's serve bundle);
    each request keeps its period/deadline/value — the traffic contract
    — while ``base`` becomes the model's `segment_cost` row. With a
    `CostModel.calibrate` model this is serving-host calibration; with
    `CostModel.from_exec_model` it reproduces the modeled contracts.
    """
    if cost_model.n_tasks != len(requests):
        raise ValueError(
            f"cost model prices {cost_model.n_tasks} tasks, "
            f"got {len(requests)} requests"
        )
    return tuple(
        TaskRequest(
            name=r.name,
            base=tuple(
                cost_model.segment_cost(i, k)
                for k in range(cost_model.n_stages)
            ),
            period=r.period,
            deadline=r.deadline,
            value=r.value,
            best_effort=r.best_effort,
            criticality=r.criticality,
        )
        for i, r in enumerate(requests)
    )


class AdmissionController:
    """Incremental Eq. 2/3 oracle for online admission.

    ``util_cap`` defaults to 1.0 (Eq. 3). Deployments wanting margin for
    model error can run at e.g. 0.9; the comparison keeps the same EPS
    float tolerance as `srt_schedulable` so cached and full verdicts
    coincide exactly at cap 1.0.
    """

    def __init__(
        self,
        overheads: Sequence[float],
        *,
        preemptive: bool = True,
        util_cap: float = 1.0,
    ):
        if not overheads:
            raise ValueError("need at least one stage")
        self.overheads = tuple(float(o) for o in overheads)
        self.preemptive = preemptive
        self.util_cap = util_cap
        self._util = [0.0] * len(self.overheads)
        self._admitted: list[TaskRequest] = []  # guaranteed, in order
        self._best_effort: list[TaskRequest] = []
        self.decisions: list[AdmissionDecision] = []

    # -- construction -------------------------------------------------
    @classmethod
    def from_table(
        cls,
        table: SegmentTable,
        taskset: TaskSet,
        *,
        preemptive: bool = True,
        util_cap: float = 1.0,
    ) -> "AdmissionController":
        """Seed a controller with a design's already-resident tasks."""
        ctl = cls(
            table.overhead, preemptive=preemptive, util_cap=util_cap
        )
        for i, t in enumerate(taskset.tasks):
            dec = ctl.admit(
                TaskRequest(
                    name=t.name,
                    base=tuple(table.base[i]),
                    period=t.period,
                    deadline=t.deadline,
                )
            )
            if not dec.admitted:
                raise ValueError(
                    f"seed task {t.name!r} itself violates Eq. 3 "
                    f"(max util {dec.max_util:.3f})"
                )
        return ctl

    @classmethod
    def from_cost_model(
        cls,
        cost_model,
        requests: Sequence[TaskRequest],
        *,
        preemptive: bool = True,
        util_cap: float = 1.0,
        strict: bool = True,
    ) -> "AdmissionController":
        """Calibrated-admission mode: a controller whose resident set
        was admitted against a `CostModel`'s (typically *measured*)
        WCETs instead of the requests' modeled ones.

        Overheads are zero — the window-boundary runtime blocks, it
        does not inflate utilization (the conformance premise) — and
        every contract is re-based via `calibrated_requests` before
        admission. ``strict`` raises if a measured contract does not
        fit; ``strict=False`` records the rejection in ``decisions``
        and continues (the conformance case turns it into a violation).
        """
        ctl = cls(
            [0.0] * cost_model.n_stages,
            preemptive=preemptive,
            util_cap=util_cap,
        )
        for req in calibrated_requests(cost_model, requests):
            dec = ctl.admit(req)
            if strict and not dec.admitted:
                raise ValueError(
                    f"measured contract {req.name!r} violates Eq. 3 "
                    f"on the calibrated host: {dec.reason}"
                )
        return ctl

    # -- properties ---------------------------------------------------
    @property
    def n_stages(self) -> int:
        return len(self.overheads)

    @property
    def admitted(self) -> tuple[TaskRequest, ...]:
        return tuple(self._admitted)

    @property
    def best_effort(self) -> tuple[TaskRequest, ...]:
        return tuple(self._best_effort)

    def utilizations(self) -> tuple[float, ...]:
        return tuple(self._util)

    def names(self) -> list[str]:
        return [r.name for r in self._admitted]

    # -- the O(n_stages) admit check ----------------------------------
    def check(self, req: TaskRequest) -> AdmissionDecision:
        """Admission verdict without committing (O(n_stages))."""
        if len(req.base) != self.n_stages:
            raise ValueError(
                f"request spans {len(req.base)} stages, "
                f"controller has {self.n_stages}"
            )
        if req.best_effort:
            return AdmissionDecision(
                request=req,
                admitted=True,
                reason="best-effort: admitted without guarantee",
                stage_utils=tuple(self._util),
                bottleneck=int(
                    max(range(self.n_stages), key=self._util.__getitem__)
                ),
                guaranteed=False,
            )
        du = req.utilization(self.overheads, self.preemptive)
        after = tuple(u + d for u, d in zip(self._util, du))
        bottleneck = int(max(range(self.n_stages), key=after.__getitem__))
        ok = after[bottleneck] <= self.util_cap + EPS
        reason = (
            f"max util {after[bottleneck]:.4f} <= cap {self.util_cap}"
            if ok
            else (
                f"stage {bottleneck} would reach "
                f"{after[bottleneck]:.4f} > cap {self.util_cap}"
            )
        )
        return AdmissionDecision(
            request=req,
            admitted=ok,
            reason=reason,
            stage_utils=after,
            bottleneck=bottleneck,
        )

    # -- the batched admit check (one array pass, T tenants) ----------
    def score_many(
        self, base, periods
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The batched admission core: Eq. 3 verdicts for ``T``
        guaranteed candidates in one array pass.

        ``base`` is ``[T, n_stages]`` (one `TaskRequest.base` row per
        candidate), ``periods`` ``[T]``. Returns ``(after, bottleneck,
        ok)`` exactly as `repro.core.rt.batch.batched_admission_check`:
        every row is an independent, non-committing check against the
        *current* cached utilization — bit-identical to a Python loop
        over `check` (the property suite asserts exact ``==``). This is
        the array layer `check_many` (and the placement/autoscale
        scoring) build on; it never sees best-effort requests, which
        consume no Eq. 2 budget.
        """
        b = np.asarray(base, dtype=np.float64)
        if b.ndim != 2 or b.shape[1] != self.n_stages:
            raise ValueError(
                f"base must be [T, {self.n_stages}], got {b.shape}"
            )
        du = batched_tenant_utilizations(
            b, self.overheads, periods, self.preemptive
        )
        return batched_admission_check(du, self._util, self.util_cap)

    def check_many(
        self, reqs: Sequence[TaskRequest]
    ) -> list[AdmissionDecision]:
        """Batched `check`: score every pending request in one array
        pass, bit-identical per-decision to ``[self.check(r) for r in
        reqs]`` (non-committing — no request sees another's admission).

        Best-effort rows short-circuit exactly like the scalar path
        (always admitted, no Eq. 2 contribution); guaranteed rows run
        through `score_many`. Decision objects (reason strings
        included) reproduce the scalar ones field-for-field.
        """
        for r in reqs:
            if len(r.base) != self.n_stages:
                raise ValueError(
                    f"request spans {len(r.base)} stages, "
                    f"controller has {self.n_stages}"
                )
        guaranteed = [i for i, r in enumerate(reqs) if not r.best_effort]
        out: list[AdmissionDecision | None] = [None] * len(reqs)
        if guaranteed:
            after, bottleneck, ok = self.score_many(
                [reqs[i].base for i in guaranteed],
                [reqs[i].period for i in guaranteed],
            )
            after_rows = after.tolist()
            for j, i in enumerate(guaranteed):
                k = int(bottleneck[j])
                admitted = bool(ok[j])
                peak = after_rows[j][k]
                reason = (
                    f"max util {peak:.4f} <= cap {self.util_cap}"
                    if admitted
                    else (
                        f"stage {k} would reach "
                        f"{peak:.4f} > cap {self.util_cap}"
                    )
                )
                out[i] = AdmissionDecision(
                    request=reqs[i],
                    admitted=admitted,
                    reason=reason,
                    stage_utils=tuple(after_rows[j]),
                    bottleneck=k,
                )
        for i, r in enumerate(reqs):
            if out[i] is None:
                out[i] = self.check(r)  # best-effort short-circuit
        return out  # type: ignore[return-value]

    def admit(self, req: TaskRequest) -> AdmissionDecision:
        """Check and, on success, commit the request."""
        # refuse duplicates before anything reaches the decision log, so
        # the log never carries an admitted=True entry that was not
        # actually committed
        if not req.best_effort and any(
            r.name == req.name for r in self._admitted
        ):
            raise ValueError(f"duplicate tenant name {req.name!r}")
        dec = self.check(req)
        self.decisions.append(dec)
        if not dec.admitted:
            return dec
        if req.best_effort:
            self._best_effort.append(req)
            return dec
        self._admitted.append(req)
        # commit = the same left-to-right accumulation a full recompute
        # in admission order performs, so the cache stays bit-exact
        du = req.utilization(self.overheads, self.preemptive)
        for k in range(self.n_stages):
            self._util[k] += du[k]
        return dec

    def release(self, name: str) -> TaskRequest:
        """Remove a tenant and rebuild the cache exactly (no drift)."""
        for pool in (self._admitted, self._best_effort):
            for i, r in enumerate(pool):
                if r.name == name:
                    pool.pop(i)
                    self._recompute()
                    return r
        raise KeyError(name)

    def _recompute(self) -> None:
        util = [0.0] * self.n_stages
        for r in self._admitted:
            du = r.utilization(self.overheads, self.preemptive)
            for k in range(self.n_stages):
                util[k] += du[k]
        self._util = util

    # -- full re-analysis view ----------------------------------------
    def to_analysis(self) -> tuple[SegmentTable, TaskSet] | None:
        """Materialize the admitted set for the offline tools (DES,
        response bounds, `srt_schedulable`). None when empty."""
        if not self._admitted:
            return None
        table = SegmentTable(
            base=[list(r.base) for r in self._admitted],
            overhead=list(self.overheads),
        )
        placeholder = Workload("traffic", (LayerDesc("seg", 1, 1, 1),))
        tasks = tuple(
            Task(
                workload=placeholder,
                period=r.period,
                deadline=r.deadline,
                name=r.name,
            )
            for r in self._admitted
        )
        return table, TaskSet(tasks=tasks)

    def verify(self) -> bool:
        """Cached verdict == full `srt_schedulable` re-analysis."""
        view = self.to_analysis()
        if view is None:
            return True
        table, ts = view
        full = srt_schedulable(table, ts, preemptive=self.preemptive)
        cached = max(self._util) <= 1.0 + EPS
        return full == cached

    def response_bounds(self, policy: str | None = None) -> dict[str, float]:
        """End-to-end response bounds of the admitted set (full
        analysis — O(tasks x stages), for reports, not the admit path)."""
        view = self.to_analysis()
        if view is None:
            return {}
        table, ts = view
        pol = policy or ("edf" if self.preemptive else "fifo")
        bounds = end_to_end_bounds(table, ts, pol)
        return {r.name: b for r, b in zip(self._admitted, bounds)}

    # -- sensitivity --------------------------------------------------
    def max_rate(self, base: Sequence[float]) -> float:
        """Max admissible release rate of a probe with WCETs ``base``
        (O(n_stages); `core.rt.max_admissible_rate` on the cache)."""
        rate = float("inf")
        for k, b in enumerate(base):
            if b <= 0.0:
                continue
            e = b + (self.overheads[k] if self.preemptive else 0.0)
            slack = self.util_cap - self._util[k]
            rate = min(rate, max(0.0, slack) / e)
        return rate

    def headroom_report(
        self, probe: Sequence[float] | None = None
    ) -> HeadroomReport:
        """Per-stage slack + max admissible probe rate + per-tenant rate
        multipliers — the "how much more traffic fits" answer."""
        probe = tuple(probe) if probe is not None else (0.0,) * self.n_stages
        stages = []
        for k in range(self.n_stages):
            slack = self.util_cap - self._util[k]
            b = probe[k]
            if b > 0.0:
                e = b + (self.overheads[k] if self.preemptive else 0.0)
                p_rate = max(0.0, slack) / e
            else:
                p_rate = float("inf")
            stages.append(
                StageHeadroom(
                    stage=k,
                    utilization=self._util[k],
                    slack=slack,
                    probe_rate=p_rate,
                )
            )
        mult = {}
        for r in self._admitted:
            du = r.utilization(self.overheads, self.preemptive)
            s_max = float("inf")
            for k, u_ik in enumerate(du):
                if u_ik <= 0.0:
                    continue
                slack = max(0.0, self.util_cap - self._util[k])
                s_max = min(s_max, 1.0 + slack / u_ik)
            mult[r.name] = s_max
        return HeadroomReport(
            stages=tuple(stages),
            probe_max_rate=min(s.probe_rate for s in stages),
            tenant_rate_multipliers=mult,
        )
