"""Scenario registry: named traffic mixes for benchmarks and examples.

A `TrafficScenario` describes a smart-transportation-style deployment as
a set of *tenants*: each references a workload — one of the paper's
five applications (``paper:<name>``, core.workloads) or an LM drawn
from the existing ``configs/`` (``config:<module>:<mode>``, flattened by
`models.extract.arch_workload`) — plus the paper's period knob (ratio
over the single-accelerator reference latency P'), an `ArrivalSpec`
(traffic shape relative to that period), a value for shed-by-value, and
an ``overdrive`` factor (actual traffic rate over the provisioned rate;
``> 1`` deliberately violates the analysis to exercise shedding).

`build` turns a scenario into everything the other layers consume:
provisioned `TaskSet` + DSE design + `SegmentTable` (analysis &
admission), seeded `ArrivalProcess` traces (DES & gateway), and
`TaskRequest` contracts. `BuiltScenario.serve_bundle` rescales the lot
to a wall-clock (or virtual) timebase and materializes `ServeTask`
GEMM chains for the `TrafficGateway`/`PharosServer` path, so examples
and benchmarks name a scenario instead of hand-building task sets.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.core.rt.task import SegmentTable, Task, TaskSet, Workload
from repro.core.workloads import (
    PAPER_WORKLOADS,
    single_acc_reference_latency,
)
from repro.traffic.admission import (
    CRITICALITY_HI,
    CRITICALITY_LEVELS,
    CRITICALITY_LO,
    TaskRequest,
)
from repro.traffic.arrival import (
    ArrivalProcess,
    MMPPArrivals,
    PeriodicArrivals,
    PoissonArrivals,
    SporadicArrivals,
)

_ARRIVAL_KINDS = ("periodic", "sporadic", "poisson", "mmpp")


@dataclass(frozen=True)
class ArrivalSpec:
    """Traffic shape, parameterized *relative* to the tenant period.

    - ``periodic``: releases every period.
    - ``sporadic``: min gap = period, exponential extra gap of mean
      ``jitter`` periods.
    - ``poisson``:  mean rate 1/period; provisioned for
      ``provision_factor`` x mean.
    - ``mmpp``:     calm rate ``calm_factor``/period, burst rate
      ``burst_factor``/period, mean dwells of ``dwells`` periods;
      provisioned for the burst rate.
    """

    kind: str = "periodic"
    jitter: float = 0.3
    calm_factor: float = 0.5
    burst_factor: float = 3.0
    dwells: tuple[float, float] = (40.0, 10.0)
    provision_factor: float = 1.5

    def __post_init__(self) -> None:
        if self.kind not in _ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; have {_ARRIVAL_KINDS}"
            )

    def build(self, period: float, seed: int) -> ArrivalProcess:
        if self.kind == "periodic":
            return PeriodicArrivals(period=period)
        if self.kind == "sporadic":
            return SporadicArrivals(
                min_gap=period, jitter=self.jitter, seed=seed
            )
        if self.kind == "poisson":
            return PoissonArrivals(
                rate=1.0 / period,
                seed=seed,
                provision_factor=self.provision_factor,
            )
        return MMPPArrivals(
            rates=(self.calm_factor / period, self.burst_factor / period),
            dwells=(self.dwells[0] * period, self.dwells[1] * period),
            seed=seed,
            provision_factor=1.0,
        )

    def analysis_period(self, period: float) -> float:
        """Provisioned inter-arrival bound for Eq. 2 accounting."""
        if self.kind in ("periodic", "sporadic"):
            return period
        if self.kind == "poisson":
            return period / self.provision_factor
        return period / self.burst_factor


@dataclass(frozen=True)
class TenantSpec:
    workload: str  # "paper:<name>" | "config:<module>:<mode>"
    ratio: float  # period = P'(workload) / ratio — the paper's knob
    arrival: ArrivalSpec = ArrivalSpec()
    value: float = 1.0
    name: str = ""
    #: actual traffic rate / provisioned rate; > 1 deliberately breaks
    #: the analysis so overload shedding engages
    overdrive: float = 1.0
    #: batch/seq only used by config:-references
    batch: int = 1
    seq: int = 2048
    #: mixed-criticality class (see `repro.traffic.admission`): "HI"
    #: tenants survive an overload mode switch, "LO" tenants are shed
    #: or demoted by the `ModeController`
    criticality: str = CRITICALITY_LO

    def __post_init__(self) -> None:
        if self.ratio <= 0 or self.overdrive <= 0:
            raise ValueError("ratio and overdrive must be positive")
        if self.criticality not in CRITICALITY_LEVELS:
            raise ValueError(
                f"unknown criticality {self.criticality!r}; "
                f"expected one of {CRITICALITY_LEVELS}"
            )
        if not self.name:
            object.__setattr__(
                self, "name", self.workload.split(":", 1)[-1]
            )


@dataclass(frozen=True)
class TrafficScenario:
    name: str
    description: str
    tenants: tuple[TenantSpec, ...]
    policy: str = "edf"  # serving/DES scheduling policy

    def __post_init__(self) -> None:
        if not self.tenants:
            raise ValueError("scenario has no tenants")


# ---------------------------------------------------------------------------
# workload resolution
# ---------------------------------------------------------------------------
def resolve_workload(spec: TenantSpec) -> Workload:
    ref = spec.workload
    src, _, rest = ref.partition(":")
    if src == "paper":
        try:
            return PAPER_WORKLOADS[rest]
        except KeyError:
            raise KeyError(
                f"unknown paper workload {rest!r}; "
                f"have {sorted(PAPER_WORKLOADS)}"
            ) from None
    if src == "config":
        module, _, mode = rest.partition(":")
        from repro.models.extract import arch_workload

        cfg = importlib.import_module(f"repro.configs.{module}").CONFIG
        return arch_workload(
            cfg, batch=spec.batch, seq=spec.seq, mode=mode or "decode"
        )
    raise ValueError(
        f"workload ref {ref!r} must start with 'paper:' or 'config:'"
    )


# ---------------------------------------------------------------------------
# build: scenario -> analysis artifacts + traffic
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BuiltScenario:
    scenario: TrafficScenario
    workloads: tuple[Workload, ...]
    taskset: TaskSet  # provisioned periods (analysis view)
    design: object  # DesignPoint from the DSE
    table: SegmentTable
    requests: tuple[TaskRequest, ...]
    arrivals: tuple[ArrivalProcess, ...]  # actual traffic (w/ overdrive)

    def des_arrivals(self, horizon: float) -> list[list[float]]:
        """Per-task explicit release times for `simulate_taskset`."""
        return [p.arrivals(horizon) for p in self.arrivals]

    def subset(self, indices, *, name: str | None = None) -> "BuiltScenario":
        """Restrict this built scenario to a tenant subset (in the given
        order) on the *same* pipeline design — the per-shard view a
        `ShardedGateway` places tenants into. Everything tenant-indexed
        is subset together (tenants, workloads, taskset, table rows,
        requests and the already-seeded arrival processes — traffic is
        preserved verbatim, not re-seeded); the design keeps its
        accelerators and stage count with its per-task layer splits
        restricted, so `serve_bundle` and the conformance `CostModel`
        work on the subset unchanged. The identity subset reproduces
        this scenario bit-exactly — the K=1 sharding equivalence.
        """
        from repro.core.dse.space import DesignPoint
        from repro.core.rt.schedulability import max_utilization

        idx = list(indices)
        if not idx:
            raise ValueError("subset needs at least one tenant")
        sub_table = SegmentTable(
            base=[list(self.table.base[i]) for i in idx],
            overhead=list(self.table.overhead),
        )
        sub_taskset = TaskSet(tasks=tuple(self.taskset.tasks[i] for i in idx))
        design = DesignPoint(
            accs=self.design.accs,
            splits=tuple(
                tuple(row[i] for i in idx) for row in self.design.splits
            ),
            max_util=max_utilization(sub_table, sub_taskset, False),
        )
        scen = TrafficScenario(
            name=name or self.scenario.name,
            description=self.scenario.description,
            tenants=tuple(self.scenario.tenants[i] for i in idx),
            policy=self.scenario.policy,
        )
        return BuiltScenario(
            scenario=scen,
            workloads=tuple(self.workloads[i] for i in idx),
            taskset=sub_taskset,
            design=design,
            table=sub_table,
            requests=tuple(self.requests[i] for i in idx),
            arrivals=tuple(self.arrivals[i] for i in idx),
        )

    def serve_bundle(
        self,
        *,
        period_scale: float,
        seed: int = 0,
        rows: int = 128,
        max_dim: int | None = None,
    ):
        """Rescale to the serving timebase and materialize GEMM chains.

        Returns ``(serve_tasks, requests, arrivals)`` for the
        `TrafficGateway`: periods *and* WCETs scale together by
        ``period_scale`` so every utilization — and therefore every
        admission verdict — is preserved; only the time unit changes.
        ``max_dim`` caps surrogate-GEMM dims for cost-model-driven
        virtual runs (see `design_to_segments`).
        """
        from repro.pipeline.stage_split import design_to_segments

        serve_tasks = design_to_segments(
            self.design,
            list(self.workloads),
            self.taskset,
            rows=rows,
            period_scale=period_scale,
            max_dim=max_dim,
        )
        requests = tuple(
            TaskRequest(
                name=r.name,
                base=tuple(b * period_scale for b in r.base),
                period=r.period * period_scale,
                value=r.value,
                criticality=r.criticality,
            )
            for r in self.requests
        )
        arrivals = tuple(
            spec.arrival.build(
                base_period * period_scale / spec.overdrive,
                seed=seed + 101 * i,
            )
            for i, (spec, base_period) in enumerate(
                zip(self.scenario.tenants, self._base_periods())
            )
        )
        return serve_tasks, requests, arrivals

    def conformance_cost_model(self, serve_tasks, *, period_scale: float = 1.0):
        """The `repro.conformance.CostModel` pricing ``serve_tasks`` on
        this scenario's design — the model-driven replacement for the
        old ``virtual_period_scale`` one-window-per-``virtual_dt``
        quantization: virtual serving is charged per executed window
        from the same exec-model WCETs the analysis uses. Pass the
        same ``period_scale`` the serve bundle was built with so costs
        and periods stay on one timebase.
        """
        from repro.conformance import CostModel

        return CostModel.from_exec_model(
            self.design,
            list(self.workloads),
            serve_tasks,
            period_scale=period_scale,
        )

    def _base_periods(self) -> tuple[float, ...]:
        # un-provisioned tenant periods (P'/ratio), recovered from the
        # provisioned taskset periods
        return tuple(
            t.period * spec.arrival.analysis_period(1.0) ** -1
            for t, spec in zip(self.taskset.tasks, self.scenario.tenants)
        )


def resolve_problem(
    scenario: TrafficScenario, platform
) -> tuple[list[Workload], TaskSet]:
    """Resolve workloads and provisioned periods — the DSE problem a
    scenario defines, before any design is chosen."""
    workloads, periods = [], []
    for spec in scenario.tenants:
        w = resolve_workload(spec)
        p_ref = single_acc_reference_latency(w, platform)
        base_period = p_ref / spec.ratio
        workloads.append(w)
        periods.append(spec.arrival.analysis_period(base_period))
    taskset = TaskSet(
        tasks=tuple(
            Task(workload=w, period=p, name=spec.name)
            for w, p, spec in zip(workloads, periods, scenario.tenants)
        )
    )
    return workloads, taskset


def materialize(
    scenario: TrafficScenario,
    workloads: list[Workload],
    taskset: TaskSet,
    design,
    *,
    seed: int = 0,
) -> BuiltScenario:
    """Turn a chosen `DesignPoint` into a full `BuiltScenario`: segment
    table, admission contracts and seeded traffic. This is the
    DSE -> serving half of `build`, split out so the provisioning
    bridge (`repro.core.dse.provision`) can materialize *any* claimed-
    feasible design — not just the one `build` would have searched."""
    from repro.core.dse.space import evaluate_design

    table = evaluate_design(design.accs, design.splits, workloads, taskset)
    requests = tuple(
        TaskRequest(
            name=spec.name,
            base=tuple(table.base[i]),
            period=taskset.tasks[i].period,
            value=spec.value,
            criticality=spec.criticality,
        )
        for i, spec in enumerate(scenario.tenants)
    )
    arrivals = tuple(
        spec.arrival.build(
            (taskset.tasks[i].period / spec.arrival.analysis_period(1.0))
            / spec.overdrive,
            seed=seed + 101 * i,
        )
        for i, spec in enumerate(scenario.tenants)
    )
    return BuiltScenario(
        scenario=scenario,
        workloads=tuple(workloads),
        taskset=taskset,
        design=design,
        table=table,
        requests=requests,
        arrivals=arrivals,
    )


def build(
    scenario: TrafficScenario,
    platform,
    *,
    max_m: int = 3,
    beam_width: int = 6,
    seed: int = 0,
    design=None,
) -> BuiltScenario:
    """Resolve workloads, size periods, run the DSE, seed the traffic.

    ``design`` (a `DesignPoint`) skips the search and materializes the
    given design instead — the `repro.core.dse.provision` path.
    """
    from repro.core.dse.explore import explore

    workloads, taskset = resolve_problem(scenario, platform)
    if design is None:
        res = explore(
            workloads,
            taskset,
            platform,
            method="beam",
            max_m=max_m,
            beam_width=beam_width,
        )
        design = res.best
        if design is None:
            raise ValueError(
                f"scenario {scenario.name!r} has no feasible design on "
                f"{platform.name}: lower the ratios or the provisioning"
            )
    return materialize(scenario, workloads, taskset, design, seed=seed)


def replicate(built: BuiltScenario, copies: int) -> BuiltScenario:
    """``copies`` independent copies of every tenant on the same
    pipeline design: names suffixed ``#c<i>``, traffic re-seeded per
    copy (same shapes, fresh randomness), per-task design splits
    duplicated. The result deliberately overcommits one pipeline —
    the population the sharded admission (`repro.traffic.shard`) has
    to triage and the autoscaler (`repro.traffic.autoscale`) has to
    absorb by growing the fleet."""
    from dataclasses import replace as dc_replace

    from repro.core.dse.space import DesignPoint

    if copies < 1:
        raise ValueError("need at least one copy")
    n = len(built.requests)
    tenants, workloads, tasks, base, reqs, arrs = [], [], [], [], [], []
    for c in range(copies):
        for i in range(n):
            spec = built.scenario.tenants[i]
            name = spec.name if c == 0 else f"{spec.name}#c{c}"
            tenants.append(dc_replace(spec, name=name))
            workloads.append(built.workloads[i])
            t = built.taskset.tasks[i]
            tasks.append(
                Task(
                    workload=t.workload,
                    period=t.period,
                    deadline=t.deadline,
                    sporadic=t.sporadic,
                    name=name,
                )
            )
            base.append(list(built.table.base[i]))
            r = built.requests[i]
            reqs.append(dc_replace(r, name=name))
            proc = built.arrivals[i]
            arrs.append(
                dc_replace(proc, seed=proc.seed + 7919 * c)
                if hasattr(proc, "seed")
                else proc
            )
    return BuiltScenario(
        scenario=TrafficScenario(
            name=f"{built.scenario.name}x{copies}",
            description=built.scenario.description,
            tenants=tuple(tenants),
            policy=built.scenario.policy,
        ),
        workloads=tuple(workloads),
        taskset=TaskSet(tasks=tuple(tasks)),
        design=DesignPoint(
            accs=built.design.accs,
            splits=tuple(
                tuple(row[i % len(row)] for i in range(copies * n))
                for row in built.design.splits
            ),
            max_util=built.design.max_util * copies,
        ),
        table=SegmentTable(base=base, overhead=list(built.table.overhead)),
        requests=tuple(reqs),
        arrivals=tuple(arrs),
    )


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
SCENARIOS: dict[str, TrafficScenario] = {}


def register(scenario: TrafficScenario) -> TrafficScenario:
    if scenario.name in SCENARIOS:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> TrafficScenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}"
        ) from None


def list_scenarios() -> list[tuple[str, str]]:
    return [(s.name, s.description) for _, s in sorted(SCENARIOS.items())]


register(
    TrafficScenario(
        name="steady_city",
        description=(
            "Baseline smart-transportation mix: periodic LiDAR "
            "perception (PointNet) + periodic camera backbone "
            "(MLP-Mixer), comfortably provisioned"
        ),
        tenants=(
            TenantSpec("paper:pointnet", ratio=1.0, value=3.0),
            TenantSpec("paper:mlp_mixer", ratio=0.8, value=1.0),
        ),
    )
)

register(
    TrafficScenario(
        name="rush_hour",
        description=(
            "Bursty peak traffic: sporadic LiDAR (sensor-synced with "
            "jitter) + MMPP camera stream whose burst state triples "
            "the rate — the admission layer provisions for the burst"
        ),
        tenants=(
            TenantSpec(
                "paper:pointnet",
                ratio=0.8,
                arrival=ArrivalSpec(kind="sporadic", jitter=0.25),
                value=3.0,
            ),
            TenantSpec(
                "paper:deit_t",
                # effective provisioned ratio is 3x this (the burst
                # rate): 0.3 * 3 = 0.9 of the reference latency
                ratio=0.3,
                arrival=ArrivalSpec(
                    kind="mmpp",
                    calm_factor=0.5,
                    burst_factor=3.0,
                    dwells=(40.0, 10.0),
                ),
                value=1.0,
            ),
        ),
    )
)

register(
    TrafficScenario(
        name="sensor_fusion",
        description=(
            "Three-tenant fusion rig: sporadic point-cloud transformer, "
            "periodic ResMLP segmentation, Poisson DeiT detections"
        ),
        tenants=(
            TenantSpec(
                "paper:point_transformer",
                ratio=0.4,
                arrival=ArrivalSpec(kind="sporadic", jitter=0.4),
                value=2.0,
            ),
            TenantSpec("paper:resmlp", ratio=0.35, value=1.5),
            TenantSpec(
                "paper:deit_t",
                ratio=0.25,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.5),
                value=1.0,
            ),
        ),
    )
)

register(
    TrafficScenario(
        name="copilot_decode",
        description=(
            "Safety + assistant: periodic DeiT safety monitor sharing "
            "the pipeline with Poisson LM decode traffic "
            "(stablelm-1.6b from configs/), decode valued lowest"
        ),
        tenants=(
            TenantSpec("paper:deit_t", ratio=0.5, value=5.0),
            TenantSpec(
                "config:stablelm_1_6b:decode",
                ratio=0.3,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.3),
                value=0.5,
                batch=8,
                seq=2048,
            ),
        ),
    )
)

register(
    TrafficScenario(
        name="multi_tenant_rush",
        description=(
            "Four-tenant peak mix for the multi-gateway scale layer: "
            "sporadic LiDAR, an MMPP camera stream overdriven past its "
            "burst provisioning, Poisson segmentation and a periodic "
            "backbone — the shard/ratelimit/shedding benchmark scenario"
        ),
        tenants=(
            TenantSpec(
                "paper:pointnet",
                ratio=0.4,
                arrival=ArrivalSpec(kind="sporadic", jitter=0.25),
                value=3.0,
            ),
            TenantSpec(
                "paper:deit_t",
                ratio=0.12,
                arrival=ArrivalSpec(
                    kind="mmpp",
                    calm_factor=0.5,
                    burst_factor=3.0,
                    dwells=(30.0, 10.0),
                ),
                value=1.0,
                overdrive=3.0,
            ),
            TenantSpec(
                "paper:resmlp",
                ratio=0.25,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.5),
                value=2.0,
                overdrive=3.0,
            ),
            TenantSpec("paper:mlp_mixer", ratio=0.3, value=1.5),
        ),
    )
)

register(
    TrafficScenario(
        name="noisy_neighbor",
        description=(
            "Two well-behaved safety tenants sharing the pipeline with "
            "a low-value Poisson tenant sending 5x its provisioned "
            "rate — the per-tenant rate-limiting and DES-level "
            "shedding stress scenario"
        ),
        tenants=(
            TenantSpec("paper:pointnet", ratio=0.7, value=4.0),
            TenantSpec(
                "paper:resmlp",
                ratio=0.5,
                arrival=ArrivalSpec(kind="sporadic", jitter=0.2),
                value=2.0,
            ),
            TenantSpec(
                "paper:deit_t",
                ratio=0.25,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.3),
                value=0.4,
                overdrive=5.0,
            ),
        ),
    )
)

register(
    TrafficScenario(
        name="sharded_city",
        description=(
            "Four periodic city tenants, comfortably provisioned and "
            "contract-honouring — the sharded-gateway conformance "
            "scenario (placement policies partition it across K "
            "pipeline shards)"
        ),
        tenants=(
            TenantSpec("paper:pointnet", ratio=0.45, value=3.0),
            TenantSpec("paper:mlp_mixer", ratio=0.35, value=1.0),
            TenantSpec("paper:resmlp", ratio=0.3, value=2.0),
            TenantSpec("paper:deit_t", ratio=0.25, value=1.5),
        ),
    )
)

register(
    TrafficScenario(
        name="av_stack",
        description=(
            "AV mixed-criticality stack: safety-critical LiDAR + camera "
            "perception (HI) sharing the pipeline with a best-effort "
            "infotainment tenant (LO) overdriven 5x past its "
            "provisioning — the mode-switch conformance scenario "
            "(overdriven, so it stays out of DEFAULT_SCENARIOS)"
        ),
        tenants=(
            TenantSpec(
                "paper:pointnet",
                ratio=0.55,
                value=5.0,
                criticality=CRITICALITY_HI,
                name="lidar_perception",
            ),
            TenantSpec(
                "paper:deit_t",
                ratio=0.3,
                value=3.0,
                criticality=CRITICALITY_HI,
                name="camera_monitor",
            ),
            TenantSpec(
                "paper:mlp_mixer",
                ratio=0.25,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.3),
                value=0.5,
                overdrive=5.0,
                criticality=CRITICALITY_LO,
                name="infotainment",
            ),
        ),
    )
)

register(
    TrafficScenario(
        name="overload_2x",
        description=(
            "Deliberate 2x overdrive on the camera tenant: traffic "
            "arrives at twice the provisioned rate, contradicting the "
            "analysis — the shedding-policy stress scenario"
        ),
        tenants=(
            TenantSpec("paper:pointnet", ratio=0.8, value=3.0),
            TenantSpec(
                "paper:mlp_mixer",
                ratio=0.7,
                arrival=ArrivalSpec(kind="poisson", provision_factor=1.2),
                value=1.0,
                overdrive=2.0,
            ),
        ),
    )
)
