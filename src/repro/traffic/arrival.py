"""Arrival models: how jobs actually reach a PHAROS deployment.

The paper's analysis (Eqs. 2–3) assumes periodic/sporadic releases with
a known minimum inter-arrival; live traffic is messier. Every generator
here implements one `ArrivalProcess` protocol:

- ``arrivals(horizon)``   — release times in ``[0, horizon)``, sorted.
  Deterministic: the same (params, seed) always produce the same trace,
  and extending the horizon only appends (prefix-stable), so DES runs,
  gateway runs and benchmarks all see the same traffic.
- ``mean_rate()``         — long-run jobs/second.
- ``analysis_period()``   — the inter-arrival bound handed to the Eq. 2
  utilization accounting. For periodic/sporadic traffic this is exact
  (the minimum gap). Poisson/MMPP traffic has *no* minimum gap, so the
  admission layer provisions for ``provision_factor`` times the mean
  rate (MMPP: the peak-state rate) — a documented heuristic, with the
  overload-shedding layer as the safety net for the residual tail risk.

Generators: `PeriodicArrivals`, `SporadicArrivals` (min inter-arrival +
optional random extra gap), `PoissonArrivals`, `MMPPArrivals` (2-state
Markov-modulated Poisson — the bursty model), `TraceArrivals` (replay).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Protocol, Sequence, runtime_checkable


@runtime_checkable
class ArrivalProcess(Protocol):
    def arrivals(self, horizon: float) -> list[float]: ...

    def mean_rate(self) -> float: ...

    def analysis_period(self) -> float: ...


@dataclass(frozen=True)
class PeriodicArrivals:
    """Strictly periodic releases: ``phase + n * period``."""

    period: float
    phase: float = 0.0

    def __post_init__(self) -> None:
        if self.period <= 0:
            raise ValueError("period must be positive")

    def arrivals(self, horizon: float) -> list[float]:
        out, t = [], self.phase
        while t < horizon:
            out.append(t)
            t += self.period
        return out

    def mean_rate(self) -> float:
        return 1.0 / self.period

    def analysis_period(self) -> float:
        return self.period


@dataclass(frozen=True)
class SporadicArrivals:
    """Sporadic releases: gaps of ``min_gap`` plus an exponential extra
    gap of mean ``jitter * min_gap``. ``jitter == 0`` degenerates to
    exactly periodic (gap == min_gap), which is what ties the sporadic
    model back to the paper's periodic analysis."""

    min_gap: float
    jitter: float = 0.0
    phase: float = 0.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.min_gap <= 0:
            raise ValueError("min_gap must be positive")
        if self.jitter < 0:
            raise ValueError("jitter must be non-negative")

    def arrivals(self, horizon: float) -> list[float]:
        rng = random.Random(self.seed)
        out, t = [], self.phase
        while t < horizon:
            out.append(t)
            extra = (
                rng.expovariate(1.0 / (self.jitter * self.min_gap))
                if self.jitter > 0
                else 0.0
            )
            t += self.min_gap + extra
        return out

    def mean_rate(self) -> float:
        return 1.0 / (self.min_gap * (1.0 + self.jitter))

    def analysis_period(self) -> float:
        return self.min_gap


@dataclass(frozen=True)
class PoissonArrivals:
    """Memoryless arrivals at ``rate`` jobs/s (exponential gaps)."""

    rate: float
    phase: float = 0.0
    seed: int = 0
    #: utilization is provisioned for rate * provision_factor (Poisson
    #: has no minimum gap; see module docstring)
    provision_factor: float = 1.5

    def __post_init__(self) -> None:
        if self.rate <= 0:
            raise ValueError("rate must be positive")
        if self.provision_factor < 1.0:
            raise ValueError("provision_factor must be >= 1")

    def arrivals(self, horizon: float) -> list[float]:
        rng = random.Random(self.seed)
        out, t = [], self.phase + rng.expovariate(self.rate)
        while t < horizon:
            out.append(t)
            t += rng.expovariate(self.rate)
        return out

    def mean_rate(self) -> float:
        return self.rate

    def analysis_period(self) -> float:
        return 1.0 / (self.rate * self.provision_factor)


@dataclass(frozen=True)
class MMPPArrivals:
    """2-state Markov-modulated Poisson process — the bursty model.

    The process alternates between a *calm* state (Poisson at
    ``rates[0]``) and a *burst* state (Poisson at ``rates[1]``), with
    exponential dwell times of mean ``dwells[s]`` seconds. Utilization
    is provisioned for the burst-state rate: bursts shorter than the
    response-time scale then stay inside the analysis, and sustained
    bursts beyond it are the shedding layer's problem by construction.
    """

    rates: tuple[float, float]
    dwells: tuple[float, float]
    phase: float = 0.0
    seed: int = 0
    provision_factor: float = 1.0  # applied to the burst-state rate

    def __post_init__(self) -> None:
        if len(self.rates) != 2 or len(self.dwells) != 2:
            raise ValueError("MMPP needs exactly two states")
        if min(self.rates) < 0 or max(self.rates) <= 0:
            raise ValueError("rates must be non-negative, one positive")
        if min(self.dwells) <= 0:
            raise ValueError("dwell times must be positive")

    def arrivals(self, horizon: float) -> list[float]:
        rng = random.Random(self.seed)
        out: list[float] = []
        t, state = self.phase, 0
        state_end = t + rng.expovariate(1.0 / self.dwells[0])
        while t < horizon:
            rate = self.rates[state]
            if rate <= 0:
                t = state_end
            else:
                nxt = t + rng.expovariate(rate)
                if nxt < state_end:
                    t = nxt
                    if t < horizon:
                        out.append(t)
                    continue
                t = state_end
            state = 1 - state
            state_end = t + rng.expovariate(1.0 / self.dwells[state])
        return out

    def mean_rate(self) -> float:
        d0, d1 = self.dwells
        return (self.rates[0] * d0 + self.rates[1] * d1) / (d0 + d1)

    def peak_rate(self) -> float:
        return max(self.rates)

    def analysis_period(self) -> float:
        return 1.0 / (self.peak_rate() * self.provision_factor)


@dataclass(frozen=True)
class TraceArrivals:
    """Replay recorded release times (e.g. a production trace)."""

    times: tuple[float, ...]
    #: optional provisioned period for the analysis; 0 -> min gap
    provisioned_period: float = 0.0

    def __post_init__(self) -> None:
        ts = tuple(float(t) for t in self.times)
        if any(t < 0 for t in ts):
            raise ValueError("trace times must be non-negative")
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("trace times must be non-decreasing")
        object.__setattr__(self, "times", ts)

    def arrivals(self, horizon: float) -> list[float]:
        return [t for t in self.times if t < horizon]

    def mean_rate(self) -> float:
        if len(self.times) < 2:
            return 0.0
        span = self.times[-1] - self.times[0]
        return (len(self.times) - 1) / span if span > 0 else math.inf

    def analysis_period(self) -> float:
        if self.provisioned_period > 0:
            return self.provisioned_period
        if len(self.times) < 2:
            return math.inf
        gap = min(b - a for a, b in zip(self.times, self.times[1:]))
        return gap if gap > 0 else 0.0


def merge_arrivals(
    processes: Sequence[ArrivalProcess], horizon: float
) -> list[tuple[float, int]]:
    """Interleave per-task traces into one sorted release schedule of
    ``(time, task_index)`` — ties release lower task indices first."""
    sched = [
        (t, i)
        for i, p in enumerate(processes)
        for t in p.arrivals(horizon)
    ]
    sched.sort()
    return sched
