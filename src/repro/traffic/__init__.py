"""Traffic & admission control for the PHAROS serving stack.

Turns the paper's design-time analysis (Eqs. 2–3, response bounds) into
an *online* layer in front of the serving runtime:

- `arrival`   — seedable arrival models (periodic, sporadic, Poisson,
  bursty MMPP, trace replay) behind one `ArrivalProcess` protocol;
- `admission` — `AdmissionController`: O(stages) admit/reject verdicts
  that agree bit-exactly with a full `srt_schedulable` re-analysis,
  plus headroom/sensitivity reports, and the batched front-end
  (`check_many` / `score_many`) pricing whole tenant cohorts in one
  array pass (docs/scale.md);
- `shedding`  — overload policies (reject-newest, shed-by-value,
  degrade-to-best-effort) + the `BacklogMonitor` that engages them when
  observed backlog contradicts the analysis, and the
  `des_release_shedding` adapter pushing the same decisions into the
  DES;
- `ratelimit` — per-tenant token buckets (`RateLimiter`, array-backed:
  `allow_many` sweeps a whole due batch vectorized, `from_arrays`
  provisions million-tenant fleets) trimming live traffic back to the
  provisioned contract in front of admission;
- `modes`     — mixed-criticality overload modes (`ModeController`):
  HI/LO tenant classes, backlog-triggered HI-mode switches that re-run
  the Eq. 3 admission over the HI survivor set *before* committing,
  and symmetric recovery when the backlog drains;
- `gateway`   — `TrafficGateway`: the admission-controlled front door
  releasing `ArrivalProcess` traffic into a `PharosServer`;
- `shard`     — `ShardedGateway`: K gateway replicas of one pipeline
  with pluggable tenant placement (hash / least-loaded / slack-aware),
  co-simulated on one shared `VirtualClock` (and, in elastic mode,
  accepting live tenant re-homing mid-run);
- `migration` — `MigrationController`: slack-aware live tenant
  migration between shards — drain the donor, re-prove the Eq. 3
  contract on the target, commit only if the proof succeeds;
- `autoscale` — `Autoscaler`: epoch-based elastic shard fleet, growing
  K when placement is unprovable and draining the emptiest shard when
  the survivors re-prove elsewhere;
- `scenarios` — named traffic mixes (smart-transportation style) built
  from the paper workloads and the LM `configs/`;
- `clock`     — `WallClock` / deterministic `VirtualClock` shared by
  gateway and server.
"""
from repro.traffic.admission import (
    CRITICALITY_HI,
    CRITICALITY_LEVELS,
    CRITICALITY_LO,
    AdmissionController,
    AdmissionDecision,
    HeadroomReport,
    TaskRequest,
    calibrated_requests,
)
from repro.traffic.arrival import (
    ArrivalProcess,
    MMPPArrivals,
    PeriodicArrivals,
    PoissonArrivals,
    SporadicArrivals,
    TraceArrivals,
    merge_arrivals,
)
from repro.traffic.autoscale import (
    AutoscaleReport,
    Autoscaler,
    EpochResult,
    RampPhase,
)
from repro.traffic.clock import VirtualClock, WallClock
from repro.traffic.gateway import GatewayReport, TrafficGateway
from repro.traffic.migration import (
    MigrationController,
    MigrationPlan,
    MigrationRecord,
)
from repro.traffic.modes import (
    MODE_HI,
    MODE_NORMAL,
    MODES,
    ModeController,
    ModeSwitch,
)
from repro.traffic.ratelimit import RateLimiter, TokenBucket
from repro.traffic.scenarios import (
    ArrivalSpec,
    BuiltScenario,
    TenantSpec,
    TrafficScenario,
    build,
    get_scenario,
    list_scenarios,
    materialize,
    register,
    replicate,
    resolve_problem,
)
from repro.traffic.shard import (
    HashByTenant,
    LeastLoaded,
    ShardedGateway,
    ShardedReport,
    ShardHeadroom,
    ShardPlan,
    SlackAware,
    built_gateway,
    get_placement,
    plan_shards,
)
from repro.traffic.shedding import (
    BacklogMonitor,
    DegradeToBestEffort,
    RejectNewest,
    ShedByValue,
    des_release_shedding,
    get_policy,
)

__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "CRITICALITY_HI",
    "CRITICALITY_LEVELS",
    "CRITICALITY_LO",
    "HeadroomReport",
    "TaskRequest",
    "calibrated_requests",
    "ArrivalProcess",
    "PeriodicArrivals",
    "SporadicArrivals",
    "PoissonArrivals",
    "MMPPArrivals",
    "TraceArrivals",
    "merge_arrivals",
    "VirtualClock",
    "WallClock",
    "TrafficGateway",
    "GatewayReport",
    "MODE_HI",
    "MODE_NORMAL",
    "MODES",
    "ModeController",
    "ModeSwitch",
    "ArrivalSpec",
    "TenantSpec",
    "TrafficScenario",
    "BuiltScenario",
    "build",
    "get_scenario",
    "list_scenarios",
    "materialize",
    "register",
    "replicate",
    "resolve_problem",
    "MigrationController",
    "MigrationPlan",
    "MigrationRecord",
    "Autoscaler",
    "AutoscaleReport",
    "EpochResult",
    "RampPhase",
    "BacklogMonitor",
    "RejectNewest",
    "ShedByValue",
    "DegradeToBestEffort",
    "des_release_shedding",
    "get_policy",
    "RateLimiter",
    "TokenBucket",
    "ShardedGateway",
    "ShardedReport",
    "ShardHeadroom",
    "ShardPlan",
    "HashByTenant",
    "LeastLoaded",
    "SlackAware",
    "built_gateway",
    "get_placement",
    "plan_shards",
]
