"""Mixed-criticality overload modes for the serving stack.

The shedding layer (`repro.traffic.shedding`) reacts to overload one
release at a time: when a tenant's observed backlog contradicts the
analysis, the *cheapest* work is dropped or demoted, regardless of what
it is. Safety-critical deployments need the inverse contract — a
Vestal-style mixed-criticality story in the spirit of MESC's
criticality-inversion analysis and HetSched's quality-of-mission
scheduling (see PAPERS.md): tenants carry a criticality class
(`TaskRequest.criticality`, "HI"/"LO"), and overload triggers a *mode
switch* with per-class guarantees instead of a per-job value call.

`ModeController` is that state machine:

- **normal mode** — every admitted tenant keeps its Eq. 3 guarantee;
  releases flow untouched.
- **HI-mode switch** — driven by the exact `BacklogMonitor` hysteresis
  the shedding layer uses (engage when pending backlog exceeds the
  analysis-derived limit, disengage at half of it). Before the switch
  *commits*, the controller re-runs Eq. 3 admission for the surviving
  HI set on a fresh `AdmissionController` — the per-class guarantee is
  re-*proved*, not assumed; a HI tenant that fails the re-proof (e.g.
  under a tightened `hi_util_cap`) is excluded from the survivor set
  and handled like LO work. While in HI mode every LO release is shed
  (``action="drop"``) or demoted to best-effort (``action="degrade"``),
  and the gateway tightens LO rate limiting (`release_cost`).
- **symmetric recovery** — when every tenant's backlog has drained
  below the disengage threshold, the controller re-proves the full
  guaranteed set and switches back to normal mode.

The controller implements the same duck type the DES's release-time
shedding hook consumes (`observe`/`engaged`/`classify`, see
`repro.scheduler.des.ReleaseShedding`), so one object serves as
``SimConfig.shedding`` in the DES and as ``TrafficGateway(modes=...)``
in the runtime; `run_mode_switch_case` in the conformance harness
checks the two layers agree on the survivor set and that HI tenants
miss zero deadlines across every transition. Mode transitions are
recorded in `switches` and drained (`drain_events`) by the host layer,
which stamps the current time and emits the ``mode_switch`` trace kind.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.traffic.admission import (
    CRITICALITY_HI,
    CRITICALITY_LO,
    AdmissionController,
    TaskRequest,
)
from repro.traffic.shedding import (
    BEST_EFFORT,
    DROP,
    SUBMIT,
    BacklogMonitor,
)

#: the two overload modes (extensible in the same way the criticality
#: levels are: one mode per shed threshold)
MODE_NORMAL = "normal"
MODE_HI = "hi"
MODES = (MODE_NORMAL, MODE_HI)

#: LO-handling verdicts a controller may apply while in HI mode
MODE_ACTIONS = ("drop", "degrade")


@dataclass(frozen=True)
class ModeSwitch:
    """One committed mode transition.

    ``survivors`` is the guarantee set *after* the transition: the
    re-proved HI tenants on a switch into HI mode, the full guaranteed
    set on recovery. ``max_util`` / ``schedulable`` are the Eq. 3
    re-proof that gated the commit (`AdmissionController.check` on a
    fresh controller).
    """

    mode: str
    survivors: tuple[str, ...]
    max_util: float
    schedulable: bool


class ModeController:
    """Criticality-aware overload-mode state machine (module docstring).

    ``admission`` supplies the analysis context (overheads, preemption
    model, response bounds for the backlog limits); ``requests`` are
    the tenant contracts in task-index order — the same order the DES
    and the gateway index tasks by. ``action`` picks the LO fate in HI
    mode; ``hi_util_cap`` optionally tightens the Eq. 3 cap the HI
    re-proof must meet; ``lo_release_cost`` is the token-bucket cost
    multiplier the gateway charges LO releases while in HI mode.
    """

    def __init__(
        self,
        admission: AdmissionController,
        requests,
        *,
        monitor: BacklogMonitor | None = None,
        action: str = "degrade",
        hi_util_cap: float | None = None,
        lo_release_cost: float = 2.0,
        bound_policy: str | None = None,
    ):
        if action not in MODE_ACTIONS:
            raise ValueError(
                f"unknown mode action {action!r}; have {MODE_ACTIONS}"
            )
        if lo_release_cost < 1.0:
            raise ValueError("lo_release_cost must be >= 1.0")
        self.admission = admission
        self.requests: tuple[TaskRequest, ...] = tuple(requests)
        self.monitor = monitor or BacklogMonitor()
        self.action = action
        self.hi_util_cap = hi_util_cap
        self.lo_release_cost = lo_release_cost
        self.bound_policy = bound_policy
        self.mode = MODE_NORMAL
        self.switches: list[ModeSwitch] = []
        self._survivors: frozenset[str] = frozenset()
        self._pending: list[ModeSwitch] = []
        self._limits: tuple[int, ...] | None = None

    # -- identity (SheddingPolicy-compatible surface) -------------------
    @property
    def name(self) -> str:
        return f"mode_{self.action}"

    @property
    def drops(self) -> bool:
        """Whether HI mode removes LO work (vs demoting it)."""
        return self.action == "drop"

    @property
    def engaged(self) -> dict[int, bool]:
        """Per-task hysteresis state (the DES reads this dict)."""
        return self.monitor.engaged

    @property
    def survivors(self) -> tuple[str, ...]:
        """The current guarantee set, admission order."""
        if self.mode == MODE_NORMAL:
            return tuple(r.name for r in self._guaranteed())
        return tuple(
            r.name for r in self._guaranteed() if r.name in self._survivors
        )

    # -- the backlog-driven state machine -------------------------------
    def limits(self) -> tuple[int, ...]:
        """Analysis-derived engage limits, one per task (lazy: response
        bounds need the admitted set, which the gateway only commits at
        `open`)."""
        if self._limits is None:
            bounds = self.admission.response_bounds(self.bound_policy)
            self._limits = tuple(
                self.monitor.limit_for(
                    bounds.get(r.name, math.inf), r.period
                )
                for r in self.requests
            )
        return self._limits

    def observe(self, task_idx: int, pending: int) -> bool:
        """Feed one backlog observation; commit any resulting mode
        transition. Same signature the DES's shedding hook uses."""
        on = self.monitor.observe(task_idx, pending, self.limits()[task_idx])
        self._maybe_transition()
        return on

    def _any_engaged(self) -> bool:
        eng = self.monitor.engaged
        return any(eng.get(i, False) for i in range(len(self.requests)))

    def _guaranteed(self) -> list[TaskRequest]:
        return [r for r in self.requests if not r.best_effort]

    def _prove(self, requests) -> tuple[tuple[str, ...], float, bool]:
        """Eq. 3 re-proof: greedily re-admit ``requests`` on a fresh
        controller. Returns (admitted names, max stage util, all fit)."""
        ctl = AdmissionController(
            self.admission.overheads,
            preemptive=self.admission.preemptive,
            util_cap=(
                self.hi_util_cap
                if self.hi_util_cap is not None
                else self.admission.util_cap
            ),
        )
        names, all_fit = [], True
        for r in requests:
            if ctl.admit(r).admitted:
                names.append(r.name)
            else:
                all_fit = False
        utils = ctl.utilizations()
        return tuple(names), (max(utils) if utils else 0.0), all_fit

    def _maybe_transition(self) -> None:
        overloaded = self._any_engaged()
        if self.mode == MODE_NORMAL and overloaded:
            # re-prove Eq. 3 for the HI set *before* the switch commits
            hi = [
                r
                for r in self._guaranteed()
                if r.criticality == CRITICALITY_HI
            ]
            names, max_util, all_fit = self._prove(hi)
            self.mode = MODE_HI
            self._survivors = frozenset(names)
            sw = ModeSwitch(
                mode=MODE_HI,
                survivors=names,
                max_util=max_util,
                schedulable=all_fit,
            )
            self.switches.append(sw)
            self._pending.append(sw)
        elif self.mode == MODE_HI and not overloaded:
            # symmetric recovery: the full guaranteed set is re-proved
            # and restored
            names, max_util, all_fit = self._prove(self._guaranteed())
            self.mode = MODE_NORMAL
            self._survivors = frozenset()
            sw = ModeSwitch(
                mode=MODE_NORMAL,
                survivors=names,
                max_util=max_util,
                schedulable=all_fit,
            )
            self.switches.append(sw)
            self._pending.append(sw)

    def drain_events(self) -> list[ModeSwitch]:
        """Transitions committed since the last drain — the host layer
        (DES / gateway) stamps its clock and emits ``mode_switch``."""
        out, self._pending = self._pending, []
        return out

    # -- per-release verdicts -------------------------------------------
    def classify(
        self, task_idx: int, overloaded=(), admission=None, requests=None
    ) -> str:
        """Release verdict for ``task_idx`` under the current mode.

        Signature-compatible with both the DES shedding hook
        (positional ``overloaded``) and `SheddingPolicy.classify`; the
        verdict depends only on the committed mode and the survivor
        set, never on which tenant happens to be overloaded.
        """
        if self.mode != MODE_HI:
            return SUBMIT
        r = self.requests[task_idx]
        if not r.best_effort and r.name in self._survivors:
            return SUBMIT
        return DROP if self.action == "drop" else BEST_EFFORT

    def release_cost(self, task_idx: int) -> float:
        """Token-bucket cost of one release — the gateway's HI-mode
        rate tightening: LO releases pay ``lo_release_cost`` tokens
        while HI mode holds, halving (by default) their sustained
        rate; survivors always pay 1."""
        if self.mode != MODE_HI:
            return 1.0
        r = self.requests[task_idx]
        if not r.best_effort and r.name in self._survivors:
            return 1.0
        return self.lo_release_cost


def criticality_counts(requests) -> dict[str, int]:
    """Tenant count per criticality level (reporting helper)."""
    out = {CRITICALITY_HI: 0, CRITICALITY_LO: 0}
    for r in requests:
        out[r.criticality] = out.get(r.criticality, 0) + 1
    return out
