"""Per-tenant token/credit rate limiting in front of admission.

Admission (`AdmissionController`) polices the *contract*: a tenant is
admitted iff its provisioned rate fits Eq. 3. The rate limiter polices
the *traffic*: even an admitted tenant only releases jobs while its
token bucket has credit, so a tenant whose live traffic exceeds its
provisioned rate is trimmed back to the contract at the front door —
before the backlog monitor ever has to engage shedding. Shedding stays
the safety net for modeled-vs-real WCET error; the bucket handles the
much more common "client sends too fast" overload.

Model: one token bucket per tenant — capacity ``burst`` tokens,
refilled continuously at ``rate`` tokens/second, one token per release.
Both knobs come from the tenant's `TaskRequest` via
`RateLimiter.for_requests`: the sustained rate is the provisioned rate
(``rate_scale / period``) and the burst is ``burst_periods`` worth of
it. With ``value_weighted=True`` the tenant's shed-value relative to
the mix mean shapes the bucket — the token-bucket analogue of
`ShedByValue`'s ordering — but only ever *downward* on the sustained
rate: a below-mean-value tenant refills slower than its contract,
while an above-mean tenant keeps the contract rate (never more — the
sustained rate is capped at the provisioned rate, so rate-limited
traffic always satisfies the admission premise) and earns its
advantage as extra burst capacity instead.

State layout: the limiter is **array-backed** — rate/burst/token/
timestamp vectors over all tenants, not per-bucket Python objects — so
the gateway's release sweep can refill and charge a whole event batch
in one `allow_many` pass (the million-tenant hot path). The scalar
`allow`/`tokens` API operates on the same vectors and `allow_many` is
bit-identical to looping it (property-tested exact ``==``, duplicate
tenants in a batch included). `TokenBucket` remains as the single-
bucket reference implementation and the `RateLimiter(buckets)`
construction vocabulary; `bucket(i)` returns a live array-backed view
with the same attribute surface.

Everything is deterministic: buckets are refilled lazily from the
release timestamps themselves (no wall clock), so a virtual-time
gateway run is bit-reproducible and a sharded gateway with one shard
reproduces the unsharded decisions exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.traffic.admission import TaskRequest


@dataclass
class TokenBucket:
    """Classic leaky/token bucket: ``burst`` capacity, ``rate``/s refill.

    Starts full (a tenant may burst immediately after admission).
    ``take`` is lazy-refill: credit accrued since the last call is added
    first, then one token is consumed if available. Timestamps must be
    non-decreasing per bucket (the gateway releases in time order);
    a stale timestamp refills nothing rather than going negative.

    This is the scalar *reference* semantics; `RateLimiter` carries the
    same state as per-tenant arrays and reproduces ``take`` bit-for-bit
    (`allow` single events, `allow_many` whole batches).
    """

    rate: float
    burst: float
    tokens: float = -1.0  # sentinel: initialize to full burst
    last: float = 0.0
    granted: int = 0
    denied: int = 0

    def __post_init__(self) -> None:
        if self.rate <= 0.0 or self.burst < 1.0:
            raise ValueError("need rate > 0 and burst >= 1 token")
        if self.tokens < 0.0:
            self.tokens = float(self.burst)

    def peek(self, now: float) -> float:
        """Credit available at ``now`` (no state change)."""
        return min(
            self.burst, self.tokens + max(0.0, now - self.last) * self.rate
        )

    def take(self, now: float, cost: float = 1.0) -> bool:
        """Spend ``cost`` tokens (default one). A cost above 1 is how
        the mixed-criticality gateway tightens a LO tenant's bucket in
        HI mode (`ModeController.release_cost`): the sustained rate
        divides by the cost without rebuilding the bucket."""
        if cost < 1.0:
            raise ValueError("token cost must be >= 1")
        self.tokens = self.peek(now)
        self.last = max(self.last, now)
        if self.tokens >= cost:
            self.tokens -= cost
            self.granted += 1
            return True
        self.denied += 1
        return False


class _BucketView:
    """Live single-tenant window into the limiter's state arrays —
    the `TokenBucket` attribute surface (rate/burst/tokens/last/
    granted/denied + peek/take) bound to index ``i``."""

    __slots__ = ("_rl", "_i")

    def __init__(self, rl: "RateLimiter", i: int):
        self._rl = rl
        self._i = i

    @property
    def rate(self) -> float:
        return float(self._rl._rate[self._i])

    @property
    def burst(self) -> float:
        return float(self._rl._burst[self._i])

    @property
    def tokens(self) -> float:
        return float(self._rl._tokens[self._i])

    @property
    def last(self) -> float:
        return float(self._rl._last[self._i])

    @property
    def granted(self) -> int:
        return int(self._rl._granted[self._i])

    @property
    def denied(self) -> int:
        return int(self._rl._denied[self._i])

    def peek(self, now: float) -> float:
        return self._rl.tokens(self._i, now)

    def take(self, now: float, cost: float = 1.0) -> bool:
        return self._rl.allow(self._i, now, cost)


class _BucketSeq(Sequence):
    """``limiter.buckets`` compatibility shim: index -> `_BucketView`."""

    __slots__ = ("_rl",)

    def __init__(self, rl: "RateLimiter"):
        self._rl = rl

    def __len__(self) -> int:
        return len(self._rl)

    def __getitem__(self, i: int) -> _BucketView:
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        return _BucketView(self._rl, range(len(self))[i])


class RateLimiter:
    """Per-tenant bucket array the `TrafficGateway` consults per release.

    Index ``i`` addresses the gateway's tenant ``i`` (the same 1:1
    alignment the gateway keeps between requests, arrivals and server
    tasks). ``allow(i, now)`` spends one token of tenant ``i``'s bucket;
    a ``False`` verdict means the release is refused up front (counted
    as ``rate_limited`` in `TenantStats`, never submitted, never shed).
    ``allow_many`` is the vectorized sweep over a whole due-release
    batch — one lazy refill + charge pass over the state arrays.
    """

    def __init__(self, buckets: Sequence[TokenBucket]):
        if len(buckets) == 0:
            raise ValueError("need at least one bucket")
        self._rate = np.array([b.rate for b in buckets], dtype=np.float64)
        self._burst = np.array([b.burst for b in buckets], dtype=np.float64)
        self._tokens = np.array(
            [b.tokens for b in buckets], dtype=np.float64
        )
        self._last = np.array([b.last for b in buckets], dtype=np.float64)
        self._granted = np.array(
            [b.granted for b in buckets], dtype=np.int64
        )
        self._denied = np.array([b.denied for b in buckets], dtype=np.int64)
        self.buckets = _BucketSeq(self)

    @classmethod
    def from_arrays(cls, rates, bursts) -> "RateLimiter":
        """Provision straight from rate/burst vectors — the soak-scale
        path (`benchmarks/scale_bench.py`), which must not build one
        Python `TokenBucket` per tenant at 10^6 tenants. Buckets start
        full, same as the `TokenBucket` constructor."""
        rl = cls.__new__(cls)
        rl._rate = np.asarray(rates, dtype=np.float64).copy()
        rl._burst = np.asarray(bursts, dtype=np.float64).copy()
        if rl._rate.ndim != 1 or rl._rate.shape != rl._burst.shape:
            raise ValueError("rates/bursts must be equal-length vectors")
        if len(rl._rate) == 0:
            raise ValueError("need at least one bucket")
        if (rl._rate <= 0.0).any() or (rl._burst < 1.0).any():
            raise ValueError("need rate > 0 and burst >= 1 token")
        rl._tokens = rl._burst.copy()
        rl._last = np.zeros_like(rl._rate)
        rl._granted = np.zeros(len(rl._rate), dtype=np.int64)
        rl._denied = np.zeros(len(rl._rate), dtype=np.int64)
        rl.buckets = _BucketSeq(rl)
        return rl

    @classmethod
    def for_requests(
        cls,
        requests: Sequence[TaskRequest],
        *,
        rate_scale: float = 1.0,
        burst_periods: float = 2.0,
        value_weighted: bool = False,
    ) -> "RateLimiter":
        """Provision one bucket per tenant from its analysis contract.

        Tenant i sustains ``rate_scale * min(w_i, 1) / period_i``
        jobs/s with a burst of ``max(1, burst_periods * w_i)`` jobs,
        where ``w_i`` is 1 or, when ``value_weighted``, the tenant's
        value over the mix mean value. The rate weight is capped at 1:
        value can only *slow* a tenant below its contract (and grow its
        burst), never sustain it above the provisioned rate the
        admission analysis accounted for.
        """
        if rate_scale <= 0.0 or burst_periods <= 0.0:
            raise ValueError("rate_scale and burst_periods must be positive")
        if value_weighted:
            mean_v = sum(r.value for r in requests) / len(requests)
            # floor the weight: value 0 is a legal contract (ShedByValue
            # treats it as shed-first), so it must yield a slow bucket,
            # not a zero-rate one the constructor rejects
            weights = [
                max(r.value / mean_v, 0.01) if mean_v > 0 else 1.0
                for r in requests
            ]
        else:
            weights = [1.0] * len(requests)
        return cls.from_arrays(
            [
                rate_scale * min(w, 1.0) / r.period
                for r, w in zip(requests, weights)
            ],
            [max(1.0, burst_periods * w) for w in weights],
        )

    def __len__(self) -> int:
        return len(self._rate)

    def bucket(self, i: int) -> _BucketView:
        """Live view of tenant ``i``'s bucket state."""
        return _BucketView(self, range(len(self))[i])

    def allow(self, i: int, now: float, cost: float = 1.0) -> bool:
        """Spend ``cost`` tokens of tenant ``i`` at time ``now`` —
        `TokenBucket.take` on the state arrays, bit-for-bit."""
        if cost < 1.0:
            raise ValueError("token cost must be >= 1")
        tok = min(
            self._burst[i],
            self._tokens[i]
            + max(0.0, now - self._last[i]) * self._rate[i],
        )
        self._last[i] = max(self._last[i], now)
        if tok >= cost:
            self._tokens[i] = tok - cost
            self._granted[i] += 1
            return True
        self._tokens[i] = tok
        self._denied[i] += 1
        return False

    def allow_many(self, times, indices, costs=None) -> np.ndarray:
        """Vectorized sweep over one due-release batch: verdicts for
        event ``j`` = release of tenant ``indices[j]`` at
        ``times[j]``, bit-identical to looping `allow` in batch order.

        Per-tenant timestamps must be non-decreasing in batch order
        (the gateway's release schedule is globally time-sorted).
        Duplicate tenants in one batch are handled exactly: events are
        swept in occurrence-rank waves — every tenant's first event in
        one vector pass, then every second event, ... — so each wave
        touches each bucket at most once and successive events of one
        tenant still see each other's refill/charge in order. Deep
        duplicate runs (a Zipf-hot tenant can occur hundreds of times
        per batch, making late waves tiny) fall back to a per-run
        scalar sweep once a wave drops below the vectorization
        break-even: the bucket's state is hoisted into Python floats
        once per run, the run replays `TokenBucket.take`'s exact IEEE
        ops per event, and the state is stored back once — same ops,
        same order, still bit-identical.
        """
        idx = np.asarray(indices, dtype=np.intp)
        t = np.asarray(times, dtype=np.float64)
        if idx.shape != t.shape or idx.ndim != 1:
            raise ValueError("times/indices must be equal-length vectors")
        n = len(idx)
        out = np.empty(n, dtype=bool)
        if n == 0:
            return out
        if costs is None:
            cost = np.ones(n, dtype=np.float64)
        else:
            cost = np.asarray(costs, dtype=np.float64)
            if cost.shape != idx.shape:
                raise ValueError("costs must align 1:1 with events")
            if (cost < 1.0).any():
                raise ValueError("token cost must be >= 1")
        # occurrence rank of each event among its tenant's events (in
        # batch order): rank r events form wave r
        order = np.argsort(idx, kind="stable")
        sorted_idx = idx[order]
        run_start = np.empty(n, dtype=bool)
        run_start[0] = True
        run_start[1:] = sorted_idx[1:] != sorted_idx[:-1]
        start_pos = np.flatnonzero(run_start)
        rank_sorted = np.arange(n) - start_pos[np.cumsum(run_start) - 1]
        rank = np.empty(n, dtype=np.intp)
        rank[order] = rank_sorted
        # regroup by rank once: wave r is a contiguous slice (batch
        # order within — stable sort), no per-wave scan over all events
        by_rank = np.argsort(rank, kind="stable")
        wave_counts = np.bincount(rank)
        # wave sizes are non-increasing in r (a tenant in wave r is in
        # every earlier wave), so the vector waves are a prefix and the
        # small-wave residue a suffix of `by_rank`
        n_vec_waves = int((wave_counts >= 32).sum())
        offset = 0
        for r in range(n_vec_waves):
            c = int(wave_counts[r])
            sel = by_rank[offset:offset + c]
            offset += c
            ii = idx[sel]
            tok = np.minimum(
                self._burst[ii],
                self._tokens[ii]
                + np.maximum(0.0, t[sel] - self._last[ii])
                * self._rate[ii],
            )
            self._last[ii] = np.maximum(self._last[ii], t[sel])
            ok = tok >= cost[sel]
            self._tokens[ii] = np.where(ok, tok - cost[sel], tok)
            self._granted[ii] += ok
            self._denied[ii] += ~ok
            out[sel] = ok
        if offset < n:
            run_len = np.diff(np.append(start_pos, n))
            t_l = t.tolist()
            cost_l = cost.tolist()
            for u in np.flatnonzero(run_len > n_vec_waves).tolist():
                s0 = int(start_pos[u])
                ev = order[
                    s0 + n_vec_waves : s0 + int(run_len[u])
                ].tolist()
                i = int(sorted_idx[s0])
                rate = float(self._rate[i])
                burst = float(self._burst[i])
                tokens = float(self._tokens[i])
                last = float(self._last[i])
                granted = denied = 0
                for j in ev:
                    now = t_l[j]
                    tok = min(
                        burst, tokens + max(0.0, now - last) * rate
                    )
                    last = max(last, now)
                    if tok >= cost_l[j]:
                        tokens = tok - cost_l[j]
                        granted += 1
                        out[j] = True
                    else:
                        tokens = tok
                        denied += 1
                        out[j] = False
                self._tokens[i] = tokens
                self._last[i] = last
                self._granted[i] += granted
                self._denied[i] += denied
        return out

    def tokens(self, i: int, now: float) -> float:
        """Credit available to tenant ``i`` at ``now`` (no state
        change) — `TokenBucket.peek` on the state arrays."""
        return float(
            min(
                self._burst[i],
                self._tokens[i]
                + max(0.0, now - self._last[i]) * self._rate[i],
            )
        )

    def totals(self) -> tuple[int, int]:
        """(granted, denied) across every tenant."""
        return (int(self._granted.sum()), int(self._denied.sum()))
