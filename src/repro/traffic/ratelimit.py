"""Per-tenant token/credit rate limiting in front of admission.

Admission (`AdmissionController`) polices the *contract*: a tenant is
admitted iff its provisioned rate fits Eq. 3. The rate limiter polices
the *traffic*: even an admitted tenant only releases jobs while its
token bucket has credit, so a tenant whose live traffic exceeds its
provisioned rate is trimmed back to the contract at the front door —
before the backlog monitor ever has to engage shedding. Shedding stays
the safety net for modeled-vs-real WCET error; the bucket handles the
much more common "client sends too fast" overload.

Model: one `TokenBucket` per tenant — capacity ``burst`` tokens,
refilled continuously at ``rate`` tokens/second, one token per release.
Both knobs come from the tenant's `TaskRequest` via
`RateLimiter.for_requests`: the sustained rate is the provisioned rate
(``rate_scale / period``) and the burst is ``burst_periods`` worth of
it. With ``value_weighted=True`` the tenant's shed-value relative to
the mix mean shapes the bucket — the token-bucket analogue of
`ShedByValue`'s ordering — but only ever *downward* on the sustained
rate: a below-mean-value tenant refills slower than its contract,
while an above-mean tenant keeps the contract rate (never more — the
sustained rate is capped at the provisioned rate, so rate-limited
traffic always satisfies the admission premise) and earns its
advantage as extra burst capacity instead.

Everything is deterministic: buckets are refilled lazily from the
release timestamps themselves (no wall clock), so a virtual-time
gateway run is bit-reproducible and a sharded gateway with one shard
reproduces the unsharded decisions exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.traffic.admission import TaskRequest


@dataclass
class TokenBucket:
    """Classic leaky/token bucket: ``burst`` capacity, ``rate``/s refill.

    Starts full (a tenant may burst immediately after admission).
    ``take`` is lazy-refill: credit accrued since the last call is added
    first, then one token is consumed if available. Timestamps must be
    non-decreasing per bucket (the gateway releases in time order);
    a stale timestamp refills nothing rather than going negative.
    """

    rate: float
    burst: float
    tokens: float = -1.0  # sentinel: initialize to full burst
    last: float = 0.0
    granted: int = 0
    denied: int = 0

    def __post_init__(self) -> None:
        if self.rate <= 0.0 or self.burst < 1.0:
            raise ValueError("need rate > 0 and burst >= 1 token")
        if self.tokens < 0.0:
            self.tokens = float(self.burst)

    def peek(self, now: float) -> float:
        """Credit available at ``now`` (no state change)."""
        return min(
            self.burst, self.tokens + max(0.0, now - self.last) * self.rate
        )

    def take(self, now: float, cost: float = 1.0) -> bool:
        """Spend ``cost`` tokens (default one). A cost above 1 is how
        the mixed-criticality gateway tightens a LO tenant's bucket in
        HI mode (`ModeController.release_cost`): the sustained rate
        divides by the cost without rebuilding the bucket."""
        if cost < 1.0:
            raise ValueError("token cost must be >= 1")
        self.tokens = self.peek(now)
        self.last = max(self.last, now)
        if self.tokens >= cost:
            self.tokens -= cost
            self.granted += 1
            return True
        self.denied += 1
        return False


class RateLimiter:
    """Per-tenant bucket array the `TrafficGateway` consults per release.

    Index ``i`` addresses the gateway's tenant ``i`` (the same 1:1
    alignment the gateway keeps between requests, arrivals and server
    tasks). ``allow(i, now)`` spends one token of tenant ``i``'s bucket;
    a ``False`` verdict means the release is refused up front (counted
    as ``rate_limited`` in `TenantStats`, never submitted, never shed).
    """

    def __init__(self, buckets: Sequence[TokenBucket]):
        if not buckets:
            raise ValueError("need at least one bucket")
        self.buckets = list(buckets)

    @classmethod
    def for_requests(
        cls,
        requests: Sequence[TaskRequest],
        *,
        rate_scale: float = 1.0,
        burst_periods: float = 2.0,
        value_weighted: bool = False,
    ) -> "RateLimiter":
        """Provision one bucket per tenant from its analysis contract.

        Tenant i sustains ``rate_scale * min(w_i, 1) / period_i``
        jobs/s with a burst of ``max(1, burst_periods * w_i)`` jobs,
        where ``w_i`` is 1 or, when ``value_weighted``, the tenant's
        value over the mix mean value. The rate weight is capped at 1:
        value can only *slow* a tenant below its contract (and grow its
        burst), never sustain it above the provisioned rate the
        admission analysis accounted for.
        """
        if rate_scale <= 0.0 or burst_periods <= 0.0:
            raise ValueError("rate_scale and burst_periods must be positive")
        if value_weighted:
            mean_v = sum(r.value for r in requests) / len(requests)
            # floor the weight: value 0 is a legal contract (ShedByValue
            # treats it as shed-first), so it must yield a slow bucket,
            # not a zero-rate one the TokenBucket constructor rejects
            weights = [
                max(r.value / mean_v, 0.01) if mean_v > 0 else 1.0
                for r in requests
            ]
        else:
            weights = [1.0] * len(requests)
        return cls(
            [
                TokenBucket(
                    rate=rate_scale * min(w, 1.0) / r.period,
                    burst=max(1.0, burst_periods * w),
                )
                for r, w in zip(requests, weights)
            ]
        )

    def __len__(self) -> int:
        return len(self.buckets)

    def allow(self, i: int, now: float, cost: float = 1.0) -> bool:
        return self.buckets[i].take(now, cost)

    def tokens(self, i: int, now: float) -> float:
        return self.buckets[i].peek(now)

    def totals(self) -> tuple[int, int]:
        """(granted, denied) across every tenant."""
        return (
            sum(b.granted for b in self.buckets),
            sum(b.denied for b in self.buckets),
        )
