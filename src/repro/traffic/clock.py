"""Injectable clocks for the serving runtime and the traffic gateway.

`PharosServer` and `TrafficGateway` take ``clock``/``sleep`` callables;
these classes bundle the two so one time source backs both:

- `WallClock` — real time (`time.perf_counter` / `time.sleep`); the
  production mode.
- `VirtualClock` — a manually-advanced timebase: ``sleep`` advances the
  clock instead of blocking, and the owner may charge arbitrary spans
  with ``advance`` (e.g. one modeled WCET per executed tile window).
  Runs are then deterministic and faster than real time, which is what
  the traffic tests and benchmarks drive.
"""
from __future__ import annotations

import time


class WallClock:
    """Real time."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, dt: float) -> None:
        time.sleep(dt)


class VirtualClock:
    """Deterministic manual timebase (starts at ``start``)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("cannot advance a clock backwards")
        self._t += dt

    def sleep(self, dt: float) -> None:  # sleeping == advancing
        self.advance(dt)
