"""Slack-aware live tenant migration across `ShardedGateway` shards.

A production fleet rebalances tenants without ever breaking an Eq. 3
contract mid-flight. The `MigrationController` implements the
drain-and-rehome discipline on an **elastic** sharded gateway
(`ShardedGateway.from_built(..., elastic=True)`) running under the
shared-clock co-simulation (``shared_clock=True``), which gives every
shard one consistent "now" to hand jobs over in:

1. **drain** — at the plan's start time the tenant's not-yet-due
   releases are pulled from the donor shard's live schedule
   (`TrafficGateway.extract_future`: new releases stop; jobs already
   released keep running). A ``migrate_start`` event is emitted.
2. **wait**  — the handover happens only once the donor reports zero
   in-flight jobs for the tenant (``server.pending == 0``): the
   guarantee the donor proved at admission keeps holding for every job
   it ever released, so no deadline can be violated *during* the
   handover.
3. **prove** — the tenant's Eq. 3 contribution is released from the
   donor (`TrafficGateway.release_tenant`, which also refreshes the
   donor's backlog limits — never score a shard with a departed
   tenant's load) and the target is chosen **slack-aware** from fresh
   per-shard headroom: among the shards whose
   `AdmissionController.check` admits the tenant, pick the one whose
   post-admit bottleneck utilization is smallest (ties to the lower
   shard index). The proof is the same O(stages) Eq. 3 check every
   admission goes through — nothing is committed yet.
4. **commit / abort** — on success the tenant is admitted on the
   target (`admit_tenant`) and its held releases are re-stamped
   *delayed-never-dropped* onto the target's schedule
   (``s_j = max(orig_j, t_commit, s_{j-1} + period)`` — the same
   min-gap chain `repro.traffic.regulate.regulate_trace` uses), with a
   ``migrate_commit`` event. If no shard can prove the contract the
   migration **aborts and restores**: the tenant is re-admitted on the
   donor (always succeeds — the donor was schedulable with it a moment
   ago) and its held releases are re-injected unchanged, with a
   ``migrate_abort`` event. Either way the fleet never runs a tenant
   without a committed Eq. 3 proof.

The controller is a co-simulation hook: `ShardedGateway.run` calls
``bind(sharded)`` once and ``on_tick(rel_now)`` every global iteration
(after the due-release sweep), so drains start and handovers land at
deterministic virtual times.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = [
    "MigrationPlan",
    "MigrationRecord",
    "MigrationController",
]


@dataclass(frozen=True)
class MigrationPlan:
    """One requested migration: drain ``tenant`` starting at scenario
    time ``at``; re-home onto ``target`` (a shard index) or, with
    ``target=None``, onto the slack-aware best shard."""

    tenant: str
    at: float
    target: int | None = None

    def __post_init__(self) -> None:
        if self.at < 0.0:
            raise ValueError("migration start time must be >= 0")


@dataclass
class MigrationRecord:
    """What actually happened to one `MigrationPlan`."""

    tenant: str
    requested_at: float
    donor: int = -1
    target: int | None = None
    started_at: float | None = None
    committed_at: float | None = None
    aborted_at: float | None = None
    #: nominal release times withheld during the drain
    held: int = 0
    reason: str = ""

    @property
    def committed(self) -> bool:
        return self.committed_at is not None

    @property
    def aborted(self) -> bool:
        return self.aborted_at is not None


@dataclass
class _Draining:
    plan: MigrationPlan
    record: MigrationRecord
    donor: int
    idx: int  # global tenant index
    held: list[float]  # withheld nominal release times


class MigrationController:
    """Executes `MigrationPlan`s over an elastic sharded gateway.

    Construct with the plans and (optionally) the run's
    `repro.obs.TraceRecorder`, pass as ``controller=`` to
    `ShardedGateway.run(shared_clock=True)`. After the run, `records`
    holds one `MigrationRecord` per plan (request order) and
    `final_assignment` the post-migration tenant -> shard map.
    """

    def __init__(self, plans, *, trace=None):
        self.plans = sorted(plans, key=lambda p: (p.at, p.tenant))
        self.records: list[MigrationRecord] = []
        self._tr = (
            trace
            if trace is not None and getattr(trace, "enabled", False)
            else None
        )
        self._sharded = None
        self._pending: list[MigrationPlan] = list(self.plans)
        self._draining: list[_Draining] = []

    # -- co-simulation hooks ------------------------------------------
    def bind(self, sharded) -> None:
        if not getattr(sharded, "elastic", False):
            raise ValueError(
                "live migration needs an elastic ShardedGateway "
                "(from_built(..., elastic=True)) — subset-built servers "
                "cannot serve a migrated-in tenant"
            )
        self._sharded = sharded
        self._idx = {n: i for i, n in enumerate(sharded.names)}

    def on_tick(self, rel_now: float) -> None:
        """Advance the migration state machine at global time
        ``rel_now`` (seconds since run start)."""
        while self._pending and self._pending[0].at <= rel_now:
            self._start(self._pending.pop(0), rel_now)
        still: list[_Draining] = []
        for d in self._draining:
            gw = self._sharded.gateways[d.donor]
            if gw.server.pending(d.idx) == 0:
                self._handover(d, rel_now)
            else:
                still.append(d)
        self._draining = still

    # -- the state machine --------------------------------------------
    def _start(self, plan: MigrationPlan, now: float) -> None:
        rec = MigrationRecord(tenant=plan.tenant, requested_at=plan.at)
        self.records.append(rec)
        idx = self._idx.get(plan.tenant)
        donor = (
            self._sharded.shard_of_tenant(idx) if idx is not None else None
        )
        if idx is None or donor is None:
            rec.aborted_at = now
            rec.reason = "tenant not active on any shard"
            return
        if plan.target is not None and (
            not 0 <= plan.target < len(self._sharded.gateways)
            or self._sharded.gateways[plan.target] is None
        ):
            rec.aborted_at = now
            rec.reason = f"target shard {plan.target} does not exist"
            return
        rec.donor = donor
        rec.started_at = now
        held = self._sharded.gateways[donor].extract_future(idx)
        rec.held = len(held)
        if self._tr is not None:
            self._tr.emit(
                "migrate_start", now, "gateway", plan.tenant,
                -1, donor,
                attrs={"held": len(held), "requested_target": plan.target},
            )
        self._draining.append(
            _Draining(plan=plan, record=rec, donor=donor, idx=idx, held=held)
        )

    def _candidates(self, d: _Draining) -> list[int]:
        if d.plan.target is not None:
            return [d.plan.target] if d.plan.target != d.donor else []
        return [
            k
            for k, gw in enumerate(self._sharded.gateways)
            if gw is not None and k != d.donor
        ]

    def _handover(self, d: _Draining, now: float) -> None:
        sharded, rec = self._sharded, d.record
        donor_gw = sharded.gateways[d.donor]
        req = donor_gw.release_tenant(d.idx)
        # slack-aware target choice on *fresh* post-release state: the
        # non-committing Eq. 3 check, smallest post-admit bottleneck
        # utilization wins (ties to the lower shard index)
        best, best_util = None, float("inf")
        for k in self._candidates(d):
            dec = sharded.gateways[k].admission.check(req)
            if not dec.admitted:
                continue
            util = dec.stage_utils[dec.bottleneck]
            if util < best_util:
                best, best_util = k, util
        if best is None:
            self._abort(d, req, now)
            return
        dec = sharded.gateways[best].admit_tenant(d.idx)
        if not dec.admitted:  # pragma: no cover — check() just passed
            self._abort(d, req, now)
            return
        # delayed-never-dropped re-stamp: the held releases land on the
        # target no earlier than the commit and at least a period apart
        restamped: list[float] = []
        prev = float("-inf")
        for t in d.held:
            s = max(t, now, prev + req.period)
            restamped.append(s)
            prev = s
        sharded.gateways[best].inject_future(d.idx, restamped)
        rec.target = best
        rec.committed_at = now
        rec.reason = "committed"
        if self._tr is not None:
            self._tr.emit(
                "migrate_commit", now, "gateway", rec.tenant,
                -1, best,
                attrs={"donor": d.donor, "held": len(restamped)},
            )

    def _abort(self, d: _Draining, req, now: float) -> None:
        rec = d.record
        donor_gw = self._sharded.gateways[d.donor]
        dec = donor_gw.admit_tenant(d.idx)
        if not dec.admitted:  # pragma: no cover — donor held it before
            raise RuntimeError(
                f"abort could not restore {rec.tenant!r} on its donor: "
                f"{dec.reason}"
            )
        donor_gw.inject_future(d.idx, d.held)
        rec.target = None
        rec.aborted_at = now
        rec.reason = "no shard could prove the Eq. 3 contract"
        if self._tr is not None:
            self._tr.emit(
                "migrate_abort", now, "gateway", rec.tenant,
                -1, d.donor,
                attrs={"reason": rec.reason, "held": len(d.held)},
            )

    # -- results ------------------------------------------------------
    @property
    def committed(self) -> list[MigrationRecord]:
        return [r for r in self.records if r.committed]

    @property
    def aborted(self) -> list[MigrationRecord]:
        return [r for r in self.records if r.aborted]

    def in_progress(self) -> list[str]:
        """Tenants still draining (non-empty after a run means the
        horizon cut a migration short — the tenant stays on its donor,
        releases withheld)."""
        return [d.record.tenant for d in self._draining]

    def final_assignment(self) -> dict[str, int]:
        """Tenant -> shard after all committed migrations (plan
        assignment with commits applied in commit order)."""
        if self._sharded is None:
            raise RuntimeError("controller was never bound to a run")
        out = {
            n: s
            for n, s in zip(
                self._sharded.names, self._sharded.plan.assignment
            )
        }
        for r in self.records:
            if r.committed and r.target is not None:
                out[r.tenant] = r.target
        return out
