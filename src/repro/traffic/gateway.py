"""`TrafficGateway`: the admission-controlled front door of a
`PharosServer`.

The gateway owns the traffic side of serving: each tenant (one
`ServeTask` on the server) comes with a `TaskRequest` (its analysis
contract) and an `ArrivalProcess` (its actual traffic). At ``run``:

1. every tenant is submitted to the `AdmissionController` — rejected
   tenants release nothing (their traffic is refused up front);
2. admitted tenants' arrival traces are merged into one release
   schedule; each due release first spends a token of its tenant's
   `RateLimiter` bucket (if one is armed — a dry bucket refuses the
   release as ``rate_limited``, trimming live traffic back to the
   provisioned contract), is then checked against the `BacklogMonitor`
   and, while observed backlog contradicts the analysis, routed through
   the `SheddingPolicy` (submit / drop / degrade-to-best-effort) — or,
   with ``modes=`` armed instead, through the mixed-criticality
   `repro.traffic.modes.ModeController`: overload commits a HI-mode
   switch (Eq. 3 re-proved for the HI survivor set first, a
   ``mode_switch`` trace event emitted), LO releases are shed/demoted
   and pay a tightened token-bucket cost while the mode holds, and the
   controller switches back when backlog drains;
3. the server is stepped between releases. With a `VirtualClock` the
   whole run is deterministic: when the server carries a
   `repro.conformance.CostModel` the clock jumps event-to-event (every
   executed tile window occupies its stage for the model's per-window
   WCET); otherwise each serving iteration charges the legacy
   ``virtual_dt`` quantum, and idle gaps fast-forward to the next
   arrival.

Clock semantics: the gateway and server must share one timebase —
construct the server with ``clock=clk.now, sleep=clk.sleep`` and hand
the same ``clk`` here. On a `WallClock` the release loop *polls* real
time (releases are stamped with their nominal schedule time; polling
delay shows up as `TenantStats.release_jitter`, not as response time
skew); on a `VirtualClock` the loop *drives* time and releases land
exactly on schedule.

Preemption model: the gateway never preempts anything itself — it only
decides, per release, whether a job enters at all (and in which service
class). Preemption granularity belongs to the server below: FIFO runs
every queued window to completion, EDF preempts between tile windows
only (`pipeline.serve`), which is the limited-preemption semantics the
DES (``preemption="window"``) and the blocking-aware analysis bound
model — see `repro.conformance` for the harness that holds all of them
to it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.pipeline.serve import DEGENERATE_SAFETY_TICK_S, PharosServer
from repro.traffic.admission import (
    AdmissionController,
    AdmissionDecision,
    TaskRequest,
)
from repro.traffic.arrival import ArrivalProcess, merge_arrivals
from repro.traffic.clock import WallClock
from repro.traffic.modes import ModeController
from repro.traffic.ratelimit import RateLimiter
from repro.traffic.shedding import (
    BEST_EFFORT,
    DROP,
    BacklogMonitor,
    SheddingPolicy,
)


@dataclass
class TenantStats:
    name: str
    admitted: bool
    scheduled: int = 0  # arrivals inside the horizon
    released: int = 0  # submitted with a guarantee
    degraded: int = 0  # submitted best-effort
    shed: int = 0  # dropped by the shedding policy
    rate_limited: int = 0  # refused by a dry token bucket
    release_jitter: list[float] = field(default_factory=list)

    def max_jitter(self) -> float:
        return max(self.release_jitter) if self.release_jitter else 0.0


@dataclass
class GatewayReport:
    tenants: list[TenantStats]
    decisions: list[AdmissionDecision]
    server_report: object  # ServerReport
    #: committed mixed-criticality transitions ``(t, mode, survivors)``
    #: (empty without a `ModeController` armed)
    mode_switches: list[tuple[float, str, tuple[str, ...]]] = field(
        default_factory=list
    )

    def tenant(self, name: str) -> TenantStats:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)

    def total_shed(self) -> int:
        return sum(t.shed for t in self.tenants)

    def total_rate_limited(self) -> int:
        return sum(t.rate_limited for t in self.tenants)

    def total_released(self) -> int:
        return sum(t.released + t.degraded for t in self.tenants)


@dataclass
class _RunState:
    """Release-loop state between `begin_run` and `finish_run` — what the
    shared-clock co-simulation driver (`repro.traffic.shard`) advances
    one event at a time across K gateways."""

    horizon_s: float
    stats: list[TenantStats]
    #: merged release schedule, ``(t_rel, tenant_index)`` ascending;
    #: entries at ``pos`` and beyond are still in the future
    sched: list[tuple[float, int]]
    pos: int
    t0: float
    virtual: bool
    cost_driven: bool
    virtual_dt: float


class TrafficGateway:
    def __init__(
        self,
        server: PharosServer,
        admission: AdmissionController,
        requests: Sequence[TaskRequest],
        arrivals: Sequence[ArrivalProcess],
        *,
        shedding: SheddingPolicy | None = None,
        monitor: BacklogMonitor | None = None,
        ratelimit: RateLimiter | None = None,
        modes: ModeController | None = None,
        clock=None,
        trace=None,
        shard: int = -1,
        active: Sequence[int] | None = None,
    ):
        if not (len(server.tasks) == len(requests) == len(arrivals)):
            raise ValueError(
                "server tasks / requests / arrivals must align 1:1"
            )
        if ratelimit is not None and len(ratelimit) != len(requests):
            raise ValueError("rate limiter buckets must align 1:1 with tenants")
        if modes is not None and shedding is not None:
            raise ValueError(
                "arm either per-job shedding or mixed-criticality modes, "
                "not both — one overload authority per gateway"
            )
        self.server = server
        self.admission = admission
        self.requests = list(requests)
        self.arrivals = list(arrivals)
        self.shedding = shedding
        self.monitor = monitor or BacklogMonitor()
        self.ratelimit = ratelimit
        self.modes = modes
        #: committed mode transitions, ``(t, mode, survivors)`` in
        #: commit order (mirrors `SimResult.mode_switches`)
        self.mode_switches: list[tuple[float, str, tuple[str, ...]]] = []
        self.clock = clock or WallClock()
        # schedule-trace handle (repro.obs.TraceRecorder), resolved
        # once: disabled tracing emits nothing and costs nothing.
        # ``shard`` tags every event when this gateway is one
        # `ShardedGateway` replica.
        self._tr = (
            trace
            if trace is not None and getattr(trace, "enabled", False)
            else None
        )
        self._tr_shard = shard
        self._admitted_idx: list[int] | None = None
        self._limits: list[int] = []
        # elastic membership: ``active`` names the tenant indices this
        # gateway initially serves (the rest are *present* — the server
        # knows their task geometry — but admit nothing and release
        # nothing until `admit_tenant` activates them mid-run). None
        # keeps the classic fixed-tenancy gateway: every request is a
        # member and mid-run churn is not expected.
        if active is not None:
            bad = [i for i in active if not 0 <= i < len(self.requests)]
            if bad:
                raise ValueError(f"active indices out of range: {bad}")
        self._elastic = active is not None
        self._active: set[int] = (
            set(active) if active is not None else set(range(len(requests)))
        )
        self._ever_active: set[int] = set(self._active)
        self._run: _RunState | None = None

    # -- phase 1: tenancy admission -----------------------------------
    def open(self) -> list[AdmissionDecision]:
        """Run admission for every (active) tenant (idempotent)."""
        if self._admitted_idx is not None:
            return self.admission.decisions
        self._admitted_idx = []
        for i, req in enumerate(self.requests):
            if self._elastic and i not in self._active:
                continue
            dec = self.admission.admit(req)
            if dec.admitted:
                self._admitted_idx.append(i)
            if self._tr is not None:
                self._tr.emit(
                    "admit" if dec.admitted else "reject",
                    self.clock.now(), "gateway", req.name,
                    -1, self._tr_shard,
                    attrs={"max_util": dec.max_util, "reason": dec.reason},
                )
        self._refresh_limits()
        return self.admission.decisions

    def _refresh_limits(self) -> None:
        """Recompute backlog limits from the *current* admitted set's
        response bounds. Called at `open` and after every mid-run
        `admit_tenant`/`release_tenant` — limits derived from a stale
        admitted set would make the backlog monitor (and everything
        scoring headroom through it) judge live traffic against a
        departed tenant's interference."""
        bounds = self.admission.response_bounds()
        self._limits = [
            self.monitor.limit_for(
                bounds.get(req.name, float("inf")), req.period
            )
            for req in self.requests
        ]

    # -- elastic membership (live migration / autoscaling) ------------
    def serves(self, i: int) -> bool:
        """Is tenant ``i`` currently an active member of this gateway?"""
        return i in self._active and (
            self._admitted_idx is None or i in self._admitted_idx
        )

    def admit_tenant(self, i: int) -> AdmissionDecision:
        """Mid-run activation of tenant ``i``: run the Eq. 3 admit
        against this gateway's *current* admitted set, and on success
        make the tenant an active member. Backlog limits are recomputed
        from the post-admit bounds (fresh, never stale)."""
        if self._admitted_idx is None:
            self.open()
        dec = self.admission.admit(self.requests[i])
        if self._tr is not None:
            self._tr.emit(
                "admit" if dec.admitted else "reject",
                self.clock.now(), "gateway", self.requests[i].name,
                -1, self._tr_shard,
                attrs={"max_util": dec.max_util, "reason": dec.reason},
            )
        if dec.admitted:
            if i not in self._admitted_idx:
                self._admitted_idx.append(i)
                self._admitted_idx.sort()
            self._active.add(i)
            self._ever_active.add(i)
            self._refresh_limits()
            if self._run is not None:
                self._run.stats[i].admitted = True
        return dec

    def release_tenant(self, i: int) -> TaskRequest:
        """Mid-run release of tenant ``i``: drop its Eq. 3 contribution
        (`AdmissionController.release` rebuilds the utilization cache
        exactly) and deactivate it. Backlog limits are recomputed so no
        later overload verdict or headroom snapshot scores this gateway
        with the departed tenant's load."""
        req = self.admission.release(self.requests[i].name)
        if self._admitted_idx is not None and i in self._admitted_idx:
            self._admitted_idx.remove(i)
        self._active.discard(i)
        self._refresh_limits()
        return req

    def extract_future(self, i: int) -> list[float]:
        """Remove tenant ``i``'s not-yet-due releases from the live
        schedule (drain: stop new releases) and return their nominal
        times (relative to the run's ``t0``, ascending)."""
        st = self._require_run()
        held = [t for t, j in st.sched[st.pos:] if j == i]
        st.sched[st.pos:] = [e for e in st.sched[st.pos:] if e[1] != i]
        st.stats[i].scheduled -= len(held)
        return held

    def inject_future(self, i: int, times: Iterable[float]) -> None:
        """Merge releases for tenant ``i`` (times relative to the run's
        ``t0``) into the live schedule — the re-home side of a
        migration handover."""
        st = self._require_run()
        ev = [(float(t), i) for t in times]
        st.sched[st.pos:] = sorted(st.sched[st.pos:] + ev)
        st.stats[i].scheduled += len(ev)

    def _require_run(self) -> _RunState:
        if self._run is None:
            raise RuntimeError(
                "no run in progress — begin_run() first"
            )
        return self._run

    # -- phase 2: the release loop ------------------------------------
    # The loop is decomposed into four primitives so that a shared-clock
    # driver (`ShardedGateway.run(shared_clock=True)`) can interleave K
    # gateways event-by-event on one timebase: `begin_run` freezes the
    # run state, `release_due` performs the due-release sweep,
    # `next_event` exposes the earliest future event, `finish_run`
    # assembles the report. `run` composes them and is bit-identical to
    # the pre-decomposition loop.
    def begin_run(
        self,
        horizon_s: float,
        *,
        virtual_dt: float | None = None,
        warmup: bool = True,
    ) -> None:
        """Open, merge arrival schedules and freeze the run state."""
        self.open()
        stats = [
            TenantStats(name=req.name, admitted=(i in self._admitted_idx))
            for i, req in enumerate(self.requests)
        ]
        admitted = list(self._admitted_idx)
        sched = merge_arrivals(
            [self.arrivals[i] for i in admitted], horizon_s
        )
        sched = [(t, admitted[j]) for t, j in sched]
        for _, i in sched:
            stats[i].scheduled += 1

        virtual = hasattr(self.clock, "advance")
        # with a CostModel on the server, virtual time is event-driven
        # (per-window WCETs), not quantized — virtual_dt only survives
        # as a degenerate-progress safety tick
        cost_driven = (
            virtual and getattr(self.server, "cost_model", None) is not None
        )
        if virtual and virtual_dt is None:
            # default serving quantum: a fraction of the tightest
            # analysis period, so even the fastest tenant gets many
            # scheduling opportunities per period
            p_min = min(
                (self.requests[i].period for i in admitted),
                default=1.0,
            )
            virtual_dt = p_min / 20.0
        if warmup:
            self.server.warmup()
        self._run = _RunState(
            horizon_s=horizon_s,
            stats=stats,
            sched=sched,
            pos=0,
            t0=self.clock.now(),
            virtual=virtual,
            cost_driven=cost_driven,
            virtual_dt=virtual_dt if virtual_dt is not None else 0.0,
        )

    def release_due(self) -> float:
        """Release every due arrival; returns elapsed run time.

        Due arrivals are released *before* the caller's horizon check so
        jobs landing between the last tick and the horizon still flow
        through the shedding path — every scheduled arrival ends up
        released, degraded or shed, never silently dropped.

        When a rate limiter is armed (and mixed-criticality modes are
        not — `ModeController.release_cost` can change mid-sweep, so
        those sweeps stay scalar), the whole due batch's token-bucket
        verdicts are computed in one `RateLimiter.allow_many` array
        pass up front. `allow_many` is bit-identical to looping
        `allow` in schedule order, and nothing else in the sweep feeds
        back into bucket state, so the batched sweep reproduces the
        scalar one decision-for-decision."""
        st = self._require_run()
        rel = self.clock.now() - st.t0
        end = st.pos
        n = len(st.sched)
        while end < n and (
            st.sched[end][0] <= rel or rel >= st.horizon_s
        ):
            end += 1
        if end == st.pos:
            return rel
        due = st.sched[st.pos:end]
        st.pos = end
        rl_ok = None
        if (
            self.ratelimit is not None
            and self.modes is None
            and len(due) > 1
        ):
            rl_ok = self.ratelimit.allow_many(
                [st.t0 + t for t, _ in due], [i for _, i in due]
            )
        for j, (sched_t, i) in enumerate(due):
            self._release(
                i,
                st.t0 + sched_t,
                max(0.0, rel - sched_t),
                st.stats,
                rl_allowed=None if rl_ok is None else bool(rl_ok[j]),
            )
        return rel

    def next_event(self) -> float:
        """Earliest future event on this gateway's timeline (absolute
        clock time): next modeled window boundary, next scheduled
        arrival, or the horizon — whichever comes first."""
        st = self._require_run()
        nxt = self.server.next_completion_time()
        if st.pos < len(st.sched):
            nxt = min(nxt, st.t0 + st.sched[st.pos][0])
        return min(nxt, st.t0 + st.horizon_s)

    def finish_run(self) -> GatewayReport:
        """Finalize the server report and close the run. Elastic
        gateways report only ever-active tenants (the rest were never
        members here — their stats rows belong to other shards)."""
        st = self._require_run()
        self._run = None
        tenants = (
            [st.stats[i] for i in sorted(self._ever_active)]
            if self._elastic
            else st.stats
        )
        return GatewayReport(
            tenants=tenants,
            decisions=list(self.admission.decisions),
            server_report=self.server.finalize_report(self.clock.now()),
            mode_switches=list(self.mode_switches),
        )

    def run(
        self,
        horizon_s: float,
        *,
        virtual_dt: float | None = None,
        warmup: bool = True,
    ) -> GatewayReport:
        self.begin_run(horizon_s, virtual_dt=virtual_dt, warmup=warmup)
        st = self._run
        while True:
            rel = self.release_due()
            if rel >= horizon_s:
                break
            ran = self.server.step()
            if st.cost_driven:
                # advance to the next modeled window boundary or the
                # next scheduled arrival, whichever comes first
                nxt = self.next_event()
                now2 = self.clock.now()
                if nxt > now2:
                    self.clock.advance(nxt - now2)
                elif not ran:
                    # degenerate safety: no progress and no future
                    # event — force time forward so the loop terminates
                    # even with a zero serving quantum
                    self.clock.advance(
                        max(st.virtual_dt, DEGENERATE_SAFETY_TICK_S)
                    )
            elif st.virtual:
                if not ran and st.pos < len(st.sched):
                    # idle: fast-forward to the next arrival
                    self.clock.advance(
                        max(st.virtual_dt, st.sched[st.pos][0] - rel)
                    )
                else:
                    self.clock.advance(st.virtual_dt)
            elif not ran:
                self.clock.sleep(1e-4)
        return self.finish_run()

    def _release(
        self,
        i: int,
        release_time: float,
        jitter: float,
        stats: list[TenantStats],
        rl_allowed: bool | None = None,
    ) -> None:
        # the token bucket polices the traffic contract before anything
        # else sees the release: a dry bucket refuses it outright
        # (lazily refilled from the nominal release timestamp, so
        # virtual and wall runs decide identically). In HI mode the
        # ModeController tightens LO tenants' buckets by charging
        # `release_cost` tokens per release instead of one.
        # ``rl_allowed`` carries a verdict `release_due` already
        # computed in its batched `allow_many` pass (bucket state is
        # already charged); None means decide here, scalar.
        if self.ratelimit is not None:
            allowed = (
                rl_allowed
                if rl_allowed is not None
                else self.ratelimit.allow(
                    i,
                    release_time,
                    cost=(
                        self.modes.release_cost(i)
                        if self.modes is not None
                        else 1.0
                    ),
                )
            )
            if not allowed:
                stats[i].rate_limited += 1
                if self._tr is not None:
                    self._tr.emit(
                        "rate_limited", self.clock.now(), "gateway",
                        self.requests[i].name, -1, self._tr_shard,
                        release=release_time,
                    )
                return
        # refresh overload state for every admitted tenant (pending
        # counts change between releases as jobs complete)
        if self.modes is not None:
            # the mode controller owns hysteresis (its monitor) *and*
            # the per-release verdict; transitions it commits during
            # the sweep are stamped with the gateway clock and emitted
            # as mode_switch events
            for j in self._admitted_idx:
                self.modes.observe(j, self.server.pending(j))
            for sw in self.modes.drain_events():
                now = self.clock.now()
                self.mode_switches.append((now, sw.mode, sw.survivors))
                if self._tr is not None:
                    self._tr.emit(
                        "mode_switch", now, "gateway", "",
                        -1, self._tr_shard,
                        attrs={
                            "mode": sw.mode,
                            "survivors": sw.survivors,
                            "schedulable": sw.schedulable,
                        },
                    )
            overloaded = [
                j
                for j in self._admitted_idx
                if self.modes.engaged.get(j)
            ]
            verdict = "submit"
            if overloaded:
                verdict = self.modes.classify(
                    i, overloaded, self.admission, self.requests
                )
        else:
            for j in self._admitted_idx:
                self.monitor.observe(
                    j, self.server.pending(j), self._limits[j]
                )
            overloaded = [
                j
                for j in self._admitted_idx
                if self.monitor.engaged.get(j)
            ]
            verdict = "submit"
            if overloaded and self.shedding is not None:
                verdict = self.shedding.classify(
                    i, overloaded, self.admission, self.requests
                )
        if verdict == DROP:
            stats[i].shed += 1
            if self._tr is not None:
                self._tr.emit(
                    "shed", self.clock.now(), "gateway",
                    self.requests[i].name, -1, self._tr_shard,
                    release=release_time,
                )
            return
        best_effort = verdict == BEST_EFFORT
        if self._tr is not None:
            self._tr.emit(
                "release", self.clock.now(), "gateway",
                self.requests[i].name, -1, self._tr_shard,
                release=release_time,
                attrs={"best_effort": True} if best_effort else None,
            )
        self.server.submit(i, release_time, best_effort=best_effort)
        if best_effort:
            stats[i].degraded += 1
        else:
            stats[i].released += 1
        stats[i].release_jitter.append(jitter)
