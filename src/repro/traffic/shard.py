"""Multi-gateway sharding: one pipeline design, K gateway shards.

One `TrafficGateway` fronts one `PharosServer` — one copy of the
pipeline. To scale a deployment past a single pipeline's Eq. 3 budget,
the `ShardedGateway` runs **K replicas of the same stage set**, each
with its own server, admission controller, backlog monitor and
(optional) rate limiter, and *places* every tenant onto exactly one
shard with a pluggable `PlacementPolicy`:

- `HashByTenant`   — stateless: ``crc32(name) % K``. No coordination,
  stable under tenant churn, blind to load.
- `LeastLoaded`    — greedy: each tenant (in request order) goes to the
  shard whose post-placement **max stage utilization** is smallest —
  the classic balls-into-bins balancer on the Eq. 2 vectors.
- `SlackAware`     — greedy on `stage_slacks`: the tenant goes to the
  shard that keeps the most slack on the stages the tenant *actually
  uses* (its active segments), ignoring stages it never touches — the
  placement analogue of the admission layer's headroom report.

Each shard then re-runs the O(stages) Eq. 3 admission over its own
tenant subset, so every shard's schedulability verdict is **bit-exact**
against a full `srt_schedulable` re-analysis of that subset (the same
`AdmissionController.verify` contract the unsharded gateway holds), and
with ``K == 1`` the sharded run reproduces the unsharded
`TrafficGateway` report bit-for-bit — placement degenerates to the
identity and the single shard is built through the very same
constructor path (`built_gateway`).

Stepping modes — `run(shared_clock=...)`:

- **shared-clock co-simulation** (default): all K shards advance in
  lockstep on one global event timeline — each iteration sweeps due
  releases on every shard, steps every server once, and advances every
  shard's clock to the globally earliest next event. For
  non-interacting shards this is observably identical to independent
  clocks (each shard's own events are a subset of the global event
  set, and a cost-model server stepped mid-window is a no-op — see
  ``tests/test_shard.py``'s differential fuzz leg), but it gives
  cross-shard controllers (live migration, `repro.traffic.migration`)
  a consistent "now" to act in.
- **independent clocks** (``shared_clock=False``): the original
  deployment semantics — each shard's gateway runs to the horizon on
  its own `VirtualClock`, one after the other.

Elastic mode — `from_built(..., elastic=True)` builds every shard's
server over the *full* scenario (so any tenant can be re-homed onto
any shard mid-run) but activates only the planned members per shard:
admission, release schedules and backlog monitoring are restricted to
active members exactly as in the subset-built path. This is the
substrate `MigrationController` and `repro.traffic.autoscale` operate
on.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Protocol, Sequence

import numpy as np

from repro.core.rt.batch import batched_tenant_utilizations
from repro.core.rt.schedulability import EPS, stage_slacks
from repro.core.rt.task import LayerDesc, SegmentTable, Task, TaskSet, Workload
from repro.traffic.admission import AdmissionController, TaskRequest
from repro.traffic.gateway import GatewayReport, TenantStats, TrafficGateway
from repro.traffic.ratelimit import RateLimiter
from repro.traffic.shedding import BacklogMonitor, SheddingPolicy


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
class PlacementPolicy(Protocol):
    name: str

    def place(
        self,
        requests: Sequence[TaskRequest],
        n_shards: int,
        *,
        overheads: Sequence[float],
        preemptive: bool,
    ) -> list[int]:
        """Tenant index -> shard index assignment."""
        ...


def _util_vector(req, overheads, preemptive):
    return req.utilization(tuple(overheads), preemptive)


def _tenant_util_matrix(requests, overheads, preemptive) -> np.ndarray:
    """``[T, K]`` Eq. 2 contribution rows, one per tenant — the shared
    precomputation of the vectorized placement policies. Row ``t`` is
    bit-identical to ``requests[t].utilization(overheads, preemptive)``
    (`batched_tenant_utilizations` contract)."""
    return batched_tenant_utilizations(
        [list(r.base) for r in requests],
        list(overheads),
        [r.period for r in requests],
        preemptive,
    )


@dataclass(frozen=True)
class HashByTenant:
    """Stateless ``crc32(tenant name) % K`` placement."""

    name: str = "hash_by_tenant"

    def place(self, requests, n_shards, *, overheads, preemptive):
        return [
            zlib.crc32(r.name.encode()) % n_shards for r in requests
        ]


@dataclass(frozen=True)
class LeastLoaded:
    """Greedy min-max-utilization placement on the Eq. 2 vectors.

    The greedy walk is tenant-sequential by definition (each decision
    feeds the next), but each tenant's scoring sweep over all K shards
    is one array pass: post-placement peaks for every shard at once,
    first-argmin shard wins. Bit-identical to the per-shard Python
    loop: the per-shard load vectors accumulate the same IEEE additions
    in the same order, ``max``/``argmin`` are value- and tie-exact
    (argmin returns the first minimum, matching ``min(range(K),
    key=(peak, s))``)."""

    name: str = "least_loaded"

    def place(self, requests, n_shards, *, overheads, preemptive):
        if not requests:
            return []
        du = _tenant_util_matrix(requests, overheads, preemptive)
        loads = np.zeros((n_shards, len(overheads)))
        out = []
        for t in range(len(requests)):
            after = loads + du[t][None, :]
            best = int(after.max(axis=1).argmin())
            out.append(best)
            loads[best] = after[best]
        return out


def _placement_analysis_view(reqs, overheads):
    """(SegmentTable, TaskSet) of already-placed requests for
    `stage_slacks` — the same materialization `AdmissionController.
    to_analysis` builds."""
    table = SegmentTable(
        base=[list(r.base) for r in reqs], overhead=list(overheads)
    )
    w = Workload("placement", (LayerDesc("seg", 1, 1, 1),))
    ts = TaskSet(
        tasks=tuple(
            Task(workload=w, period=r.period, deadline=r.deadline, name=r.name)
            for r in reqs
        )
    )
    return table, ts


@dataclass(frozen=True)
class SlackAware:
    """Greedy placement maximizing the post-placement `stage_slacks`
    minimum over the tenant's *active* stages (stages it never touches
    do not vote).

    Scores all K shards per tenant in one array pass instead of
    materializing a fresh (`SegmentTable`, `TaskSet`) per
    (tenant, shard) pair and re-summing Eq. 2 from scratch — the
    O(tenants × shards × placed) walk this replaces. Bit-identical to
    the scalar greedy: per-shard utilization accumulates the same
    additions in placement order (matching `stage_utilization`'s
    task-order ``sum`` over ``placed[s] + [r]``), the slack clamp is
    the scalar EPS band, and first-argmax over min-slack matches
    ``max(range(K), key=(min_slack, -s))`` — smallest shard index on
    ties."""

    name: str = "slack_aware"

    def place(self, requests, n_shards, *, overheads, preemptive):
        if not requests:
            return []
        du = _tenant_util_matrix(requests, overheads, preemptive)
        util = np.zeros((n_shards, len(overheads)))
        out = []
        for t, r in enumerate(requests):
            active = [k for k, b in enumerate(r.base) if b > 0.0]
            after = util + du[t][None, :]
            slacks = 1.0 - after
            slacks = np.where(
                (slacks < 0.0) & (slacks >= -EPS), 0.0, slacks
            )
            best = int(slacks[:, active].min(axis=1).argmax())
            out.append(best)
            util[best] = after[best]
        return out


PLACEMENTS = {
    p.name: p for p in (HashByTenant(), LeastLoaded(), SlackAware())
}


def get_placement(name: str) -> PlacementPolicy:
    try:
        return PLACEMENTS[name]
    except KeyError:
        raise KeyError(
            f"unknown placement policy {name!r}; have {sorted(PLACEMENTS)}"
        ) from None


# ---------------------------------------------------------------------------
# the plan and the merged report
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardPlan:
    """Tenant -> shard assignment plus the per-shard member lists
    (original tenant indices, ascending — order-preserving, which is
    what makes the K=1 identity exact)."""

    n_shards: int
    assignment: tuple[int, ...]

    @property
    def members(self) -> tuple[tuple[int, ...], ...]:
        return tuple(
            tuple(i for i, s in enumerate(self.assignment) if s == k)
            for k in range(self.n_shards)
        )


@dataclass(frozen=True)
class ShardHeadroom:
    """Remaining capacity of one shard replica — the operator's
    "how much more fits on this copy of the pipeline" answer.

    ``stage_slacks`` follows `repro.core.rt.stage_slacks` semantics
    (``1 - u^k`` with the tiny-negative clamp); `max_admissible_rate`
    is the `repro.core.rt.max_admissible_rate` bound evaluated against
    this shard's admitted set."""

    shard: int
    tenants: tuple[str, ...]
    stage_utilizations: tuple[float, ...]
    stage_slacks: tuple[float, ...]
    #: per admitted tenant: max rate multiplier keeping Eq. 3
    tenant_rate_multipliers: dict[str, float]
    overheads: tuple[float, ...]
    preemptive: bool

    @property
    def bottleneck(self) -> int:
        return int(
            max(
                range(len(self.stage_utilizations)),
                key=self.stage_utilizations.__getitem__,
            )
        )

    def max_admissible_rate(self, base: Sequence[float]) -> float:
        """Largest release rate (jobs/s) of a probe task with per-stage
        WCETs ``base`` this shard can still absorb under Eq. 3."""
        if len(base) != len(self.stage_slacks):
            raise ValueError("probe WCET vector length != n_stages")
        rate = float("inf")
        for k, b in enumerate(base):
            if b <= 0.0:
                continue
            e = b + (self.overheads[k] if self.preemptive else 0.0)
            rate = min(rate, max(0.0, self.stage_slacks[k]) / e)
        return rate


def _shard_headroom(shard: int, gw: TrafficGateway) -> ShardHeadroom:
    """Headroom snapshot of one shard from its admission controller."""
    from repro.core.rt.schedulability import (
        stage_slacks as rt_stage_slacks,
    )

    ctl = gw.admission
    view = ctl.to_analysis()
    if view is None:
        slacks = tuple(1.0 for _ in range(ctl.n_stages))
    else:
        table, ts = view
        slacks = tuple(rt_stage_slacks(table, ts, ctl.preemptive))
    hr = ctl.headroom_report()
    return ShardHeadroom(
        shard=shard,
        tenants=tuple(ctl.names()),
        stage_utilizations=ctl.utilizations(),
        stage_slacks=slacks,
        tenant_rate_multipliers=dict(hr.tenant_rate_multipliers),
        overheads=ctl.overheads,
        preemptive=ctl.preemptive,
    )


@dataclass(frozen=True)
class ShardedReport:
    """Per-shard `GatewayReport`s plus the plan that produced them.
    Empty shards carry ``None``.

    Aggregate totals are computed once on first access and memoized
    (the report is a finished-run snapshot — per-tenant stats no longer
    change), so a benchmark polling ``total_released`` per batch reads
    three cached ints instead of re-walking K×T tenant stat rows."""

    plan: ShardPlan
    reports: tuple[GatewayReport | None, ...]
    #: per-shard remaining capacity (`ShardHeadroom`; None for empty
    #: shards) — the ROADMAP's shard-aware headroom report
    headrooms: tuple[ShardHeadroom | None, ...] = ()

    def tenant(self, name: str) -> TenantStats:
        for rep in self.reports:
            if rep is None:
                continue
            for t in rep.tenants:
                if t.name == name:
                    return t
        raise KeyError(name)

    def shard_of(self, name: str) -> int:
        for k, rep in enumerate(self.reports):
            if rep is not None and any(t.name == name for t in rep.tenants):
                return k
        raise KeyError(name)

    @property
    def tenants(self) -> tuple[TenantStats, ...]:
        return tuple(
            t
            for rep in self.reports
            if rep is not None
            for t in rep.tenants
        )

    def admitted_count(self) -> int:
        return sum(1 for t in self.tenants if t.admitted)

    def _totals(self) -> tuple[int, int, int]:
        """(shed, rate_limited, released) in one walk, memoized.
        Frozen dataclasses still own their ``__dict__``, so the cache
        rides along without thawing the report."""
        cached = self.__dict__.get("_totals_cache")
        if cached is None:
            shed = limited = released = 0
            for r in self.reports:
                if r is None:
                    continue
                shed += r.total_shed()
                limited += r.total_rate_limited()
                released += r.total_released()
            cached = (shed, limited, released)
            object.__setattr__(self, "_totals_cache", cached)
        return cached

    def total_shed(self) -> int:
        return self._totals()[0]

    def total_rate_limited(self) -> int:
        return self._totals()[1]

    def total_released(self) -> int:
        return self._totals()[2]


def plan_shards(
    requests: Sequence[TaskRequest],
    shards: int,
    placement: "PlacementPolicy | str | None" = None,
    *,
    n_stages: int,
    preemptive: bool,
) -> tuple[PlacementPolicy, ShardPlan]:
    """Resolve a placement policy (by name or instance; default
    `HashByTenant`) and compute the tenant -> shard plan. This is the
    single plan-construction path shared by `ShardedGateway.from_built`
    and the conformance harness's ``run_sharded_case`` — what the
    harness checks is, by construction, the plan the gateway runs."""
    if shards < 1:
        raise ValueError("need at least one shard")
    if isinstance(placement, str):
        placement = get_placement(placement)
    placement = placement or HashByTenant()
    assignment = placement.place(
        requests,
        shards,
        overheads=[0.0] * n_stages,
        preemptive=preemptive,
    )
    return placement, ShardPlan(
        n_shards=shards, assignment=tuple(assignment)
    )


# ---------------------------------------------------------------------------
# building one gateway (the shared constructor path)
# ---------------------------------------------------------------------------
def built_gateway(
    built,
    *,
    policy: str | None = None,
    seed: int = 0,
    max_dim: int | None = 512,
    shedding: SheddingPolicy | None = None,
    monitor: BacklogMonitor | None = None,
    ratelimit: RateLimiter | None = None,
    make_modes=None,
    trace=None,
    shard: int = -1,
    active: Sequence[int] | None = None,
) -> TrafficGateway:
    """One deterministic cost-model `TrafficGateway` over a
    `BuiltScenario` (or a `BuiltScenario.subset`), on its own
    `VirtualClock`: the server executes surrogate GEMM windows while
    virtual time is charged per window from the conformance
    `CostModel`'s exec-model WCETs. This is the single constructor path
    both the unsharded gateway and every `ShardedGateway` shard go
    through — K=1 equivalence is structural, not coincidental.

    ``trace`` (a `repro.obs.TraceRecorder`) is handed to both the
    gateway and its server; ``shard`` tags every emitted event with the
    replica index (-1: unsharded). ``make_modes(admission, requests)``
    builds a fresh `repro.traffic.modes.ModeController` over the
    gateway's own admission controller after it is constructed — the
    mixed-criticality analogue of ``monitor``/``ratelimit``.
    """
    from repro.pipeline.serve import PharosServer
    from repro.traffic.clock import VirtualClock

    policy = policy or built.scenario.policy
    serve_tasks, _reqs, _arr = built.serve_bundle(
        period_scale=1.0, seed=seed, max_dim=max_dim
    )
    cost_model = built.conformance_cost_model(serve_tasks)
    clk = VirtualClock()
    server = PharosServer(
        serve_tasks,
        built.design.n_stages,
        policy=policy,
        cost_model=cost_model,
        clock=clk.now,
        sleep=clk.sleep,
        trace=trace,
        trace_shard=shard,
    )
    admission = AdmissionController(
        [0.0] * built.design.n_stages,
        preemptive=(policy == "edf"),
    )
    requests = list(built.requests)
    modes = make_modes(admission, requests) if make_modes else None
    return TrafficGateway(
        server,
        admission,
        requests,
        list(built.arrivals),
        shedding=shedding,
        monitor=monitor,
        ratelimit=ratelimit,
        modes=modes,
        clock=clk,
        trace=trace,
        shard=shard,
        active=active,
    )


# ---------------------------------------------------------------------------
# the sharded gateway
# ---------------------------------------------------------------------------
class ShardedGateway:
    """K independent `TrafficGateway` shards over one pipeline design.

    ``gateways[k]`` serves the tenants ``plan.members[k]`` (original
    indices, order preserved); empty shards hold ``None``. Use
    `from_built` for the batteries-included scenario path, or construct
    directly from pre-built per-shard gateways for custom wiring.
    """

    def __init__(
        self,
        plan: ShardPlan,
        gateways: Sequence[TrafficGateway | None],
        names: Sequence[str],
        *,
        elastic: bool = False,
    ):
        if len(gateways) != plan.n_shards:
            raise ValueError("one gateway (or None) per shard required")
        self.plan = plan
        self.gateways = list(gateways)
        self.names = list(names)
        #: built over the full scenario per shard (tenants re-homeable)?
        self.elastic = elastic

    @classmethod
    def from_built(
        cls,
        built,
        *,
        shards: int,
        placement: PlacementPolicy | str | None = None,
        policy: str | None = None,
        seed: int = 0,
        max_dim: int | None = 512,
        shedding: SheddingPolicy | None = None,
        make_monitor=None,
        make_ratelimit=None,
        make_modes=None,
        trace=None,
        elastic: bool = False,
        plan: ShardPlan | None = None,
    ) -> "ShardedGateway":
        """Place a `BuiltScenario`'s tenants across ``shards`` replicas.

        ``make_monitor()`` / ``make_ratelimit(sub_requests)`` /
        ``make_modes(admission, sub_requests)`` build one fresh
        `BacklogMonitor` / `RateLimiter` / `ModeController` per shard
        (monitors, buckets and mode state are stateful — shards must
        not share them; each shard runs its own mode machine over its
        own tenant subset).

        ``trace`` (a `repro.obs.TraceRecorder`) is shared by every
        shard's gateway and server — events carry the shard index —
        and receives one ``place`` event per tenant recording the
        placement decision.

        ``elastic=True`` builds each shard's server over the *full*
        scenario with only the planned members active, so tenants can
        later be re-homed across shards by a `MigrationController`
        (subset-built servers have fixed task lists and cannot serve a
        migrated-in tenant). Empty shards still get a (fully inactive)
        gateway in elastic mode — they are valid migration targets.

        ``plan`` overrides placement entirely with an explicit
        `ShardPlan` (assignment indices into ``built.requests``) — the
        autoscaler's path, where the plan is carried over from the
        previous epoch rather than recomputed.
        """
        policy = policy or built.scenario.policy
        if plan is not None:
            if plan.n_shards != shards or len(plan.assignment) != len(
                built.requests
            ):
                raise ValueError("explicit plan does not match scenario")
            placement_name = "explicit"
        else:
            _placement, plan = plan_shards(
                built.requests,
                shards,
                placement,
                n_stages=built.design.n_stages,
                preemptive=(policy == "edf"),
            )
            placement_name = _placement.name
        if trace is not None and getattr(trace, "enabled", False):
            for r, k in zip(built.requests, plan.assignment):
                trace.emit(
                    "place", 0.0, "gateway", r.name, -1, k,
                    attrs={"placement": placement_name},
                )
        gateways: list[TrafficGateway | None] = []
        for k, members in enumerate(plan.members):
            if not members and not elastic:
                gateways.append(None)
                continue
            sub = built if elastic else built.subset(members)
            gateways.append(
                built_gateway(
                    sub,
                    policy=policy,
                    seed=seed,
                    max_dim=max_dim,
                    shedding=shedding,
                    monitor=make_monitor() if make_monitor else None,
                    ratelimit=(
                        make_ratelimit(sub.requests)
                        if make_ratelimit
                        else None
                    ),
                    make_modes=make_modes,
                    trace=trace,
                    shard=k,
                    active=members if elastic else None,
                )
            )
        return cls(
            plan,
            gateways,
            [r.name for r in built.requests],
            elastic=elastic,
        )

    def open(self):
        """Run tenancy admission on every shard; returns the flattened
        decision list (shard-major, request order within each shard)."""
        decisions = []
        for gw in self.gateways:
            if gw is not None:
                decisions.extend(gw.open())
        return decisions

    def verify(self) -> bool:
        """Every shard's cached Eq. 3 verdict equals a full
        `srt_schedulable` re-analysis of its admitted subset."""
        return all(
            gw.admission.verify()
            for gw in self.gateways
            if gw is not None
        )

    def headroom(self) -> tuple[ShardHeadroom | None, ...]:
        """Per-shard remaining-capacity snapshot, computed fresh from
        each shard's *live* admission controller (run `open` first —
        before admission every shard trivially reports full slack).
        Always recompute through this method after a mid-run
        release/admit; a snapshot taken earlier still scores departed
        tenants' load (the headroom-staleness pitfall)."""
        return tuple(
            _shard_headroom(k, gw) if gw is not None else None
            for k, gw in enumerate(self.gateways)
        )

    def shard_of_tenant(self, i: int) -> int | None:
        """Shard currently serving global tenant index ``i`` (live
        membership, not the static plan), or None if nowhere active."""
        for k, gw in enumerate(self.gateways):
            if gw is not None and gw.serves(i):
                return k
        return None

    def run(
        self,
        horizon_s: float,
        *,
        virtual_dt: float | None = None,
        warmup: bool = True,
        shared_clock: bool = True,
        controller=None,
    ) -> ShardedReport:
        """Drive every shard to ``horizon_s``.

        ``shared_clock=True`` (default) co-simulates all K shards on
        one global event timeline; ``controller`` (duck-typed:
        ``bind(sharded)`` + ``on_tick(rel_now)``, e.g. a
        `repro.traffic.migration.MigrationController`) is invoked once
        per global iteration after the due-release sweep.
        ``shared_clock=False`` restores the original independent-clock
        semantics (no controller possible — there is no global now)."""
        if not shared_clock:
            if controller is not None:
                raise ValueError(
                    "cross-shard controllers require shared_clock=True"
                )
            reports = tuple(
                gw.run(horizon_s, virtual_dt=virtual_dt, warmup=warmup)
                if gw is not None
                else None
                for gw in self.gateways
            )
            return ShardedReport(
                plan=self.plan, reports=reports, headrooms=self.headroom()
            )

        from repro.pipeline.serve import DEGENERATE_SAFETY_TICK_S

        live = [gw for gw in self.gateways if gw is not None]
        if not live:
            return ShardedReport(
                plan=self.plan,
                reports=tuple(None for _ in self.gateways),
                headrooms=self.headroom(),
            )
        for gw in live:
            if not hasattr(gw.clock, "advance"):
                raise ValueError(
                    "shared-clock co-simulation needs virtual clocks"
                )
        for gw in live:
            gw.begin_run(horizon_s, virtual_dt=virtual_dt, warmup=warmup)
        if controller is not None:
            controller.bind(self)
        while True:
            rels = [gw.release_due() for gw in live]
            if controller is not None:
                controller.on_tick(max(rels))
                # a handover may have injected new releases due now
                rels = [gw.release_due() for gw in live]
            if all(r >= horizon_s for r in rels):
                break
            ran_any = False
            for gw in live:
                ran_any = gw.server.step() or ran_any
            # the globally earliest next event; every shard's clock
            # advances to it in lockstep. A shard woken at another
            # shard's event time is a no-op: no due arrivals, and a
            # cost-model server stepped mid-window does nothing.
            nxt = min(gw.next_event() for gw in live)
            now = live[0].clock.now()
            if nxt > now:
                for gw in live:
                    gw.clock.advance(nxt - now)
            elif not ran_any:
                tick = max(
                    max(gw._run.virtual_dt for gw in live),
                    DEGENERATE_SAFETY_TICK_S,
                )
                for gw in live:
                    gw.clock.advance(tick)
        reports = tuple(
            gw.finish_run() if gw is not None else None
            for gw in self.gateways
        )
        return ShardedReport(
            plan=self.plan, reports=reports, headrooms=self.headroom()
        )
