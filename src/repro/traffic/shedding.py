"""Overload shedding: what to do when reality contradicts the analysis.

Admission guarantees Eq. 3 for the *modeled* traffic. Live systems still
overload — stochastic arrivals exceed their provisioned rate, WCETs were
optimistic, a stage degrades. The `BacklogMonitor` watches the observed
per-tenant backlog against what the analysis promises (bounded response
=> bounded backlog) and engages a `SheddingPolicy` while the two
disagree; the policy decides, per released job, whether it is submitted,
dropped, or demoted to best-effort:

- `RejectNewest`   — admission-order LIFO: tenants admitted last lose
  their jobs first (the earliest tenants keep their contract).
- `ShedByValue`    — drop jobs of the lowest value-density tenant first
  (value per unit of bottleneck utilization), safety tenants last.
- `DegradeToBestEffort` — same ordering as `ShedByValue` but demotes to
  the no-guarantee class instead of dropping: the work still runs when
  capacity allows, it just stops competing with guaranteed deadlines.

Policies only act on tenants with *observed* backlog; a tenant inside
its analysis envelope is never shed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Protocol, Sequence

from repro.traffic.admission import AdmissionController, TaskRequest

#: shedding verdicts for one released job
SUBMIT = "submit"
DROP = "drop"
BEST_EFFORT = "best_effort"


@dataclass
class BacklogMonitor:
    """Detects analysis contradiction from observed backlog.

    If the admitted set is schedulable, each tenant's pending-job count
    is bounded by ``ceil(R_bound / period) + 1`` (jobs released inside
    one response-bound window). We engage shedding when the observed
    pending count exceeds ``margin`` times that bound (or ``fallback``
    jobs when the analytic bound is infinite/unavailable), and
    disengage at half the trigger level — hysteresis, so the gateway
    does not flap at the boundary.
    """

    margin: float = 2.0
    fallback: int = 8
    engaged: dict[int, bool] = field(default_factory=dict)

    def limit_for(self, bound: float, period: float) -> int:
        if not math.isfinite(bound) or bound <= 0:
            return self.fallback
        return max(2, math.ceil(self.margin * (bound / period + 1.0)))

    def observe(self, task_idx: int, pending: int, limit: int) -> bool:
        """Update hysteresis state; True while shedding is engaged."""
        on = self.engaged.get(task_idx, False)
        if not on and pending > limit:
            on = True
        elif on and pending <= max(1, limit // 2):
            on = False
        self.engaged[task_idx] = on
        return on

    def any_engaged(self) -> bool:
        return any(self.engaged.values())


class SheddingPolicy(Protocol):
    name: str
    #: whether the policy actually *removes* work (drops releases).
    #: Dropping policies can restore the analysis's boundedness promise
    #: under sustained overdrive; demote-only policies cannot — the
    #: overload conformance case (`run_shedding_case`) keys its verdict
    #: claim on this.
    drops: bool

    def classify(
        self,
        task_idx: int,
        overloaded: Sequence[int],
        admission: AdmissionController,
        requests: Sequence[TaskRequest],
    ) -> str:
        """Verdict for one released job of ``task_idx`` given the set of
        currently-overloaded tenant indices: SUBMIT, DROP or
        BEST_EFFORT."""
        ...


def _value_density(
    req: TaskRequest, admission: AdmissionController
) -> float:
    """Value per unit of bottleneck-stage utilization demand."""
    du = req.utilization(admission.overheads, admission.preemptive)
    demand = max(du) if any(du) else 1e-12
    return req.value / max(demand, 1e-12)


@dataclass(frozen=True)
class RejectNewest:
    """Shed jobs of the most recently admitted overloaded tenants."""

    name: str = "reject_newest"
    #: a dropping policy actually removes work, so it can restore the
    #: analysis's boundedness promise under sustained overdrive;
    #: demote-only policies cannot (the work still runs) — overload
    #: conformance (`run_shedding_case`) keys its verdict claim on this
    drops: bool = True

    def classify(self, task_idx, overloaded, admission, requests):
        if task_idx not in overloaded:
            return SUBMIT
        # Tenants earlier in admission order keep their releases; the
        # newest overloaded tenant(s) shed. Order = position of the
        # request name in the controller's admission log.
        order = admission.names()

        def rank(i):
            try:
                return order.index(requests[i].name)
            except ValueError:
                return len(order)  # unknown/best-effort: shed first

        newest = max(overloaded, key=rank)
        return DROP if task_idx == newest else SUBMIT


@dataclass(frozen=True)
class ShedByValue:
    """Shed the lowest value-density overloaded tenant's jobs."""

    name: str = "shed_by_value"
    drops: bool = True

    def classify(self, task_idx, overloaded, admission, requests):
        if task_idx not in overloaded:
            return SUBMIT
        cheapest = min(
            overloaded,
            key=lambda i: _value_density(requests[i], admission),
        )
        return DROP if task_idx == cheapest else SUBMIT


@dataclass(frozen=True)
class DegradeToBestEffort:
    """Demote instead of drop: overloaded low-value tenants keep running
    without a deadline guarantee."""

    name: str = "degrade_best_effort"
    drops: bool = False

    def classify(self, task_idx, overloaded, admission, requests):
        if task_idx not in overloaded:
            return SUBMIT
        cheapest = min(
            overloaded,
            key=lambda i: _value_density(requests[i], admission),
        )
        return BEST_EFFORT if task_idx == cheapest else SUBMIT


def des_release_shedding(
    policy: SheddingPolicy,
    admission: AdmissionController,
    requests: Sequence[TaskRequest],
    *,
    monitor: BacklogMonitor | None = None,
    bound_policy: str | None = None,
):
    """Mirror the gateway's backlog-triggered shedding *inside* the DES.

    Builds a `repro.scheduler.des.ReleaseShedding` whose per-task engage
    limits come from the admitted set's analysis response bounds exactly
    like `TrafficGateway.open` derives the gateway's
    (``monitor.limit_for(bound, period)``), and whose classify hook
    calls this module's ``policy`` with the same arguments the gateway
    passes. `scheduler.des.simulate(cfg.shedding=...)` then sheds at
    release time against the *simulated* backlog — same hysteresis,
    same policy, same limits — so DES, runtime and analysis can be
    conformance-checked under overload.
    """
    from repro.scheduler.des import ReleaseShedding

    monitor = monitor or BacklogMonitor()
    bounds = admission.response_bounds(bound_policy)
    limits = tuple(
        monitor.limit_for(bounds.get(r.name, float("inf")), r.period)
        for r in requests
    )

    def classify(task_idx: int, overloaded) -> str:
        return policy.classify(task_idx, list(overloaded), admission, requests)

    return ReleaseShedding(limits=limits, classify=classify)


POLICIES = {
    p.name: p
    for p in (RejectNewest(), ShedByValue(), DegradeToBestEffort())
}


def get_policy(name: str) -> SheddingPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown shedding policy {name!r}; have {sorted(POLICIES)}"
        ) from None
