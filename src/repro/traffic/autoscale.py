"""Headroom-driven autoscaling: grow/shrink the shard fleet as traffic
ramps, never running a tenant without an Eq. 3 proof.

The `Autoscaler` drives a **ramp** — a sequence of `RampPhase`s, each
naming the tenants active for a duration — as a chain of epochs. Every
epoch runs a static `ShardedGateway` (shared-clock co-simulation) over
the phase's active tenants; *between* epochs the autoscaler re-plans
the fleet from headroom:

- **carry over**  — tenants surviving from the previous phase keep
  their shard (placement stability: no gratuitous re-homing).
- **grow**        — each newly active tenant is placed slack-aware
  (smallest post-admit bottleneck utilization among the shards whose
  Eq. 3 `AdmissionController.check` admits it). When *no* shard can
  prove the contract, the fleet grows by one replica (up to
  ``max_shards``) and the tenant lands there.
- **shrink**      — after placement the emptiest shard (fewest
  tenants, ties to the lightest bottleneck utilization from the fresh
  headroom of its proof controller) is **drained before removal**:
  every one of its tenants must be provably re-admittable on the
  remaining shards — only then are they re-homed (one
  ``migrate_start``/``migrate_commit`` pair each, stamped at the phase
  boundary) and the replica retired. If any tenant fits nowhere else
  the shard stays. Shrinking repeats until blocked or ``min_shards``.

Scoring always uses freshly recomputed utilizations (the proof
controllers mirror each shard's would-be admitted set), never a stale
snapshot — the headroom-staleness discipline
`TrafficGateway.release_tenant` enforces at the gateway layer. The
previous epoch's `ShardedReport.headrooms` is surfaced on each
`EpochResult` so callers can correlate decisions with observed load.

The whole ramp is deterministic: phase boundaries are virtual times,
placement is greedy with fixed tie-breaks, and each epoch's gateway is
built through the same `built_gateway` path as every other run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.rt.batch import batched_tenant_utilizations
from repro.core.rt.schedulability import EPS
from repro.traffic.admission import AdmissionController
from repro.traffic.shard import ShardedGateway, ShardedReport, ShardPlan

__all__ = [
    "RampPhase",
    "EpochResult",
    "AutoscaleReport",
    "Autoscaler",
]


@dataclass(frozen=True)
class RampPhase:
    """One traffic plateau: the *global* tenant indices (into the
    scenario's request list) active for ``duration`` seconds."""

    duration: float
    active: tuple[int, ...]

    def __post_init__(self) -> None:
        if self.duration <= 0.0:
            raise ValueError("phase duration must be > 0")
        if len(set(self.active)) != len(self.active):
            raise ValueError("duplicate tenant indices in phase")


@dataclass
class EpochResult:
    """One phase as actually run."""

    phase: int
    t_start: float
    n_shards: int
    #: global tenant index -> shard for this epoch
    assignment: dict[int, int]
    report: ShardedReport
    #: tenants re-homed off a drained shard at this epoch's boundary
    rehomed: tuple[str, ...] = ()
    grew: int = 0
    shrank: int = 0

    def admitted_count(self) -> int:
        return self.report.admitted_count()

    def tenant_count(self) -> int:
        return len(self.assignment)


@dataclass
class AutoscaleReport:
    epochs: list[EpochResult] = field(default_factory=list)

    def admit_rate(self) -> float:
        """Admitted tenant-phases / active tenant-phases over the whole
        ramp — the gate metric `benchmarks/shard_bench.py` compares
        against every static-K fleet."""
        total = sum(e.tenant_count() for e in self.epochs)
        adm = sum(e.admitted_count() for e in self.epochs)
        return adm / total if total else 1.0

    def max_shards_used(self) -> int:
        return max((e.n_shards for e in self.epochs), default=0)

    def shard_counts(self) -> tuple[int, ...]:
        return tuple(e.n_shards for e in self.epochs)

    def final_assignment(self) -> dict[int, int]:
        return dict(self.epochs[-1].assignment) if self.epochs else {}


class Autoscaler:
    """Elastic fleet sizing over one `BuiltScenario`.

    ``make_gateway`` hooks the per-epoch `ShardedGateway` construction
    for tests; the default goes through
    `ShardedGateway.from_built(built.subset(...), plan=...)`.
    """

    def __init__(
        self,
        built,
        *,
        min_shards: int = 1,
        max_shards: int = 8,
        policy: str | None = None,
        seed: int = 0,
        max_dim: int | None = 512,
        trace=None,
    ):
        if not 1 <= min_shards <= max_shards:
            raise ValueError("need 1 <= min_shards <= max_shards")
        self.built = built
        self.min_shards = min_shards
        self.max_shards = max_shards
        self.policy = policy or built.scenario.policy
        self.seed = seed
        self.max_dim = max_dim
        self._tr = (
            trace
            if trace is not None and getattr(trace, "enabled", False)
            else None
        )
        self._preemptive = self.policy == "edf"
        self._n_stages = built.design.n_stages

    # -- proof controllers: fresh Eq. 3 state per planning round ------
    def _controllers(
        self, assign: dict[int, int], n_shards: int
    ) -> list[AdmissionController]:
        ctls = [
            AdmissionController(
                [0.0] * self._n_stages, preemptive=self._preemptive
            )
            for _ in range(n_shards)
        ]
        for i in sorted(assign):
            ctls[assign[i]].admit(self.built.requests[i])
        return ctls

    def _score_shards(
        self, ctls: Sequence[AdmissionController], req
    ) -> tuple[np.ndarray, np.ndarray]:
        """One array pass over all K proof controllers: per-shard
        post-admit bottleneck utilization (``peak``) and Eq. 3 verdict
        (``ok``), value-identical to calling ``ctls[k].check(req)``
        per shard — the same ``du + util`` IEEE additions against each
        controller's cached Eq. 2 state, the same ``util_cap + EPS``
        band. This is what keeps the planning round O(K·stages) in
        numpy instead of O(K) Python `check` calls per tenant."""
        if len(req.base) != self._n_stages:
            raise ValueError(
                f"request spans {len(req.base)} stages, "
                f"fleet has {self._n_stages}"
            )
        du = batched_tenant_utilizations(
            [list(req.base)],
            [0.0] * self._n_stages,
            [req.period],
            self._preemptive,
        )[0]
        cur = np.array(
            [ctl.utilizations() for ctl in ctls], dtype=np.float64
        )
        caps = np.array([ctl.util_cap for ctl in ctls], dtype=np.float64)
        after = du[None, :] + cur
        peak = after.max(axis=1)
        ok = peak <= caps + EPS
        return peak, ok

    def _best_shard(
        self, ctls: Sequence[AdmissionController], req, exclude=()
    ) -> int | None:
        """Slack-aware: admitting shard with the smallest post-admit
        bottleneck utilization; None when no shard proves Eq. 3.
        First-argmin tie-break — the first shard reaching the smallest
        peak wins, exactly like the scalar strict-``<`` scan."""
        peak, ok = self._score_shards(ctls, req)
        score = np.where(ok, peak, np.inf)
        for k in exclude:
            score[k] = np.inf
        if not np.isfinite(score).any():
            return None
        return int(score.argmin())

    # -- one planning round -------------------------------------------
    def _plan_epoch(
        self,
        active: Sequence[int],
        assign: dict[int, int],
        n_shards: int,
        t_now: float,
    ) -> tuple[dict[int, int], int, tuple[str, ...], int, int]:
        """Carry over survivors, place arrivals, grow, then drain-and-
        shrink. Returns (assignment, K, rehomed names, grew, shrank)."""
        active_set = set(active)
        assign = {
            i: s for i, s in sorted(assign.items()) if i in active_set
        }
        grew = shrank = 0

        # place newly active tenants (ascending index: deterministic)
        for i in sorted(active_set - set(assign)):
            req = self.built.requests[i]
            ctls = self._controllers(assign, n_shards)
            best = self._best_shard(ctls, req)
            if best is None and n_shards < self.max_shards:
                n_shards += 1
                grew += 1
                best = n_shards - 1
            if best is None:
                # fleet at max and no shard proves the contract: the
                # tenant still gets the least-bad shard and the epoch's
                # own admission rejects it there (counted, not hidden)
                ctls = self._controllers(assign, n_shards)
                peak, _ = self._score_shards(ctls, req)
                best = int(peak.argmin())
            assign[i] = best

        # drain-and-remove the emptiest shard while everything it holds
        # provably fits elsewhere
        rehomed: list[str] = []
        while n_shards > self.min_shards:
            ctls = self._controllers(assign, n_shards)
            occupancy = [
                (
                    sum(1 for i in sorted(assign) if assign[i] == k),
                    max(ctls[k].utilizations(), default=0.0),
                    -k,
                )
                for k in range(n_shards)
            ]
            victim = min(range(n_shards), key=lambda k: occupancy[k])
            movers = [i for i in sorted(assign) if assign[i] == victim]
            moves: dict[int, int] = {}
            ok = True
            for i in movers:
                req = self.built.requests[i]
                dst = self._best_shard(ctls, req, exclude=(victim,))
                if dst is None:
                    ok = False
                    break
                ctls[dst].admit(req)
                moves[i] = dst
            if not ok:
                break
            for i, dst in sorted(moves.items()):
                assign[i] = dst
                name = self.built.requests[i].name
                rehomed.append(name)
                if self._tr is not None:
                    self._tr.emit(
                        "migrate_start", t_now, "gateway", name,
                        -1, victim,
                        attrs={"held": 0, "requested_target": dst},
                    )
                    self._tr.emit(
                        "migrate_commit", t_now, "gateway", name,
                        -1, dst,
                        attrs={"donor": victim, "held": 0},
                    )
            # retire the replica: higher shards slide down one slot
            assign = {
                i: (s - 1 if s > victim else s)
                for i, s in sorted(assign.items())
            }
            n_shards -= 1
            shrank += 1
        return assign, n_shards, tuple(rehomed), grew, shrank

    # -- the ramp -----------------------------------------------------
    def run_ramp(
        self,
        phases: Sequence[RampPhase],
        *,
        virtual_dt: float | None = None,
        warmup: bool = True,
    ) -> AutoscaleReport:
        out = AutoscaleReport()
        assign: dict[int, int] = {}
        n_shards = self.min_shards
        t_now = 0.0
        for p, phase in enumerate(phases):
            active = sorted(phase.active)
            for i in active:
                if not 0 <= i < len(self.built.requests):
                    raise ValueError(f"tenant index {i} out of range")
            assign, n_shards, rehomed, grew, shrank = self._plan_epoch(
                active, assign, n_shards, t_now
            )
            sub = self.built.subset(
                tuple(active), name=f"{self.built.scenario.name}.p{p}"
            )
            plan = ShardPlan(
                n_shards=n_shards,
                assignment=tuple(assign[i] for i in active),
            )
            gw = ShardedGateway.from_built(
                sub,
                shards=n_shards,
                plan=plan,
                policy=self.policy,
                seed=self.seed,
                max_dim=self.max_dim,
                trace=self._tr,
            )
            report = gw.run(
                phase.duration,
                virtual_dt=virtual_dt,
                warmup=warmup,
                shared_clock=True,
            )
            out.epochs.append(
                EpochResult(
                    phase=p,
                    t_start=t_now,
                    n_shards=n_shards,
                    assignment=dict(assign),
                    report=report,
                    rehomed=rehomed,
                    grew=grew,
                    shrank=shrank,
                )
            )
            t_now += phase.duration
        return out
