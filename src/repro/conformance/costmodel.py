"""Per-layer virtual cost model for the serving runtime.

The virtual-time serving mode used to quantize service at one tile
window per fixed ``virtual_dt`` — every layer of every task cost the
same virtual second, no matter what the analysis said its WCET was
(`BuiltScenario.virtual_period_scale` existed purely to paper over the
mismatch at the *bottleneck* stage; every other stage was off). The
`CostModel` replaces that: it prices each (task, layer) individually and
the `PharosServer` charges exactly that much virtual time per executed
tile window, so the virtual runtime is driven by the *same* WCETs the
Eq. 2/3 analysis and the DES consume.

Two sources:

- `CostModel.from_exec_model` — the analytic path: per-layer latency
  from `core.perfmodel.layer_latency` on the design's accelerators.
  Per-stage sums then equal `SegmentTable.base` bit-for-bit (both are
  the same left-to-right `segment_latency` accumulation), which is what
  makes the three layers comparable in the conformance harness.
- `CostModel.calibrate` — the measured path (ROADMAP: "wall-clock
  calibration of serve-path WCETs"): `PharosServer.warmup`-style probes
  time the actual window executor per (task, layer) and the model
  carries wall seconds instead of modeled ones. `segment_table()` then
  yields a *measured* WCET table to feed the admission controller on
  the real host.

Preemption in the serving runtime happens only at window boundaries: a
preemptor blocks for at most one in-flight window and resumption costs
nothing extra (the fp32 accumulator stays in the job's buffer and the
virtual executor re-streams nothing). `stage_window_quantum` is that
blocking term per stage — the runtime's realization of the paper's
Eq. 5 ``xi`` — and `segment_table`/`des_overheads` hand it to the
analysis (Eq. 4 inflation) and the DES so all three layers model the
same preemption cost structure.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.perfmodel.exec_model import layer_latency
from repro.core.rt.task import SegmentTable
from repro.pipeline.serve import DEFAULT_BLOCK, window_plan
from repro.scheduler.des import StageOverhead


@dataclass(frozen=True)
class CostModel:
    """Per-(task, layer) virtual WCETs + window counts.

    ``layer_costs[i][j]`` is the full service of task i's layer j in
    (virtual) seconds; the serving runtime charges
    ``layer_costs[i][j] / layer_windows[i][j]`` per executed window.
    """

    layer_costs: tuple[tuple[float, ...], ...]
    layer_windows: tuple[tuple[int, ...], ...]
    stage_of_layer: tuple[tuple[int, ...], ...]
    n_stages: int
    source: str = "exec_model"

    def __post_init__(self) -> None:
        if not (
            len(self.layer_costs)
            == len(self.layer_windows)
            == len(self.stage_of_layer)
        ):
            raise ValueError("per-task vectors must align")
        for costs, wins, stages in zip(
            self.layer_costs, self.layer_windows, self.stage_of_layer
        ):
            if not (len(costs) == len(wins) == len(stages)):
                raise ValueError("per-layer vectors must align")
            if any(c <= 0.0 for c in costs):
                raise ValueError("layer costs must be positive")
            if any(w < 1 for w in wins):
                raise ValueError("each layer needs >= 1 window")
            if any(s < 0 or s >= self.n_stages for s in stages):
                raise ValueError("stage index out of range")

    # -- accessors ----------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return len(self.layer_costs)

    def layer_cost(self, task_id: int, layer: int) -> float:
        return self.layer_costs[task_id][layer]

    def window_cost(self, task_id: int, layer: int) -> float:
        """Virtual seconds one executed tile window charges."""
        return (
            self.layer_costs[task_id][layer]
            / self.layer_windows[task_id][layer]
        )

    def segment_cost(self, task_id: int, stage: int) -> float:
        """``b_i^k``: summed layer costs of task i's segment on stage k."""
        return sum(
            c
            for c, s in zip(
                self.layer_costs[task_id], self.stage_of_layer[task_id]
            )
            if s == stage
        )

    def stage_window_quantum(self) -> list[float]:
        """Worst-case single-window service per stage — how long a
        window-boundary preemptor can be blocked (the runtime's Eq. 5
        ``xi`` analogue; store/load cost 0 in the virtual executor)."""
        q = [0.0] * self.n_stages
        for i in range(self.n_tasks):
            for j, s in enumerate(self.stage_of_layer[i]):
                q[s] = max(q[s], self.window_cost(i, j))
        return q

    # -- bridges to the other layers ----------------------------------
    def segment_table(self) -> SegmentTable:
        """Analysis view: base = per-stage cost sums, overhead = the
        per-stage window quantum — one consistent WCET source for
        Eq. 2/3, the response bounds, and the DES."""
        base = [
            [self.segment_cost(i, k) for k in range(self.n_stages)]
            for i in range(self.n_tasks)
        ]
        return SegmentTable(base=base, overhead=self.stage_window_quantum())

    def des_overheads(self) -> list[StageOverhead]:
        """DES preemption costs matching the runtime: the preemptor
        drains at most one window (``pre`` = quantum) and resumption is
        free (``post`` = 0)."""
        return [
            StageOverhead(e_tile=q) for q in self.stage_window_quantum()
        ]

    def chunk_schedule(self) -> list[dict[int, tuple[float, ...]]]:
        """Per task: stage -> the non-preemptible chunk lengths (one
        per executed tile window, in execution order) of that task's
        segment on the stage — exactly the service quanta
        `PharosServer` charges between preemption opportunities. Feeds
        `scheduler.des.simulate_taskset(chunk_schedules=...,
        preemption="window")` so the DES defers preemption at the same
        boundaries the runtime does."""
        out: list[dict[int, tuple[float, ...]]] = []
        for i in range(self.n_tasks):
            per_stage: dict[int, list[float]] = {}
            for j, s in enumerate(self.stage_of_layer[i]):
                per_stage.setdefault(s, []).extend(
                    [self.window_cost(i, j)] * self.layer_windows[i][j]
                )
            out.append({k: tuple(v) for k, v in sorted(per_stage.items())})
        return out

    def scaled(self, factor: float) -> "CostModel":
        """Rescale every cost (e.g. analytic seconds -> wall seconds)."""
        if factor <= 0.0:
            raise ValueError("scale factor must be positive")
        return CostModel(
            layer_costs=tuple(
                tuple(c * factor for c in row) for row in self.layer_costs
            ),
            layer_windows=self.layer_windows,
            stage_of_layer=self.stage_of_layer,
            n_stages=self.n_stages,
            source=self.source,
        )

    # -- constructors -------------------------------------------------
    @classmethod
    def from_exec_model(
        cls,
        design,
        workloads,
        serve_tasks,
        *,
        block=DEFAULT_BLOCK,
        backend: str = "jnp",
        window_tiles: int = 4,
        period_scale: float = 1.0,
    ) -> "CostModel":
        """Price each workload layer on its assigned accelerator.

        ``serve_tasks`` (from `design_to_segments`) supply the stage map
        and the block-rounded GEMM geometry the server will actually
        execute, so window counts match the runtime exactly.
        """
        costs, windows, stages = [], [], []
        for i, (w, st) in enumerate(zip(workloads, serve_tasks)):
            if len(w.layers) != len(st.weights):
                raise ValueError(
                    f"task {st.name!r}: workload has {len(w.layers)} "
                    f"layers, serve task {len(st.weights)}"
                )
            row_c, row_w = [], []
            M = st.input_rows
            for layer, weight, k in zip(
                w.layers, st.weights, st.stage_of_layer
            ):
                K, N = weight.shape
                row_c.append(
                    layer_latency(layer, design.accs[k]) * period_scale
                )
                _, n_win = window_plan(
                    M, N, K,
                    block=block, backend=backend,
                    window_tiles=window_tiles,
                )
                row_w.append(n_win)
            costs.append(tuple(row_c))
            windows.append(tuple(row_w))
            stages.append(tuple(st.stage_of_layer))
        return cls(
            layer_costs=tuple(costs),
            layer_windows=tuple(windows),
            stage_of_layer=tuple(stages),
            n_stages=design.n_stages,
            source="exec_model",
        )

    @classmethod
    def calibrate(
        cls, server, *, reps: int = 3, period_scale: float = 1.0
    ) -> "CostModel":
        """Measure per-(task, layer) window wall times on ``server``'s
        executor (warmup-style probes; min over ``reps`` timed runs
        after one untimed compile pass) and return a wall-clock cost
        model. ``period_scale`` optionally rescales the measured
        seconds onto another timebase."""
        import jax
        import jax.numpy as jnp

        from repro.pipeline.serve import _run_window

        if reps < 1:
            raise ValueError("need at least one timed repetition")
        costs, windows, stages = [], [], []
        n_stages = len(server.stages)
        for i, t in enumerate(server.tasks):
            x = server.inputs[i]
            row_c, row_w = [], []
            for w in t.weights:
                M, (K, N) = x.shape[0], w.shape
                window, n_win = window_plan(
                    M, N, K,
                    block=server.block, backend=server.backend,
                    window_tiles=server.window_tiles,
                )
                c0 = jnp.zeros((M, N), jnp.float32)
                # untimed pass absorbs JIT compilation
                c, _ = _run_window(
                    x, w, c0, 0,
                    block=server.block, window=window,
                    backend=server.backend,
                )
                jax.block_until_ready(c)
                best = float("inf")
                for _ in range(reps):
                    # rtlint: disable=clock-domain -- calibration probe:
                    # this deliberately measures real kernel wall time
                    t0 = time.perf_counter()
                    c, _ = _run_window(
                        x, w, c0, 0,
                        block=server.block, window=window,
                        backend=server.backend,
                    )
                    jax.block_until_ready(c)
                    # rtlint: disable=clock-domain -- calibration probe
                    best = min(best, time.perf_counter() - t0)
                row_c.append(max(best, 1e-12) * n_win * period_scale)
                row_w.append(n_win)
                # chain shapes like the real execution (one window is
                # enough: probe timing is value-independent and `c`
                # already has the full (M, N) accumulator shape)
                x = c
            costs.append(tuple(row_c))
            windows.append(tuple(row_w))
            stages.append(tuple(t.stage_of_layer))
        return cls(
            layer_costs=tuple(costs),
            layer_windows=tuple(windows),
            stage_of_layer=tuple(stages),
            n_stages=n_stages,
            source="calibrated",
        )
