"""Cross-layer conformance harness: analysis vs DES vs serving runtime.

PHAROS's safety story rests on three layers telling the same story
about one scenario:

1. the **analysis** (`core.rt`): Eq. 3 schedulability + busy-period
   response bounds — sound upper bounds;
2. the **DES** (`scheduler.des`): event-driven simulation on the same
   WCETs — tighter, still model-level;
3. the **runtime** (`pipeline.serve` on a `VirtualClock` driven by a
   `CostModel`): the executing control flow, real GEMM windows, virtual
   time charged per window from the same WCETs.

The harness runs one scenario through all three under one policy and
enforces the soundness ordering

    analytical bound  >=  DES response  >=  runtime response (~)

together with verdict agreement: analysis-schedulable implies
DES-schedulable implies the runtime accumulates no backlog. Every
failure is reported as a `Violation` naming the two layers that
disagree and by how much — this is the differential-oracle methodology
real-time frameworks (Cheddar, MAST) use to validate analyses against
simulation, applied across our stack.

Preemption model and clock semantics: all three layers model the
**same limited-preemption discipline** — preemption only at tile-window
boundaries. The analysis carries it as a per-stage blocking term
(`end_to_end_bounds(blocking=...)`), the DES executes the `CostModel`'s
window chunks with boundary-deferred preemption
(``preemption="window"``), and the runtime realizes it between executed
GEMM windows. Analysis and DES run on their own exact virtual
timebases; the runtime leg runs on a `VirtualClock` advanced
event-to-event by modeled window WCETs (`run_virtual_server`), so every
number compared here is a deterministic model second. The one
wall-clock leg is `run_wallclock_case`, which runs the gateway on a
`WallClock` and compares against a *calibrated* (measured-WCET)
`CostModel` under an explicit noise margin.

Modeling notes that make the comparison apples-to-apples:

- All three layers read their WCETs from the same `CostModel`
  (`segment_table()` for analysis/DES, per-window costs for the
  runtime), so a disagreement is a *semantics* bug, never a unit skew.
- The window-boundary deferral inserts **no extra work** (the in-flight
  window completes useful work; accumulators stay resident, so there is
  no spill/reload xi). The layers therefore compare on *raw* WCETs —
  Eq. 3 on raw utilization is the sound verdict for every layer — and
  the window quantum enters the analysis once per stage as the
  limited-preemption **blocking term**, not as Eq. 4 inflation.
  (`CostModel.segment_table`/`des_overheads` still expose the
  conservative inserted-overhead accounting for admission users that
  want Eq. 4 margins.)
- Traffic is **regulated** to the admission contract before the run
  (`regulate_trace`): the analytic layer's premise is a minimum
  inter-arrival of one provisioned period, which raw Poisson/MMPP
  traces violate with probability 1. Unregulated overload is the
  shedding layer's test surface, not conformance's.
- Because the DES defers preemption at the same window boundaries as
  the runtime **and** mirrors its simultaneous-event ordering
  (releases before completions, completions in stage-index order,
  FIFO pools in insertion order — see `scheduler.des`), the DES >=
  runtime comparison needs only a residual-noise tolerance
  (`tol_rel`, plus `quantum_slack` windows absolute — strictly
  tighter than both the PR-2 values that absorbed the idealized-DES
  deferral gap and the PR-3 value that absorbed fan-in forwarding
  ties, which now agree bit-for-bit).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, replace

from repro.conformance.costmodel import CostModel
from repro.obs.diff import TraceDiff, trace_diff
from repro.obs.trace import TraceRecorder
from repro.core.rt.response_time import end_to_end_bounds
from repro.core.rt.schedulability import srt_schedulable
from repro.core.rt.task import SegmentTable, TaskSet
from repro.scheduler.des import SimResult, simulate_taskset


#: the registry scenarios whose traffic honours its own contract
#: (overdrive == 1) — the conformance acceptance sweep
DEFAULT_SCENARIOS = (
    "steady_city",
    "rush_hour",
    "sensor_fusion",
    "copilot_decode",
)

POLICIES = ("fifo", "edf")


def regulate_trace(times, min_gap: float) -> list[float]:
    """Clamp a release trace to the admission contract: consecutive
    gaps of at least ``min_gap`` (a leaky-bucket regulator — arrivals
    are delayed, never dropped)."""
    out: list[float] = []
    prev = None
    for t in times:
        t = float(t) if prev is None else max(float(t), prev + min_gap)
        out.append(t)
        prev = t
    return out


#: the DES-vs-runtime tolerance PR 2 shipped with an idealized
#: (instant-preemption) DES — kept as the reference point the
#: window-boundary DES must beat (asserted by
#: ``benchmarks/conformance_bench.py`` in CI)
PR2_TOL_REL = 0.02
PR2_QUANTUM_SLACK = 2.0

#: the slack the window-boundary DES needed *before* it adopted the
#: runtime's simultaneous-event tie-breaking (fan-in forwarding ties
#: were worth ~0.36 visit-quanta) — the reference point the aligned
#: DES must beat, asserted in CI alongside the PR-2 constants
PR3_QUANTUM_SLACK = 0.75


@dataclass(frozen=True)
class ConformanceConfig:
    #: simulated horizon, in multiples of the longest tenant period
    horizon_periods: float = 40.0
    #: enforce the min-inter-arrival contract on stochastic traces
    regulate: bool = True
    #: DES-vs-runtime schedule-noise tolerance (relative on the DES
    #: max). With the window-boundary DES the systematic deferral gap
    #: is gone, and since the DES adopted the runtime's
    #: simultaneous-event ordering (releases before completions,
    #: completions in stage-index order, FIFO pools in insertion order
    #: — the fan-in forwarding ties that used to cost ~0.36
    #: visit-quanta), the worst residual observed across the registry
    #: is 0.07 visit-quanta (``sensor_fusion``/edf), so both knobs sit
    #: strictly below the PR-3 values (0.01 / 0.75), which sat strictly
    #: below the `PR2_*` values before them
    tol_rel: float = 0.01
    #: plus this many worst-case windows of absolute slack
    quantum_slack: float = 0.25
    #: analysis-vs-DES tolerance (bounds are sound: float noise only)
    analysis_tol_rel: float = 1e-9
    #: runtime backlog divergence threshold (mirrors the DES's
    #: `SimConfig.backlog_limit` default)
    backlog_limit: int = 64
    # -- overload (shedding) case (`run_shedding_case`) ---------------
    #: DES-vs-runtime tolerance for the shedding case. Looser than the
    #: contract-honouring knobs above on purpose: under overload the
    #: two layers engage their (identical) shedding machinery against
    #: *their own* backlog observations, so the shed sets differ
    #: slightly and a surviving job may sit behind a job the other
    #: layer shed — noise proportional to the backlog the monitor
    #: tolerates before engaging, not to one tie-break
    shed_tol_rel: float = 0.05
    #: absolute slack of the shedding case, in worst-case windows
    shed_quantum_slack: float = 4.0
    #: surrogate-GEMM dimension cap for the virtual-server leg: timing
    #: comes from the CostModel, so the executed GEMMs only preserve
    #: window/stage structure (keeps LM-tenant chains host-runnable)
    max_dim: int = 512
    seed: int = 0
    #: record DES and runtime schedule traces (`repro.obs`) during
    #: `run_case` and attach a first-divergence `trace_diff` to the
    #: `CaseResult` — a tripped tolerance then names the exact event
    #: where the layers parted ways instead of just the worst job.
    #: Off by default: tracing is opt-in everywhere
    record_traces: bool = False
    # -- wall-clock case (`run_wallclock_case`) -----------------------
    #: horizon of the wall run, in multiples of the longest wall period
    wall_horizon_periods: float = 12.0
    #: timed repetitions per calibration probe
    wall_reps: int = 3
    #: utilization headroom of the wall timebase: periods are scaled so
    #: measured utilization sits at <= 1/headroom of the modeled one
    #: (leaves room for the serving loop's own Python overhead, which
    #: the per-window probes cannot see)
    wall_scale_headroom: float = 4.0
    #: noise margin on measured-vs-predicted wall responses: the host
    #: is not an RTOS — GC, scheduler jitter and JIT cache effects land
    #: on top of the calibrated WCETs, so the wall leg checks
    #: ``measured <= margin * analytic bound`` rather than the model
    #: legs' near-equality
    wall_margin: float = 3.0
    #: calibrated-admission mode (ROADMAP "conformance next steps"):
    #: the wall gateway's tenancy admission runs against the *measured*
    #: WCET contracts (`repro.traffic.admission.calibrated_requests`)
    #: instead of the modeled ones — every tenant must still fit (the
    #: wall timebase carries `wall_scale_headroom` of slack) and the
    #: cached verdict must survive full re-analysis
    calibrated_admission: bool = False


@dataclass(frozen=True)
class TaskConformance:
    """Per-task view of one conformance case."""

    task: str
    analytic_bound: float
    des_max: float
    des_jobs: int
    server_max: float
    server_jobs: int
    in_flight: int


@dataclass(frozen=True)
class Violation:
    """Two adjacent layers disagree; ``lhs`` should not exceed ``rhs``."""

    scenario: str
    policy: str
    task: str
    kind: str  # analytic_vs_des | des_vs_server | verdict_*
    lhs: float
    rhs: float
    detail: str

    @property
    def margin(self) -> float:
        return self.lhs - self.rhs

    def __str__(self) -> str:
        return (
            f"[{self.scenario}/{self.policy}] {self.kind} ({self.task}): "
            f"{self.lhs:.6g} > {self.rhs:.6g} — {self.detail}"
        )


@dataclass(frozen=True)
class CaseResult:
    scenario: str
    policy: str
    analysis_schedulable: bool
    des_schedulable: bool
    server_bounded: bool
    tasks: tuple[TaskConformance, ...]
    violations: tuple[Violation, ...]
    #: DES-vs-runtime first-divergence diagnosis, aligned under the
    #: case's own per-task conformance allowance (None unless
    #: `ConformanceConfig.record_traces`)
    trace_diff: TraceDiff | None = None
    #: host wall-clock seconds this case took (all three layers) —
    #: trend-tracked by ``benchmarks/conformance_bench.py``
    wall_seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclass(frozen=True)
class ConformanceReport:
    """Sweep result: scenarios x policies, one `CaseResult` each."""

    cases: tuple[CaseResult, ...]

    @property
    def violations(self) -> tuple[Violation, ...]:
        return tuple(v for c in self.cases for v in c.violations)

    @property
    def ok(self) -> bool:
        return not self.violations

    def case(self, scenario: str, policy: str) -> CaseResult:
        for c in self.cases:
            if c.scenario == scenario and c.policy == policy:
                return c
        raise KeyError((scenario, policy))

    def summary(self) -> str:
        lines = [
            f"{'scenario':14s} {'policy':6s} {'A-sched':7s} "
            f"{'DES-sched':9s} {'srv-ok':6s} {'worst des/bound':15s} "
            f"{'worst srv/des':13s} viol"
        ]
        for c in self.cases:
            r_ad = max(
                (
                    t.des_max / t.analytic_bound
                    for t in c.tasks
                    if math.isfinite(t.analytic_bound)
                    and t.analytic_bound > 0
                ),
                default=float("nan"),
            )
            r_sd = max(
                (
                    t.server_max / t.des_max
                    for t in c.tasks
                    if t.des_max > 0 and t.server_jobs
                ),
                default=float("nan"),
            )
            lines.append(
                f"{c.scenario:14s} {c.policy:6s} "
                f"{str(c.analysis_schedulable):7s} "
                f"{str(c.des_schedulable):9s} "
                f"{str(c.server_bounded):6s} "
                f"{r_ad:15.4f} {r_sd:13.4f} {len(c.violations)}"
            )
        for v in self.violations:
            lines.append(f"  VIOLATION {v}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the virtual-server leg
# ---------------------------------------------------------------------------
def run_virtual_server(
    serve_tasks,
    n_stages: int,
    policy: str,
    cost_model: CostModel,
    traces,
    horizon: float,
    *,
    trace=None,
):
    """Drive a cost-model `PharosServer` with explicit release traces on
    a `VirtualClock`, event-to-event (no quantization, no shedding — the
    conformance leg must see the raw runtime). ``trace`` (a
    `repro.obs.TraceRecorder`) captures the runtime's schedule events."""
    from repro.pipeline.serve import PharosServer
    from repro.traffic.clock import VirtualClock

    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        n_stages,
        policy=policy,
        cost_model=cost_model,
        clock=clk.now,
        sleep=clk.sleep,
        trace=trace,
    )
    sched = sorted(
        (t, i) for i, trace in enumerate(traces) for t in trace
    )
    pos = 0
    while True:
        now = clk.now()
        while pos < len(sched) and sched[pos][0] <= now:
            srv.submit(sched[pos][1], sched[pos][0])
            pos += 1
        if now >= horizon:
            break
        srv.step()
        nxt = srv.next_completion_time()
        if pos < len(sched):
            nxt = min(nxt, sched[pos][0])
        nxt = min(nxt, horizon)
        now2 = clk.now()
        if nxt > now2:
            clk.advance(nxt - now2)
    return srv.finalize_report(horizon)


# ---------------------------------------------------------------------------
# one case: scenario x policy through all three layers
# ---------------------------------------------------------------------------
def run_case(
    built,
    policy: str,
    *,
    cfg: ConformanceConfig | None = None,
) -> CaseResult:
    """Run one `BuiltScenario` through analysis, DES and the virtual
    runtime under ``policy`` and compare."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    # rtlint: disable=clock-domain -- harness self-timing: wall_seconds
    # reports how long the conformance run itself took, not model time
    t_start = time.perf_counter()
    scenario = built.scenario.name
    taskset = built.taskset
    preemptive = policy == "edf"

    serve_tasks, _requests, _arrivals = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    cm = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    # zero-overhead WCET view: window-boundary deferral inserts no work
    # (see module docstring), so analysis and DES run on raw WCETs and
    # the quantum enters the analysis as the blocking term instead of
    # as Eq. 4 inflation
    table = SegmentTable(
        base=cm.segment_table().base,
        overhead=[0.0] * cm.n_stages,
    )
    periods = [t.period for t in taskset.tasks]
    horizon = cfg.horizon_periods * max(periods)

    traces = built.des_arrivals(horizon)
    if cfg.regulate:
        traces = [
            [t for t in regulate_trace(tr, p) if t < horizon]
            for tr, p in zip(traces, periods)
        ]

    # per-stage blocking term: the longest non-preemptible window a
    # boundary-deferred preemptor can wait behind
    quanta = cm.stage_window_quantum()

    # layer 1: analysis (blocking-aware under EDF: limited preemption
    # adds at most one in-flight window per stage visit)
    sched_a = srt_schedulable(table, taskset, preemptive)
    bounds = end_to_end_bounds(table, taskset, policy, blocking=quanta)

    # layer 2: DES on the same WCETs with the runtime's own
    # limited-preemption semantics — jobs execute the CostModel's
    # window chunks and preemption defers to chunk boundaries, so the
    # DES-vs-runtime gap is tie-breaking noise, not a quantum
    des_tr = TraceRecorder() if cfg.record_traces else None
    srv_tr = TraceRecorder() if cfg.record_traces else None
    des: SimResult = simulate_taskset(
        table,
        taskset,
        policy,
        horizon=horizon,
        overheads=None,
        arrivals=traces,
        chunk_schedules=cm.chunk_schedule(),
        preemption="window",
        trace=des_tr,
    )

    # layer 3: the executing runtime in model-driven virtual time
    srv = run_virtual_server(
        serve_tasks, built.design.n_stages, policy, cm, traces, horizon,
        trace=srv_tr,
    )

    # ---- compare ----
    # per-task schedule-noise allowance: the DES now defers preemption
    # at the same window boundaries as the runtime, so the residual gap
    # is simultaneous-event tie-breaking (fractions of a window), not
    # the systematic one-window-per-stage deferral PR 2 tolerated
    visit_quanta = [
        sum(q for q, b in zip(quanta, row) if b > 0.0)
        for row in table.base
    ]
    violations: list[Violation] = []
    task_rows: list[TaskConformance] = []
    allow_by_task: dict[str, float] = {}
    for i, t in enumerate(taskset.tasks):
        r_des = des.response_times[i]
        r_srv = srv.response_times.get(t.name, [])
        des_max = max(r_des) if r_des else 0.0
        bound = bounds[i]
        if r_des and math.isfinite(bound):
            lhs = des_max
            if lhs > bound * (1.0 + cfg.analysis_tol_rel) + 1e-12:
                violations.append(
                    Violation(
                        scenario, policy, t.name, "analytic_vs_des",
                        lhs, bound,
                        "DES response exceeds the analytical bound",
                    )
                )
        # Same-task jobs complete in release order in both layers, so
        # index j names the *same job* on each side — compare job-wise.
        # A job only one side completed carries no ordering claim: the
        # other side not finishing it by the horizon means it was the
        # slower one on exactly that job (the runtime-slower direction
        # is still caught through in_flight/backlog below).
        allow = des_max * cfg.tol_rel + cfg.quantum_slack * visit_quanta[i]
        allow_by_task[t.name] = allow
        worst = None  # (excess, job index)
        for j, (rd, rs) in enumerate(zip(r_des, r_srv)):
            if rs > rd + allow and (worst is None or rs - rd > worst[0]):
                worst = (rs - rd, j)
        if worst is not None:
            j = worst[1]
            violations.append(
                Violation(
                    scenario, policy, t.name, "des_vs_server",
                    r_srv[j], r_des[j],
                    f"runtime response of job {j} exceeds the DES "
                    "beyond the window-quantization tolerance",
                )
            )
        task_rows.append(
            TaskConformance(
                task=t.name,
                analytic_bound=bound,
                des_max=des_max,
                des_jobs=len(r_des),
                server_max=max(r_srv) if r_srv else 0.0,
                server_jobs=len(r_srv),
                in_flight=srv.in_flight.get(t.name, 0),
            )
        )

    server_bounded = srv.jobs_completed > 0 and all(
        row.in_flight <= cfg.backlog_limit for row in task_rows
    )
    if sched_a and not des.schedulable:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_analysis_des",
                1.0, 0.0,
                "analysis says schedulable but the DES detected "
                f"divergence (overload={des.overload_detected}, "
                f"growth={des.growth_detected})",
            )
        )
    if des.schedulable and not server_bounded:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_des_server",
                float(max((r.in_flight for r in task_rows), default=0)),
                float(cfg.backlog_limit),
                "DES says schedulable but the runtime accumulated "
                "backlog",
            )
        )
    # ---- trace-level differential diagnosis ----
    # Align the two event streams under the same per-task allowance the
    # job-wise compare used: a tripped des_vs_server tolerance then
    # carries the *first* event where the layers parted ways, turning a
    # failed number into a pinpointed schedule divergence.
    diff = None
    if cfg.record_traces:
        diff = trace_diff(
            des_tr, srv_tr, time_tol=allow_by_task,
            names=("des", "runtime"),
        )
        if diff.divergence is not None:
            violations = [
                replace(v, detail=f"{v.detail}; first divergence: "
                        f"{diff.divergence}")
                if v.kind == "des_vs_server" else v
                for v in violations
            ]
    return CaseResult(
        scenario=scenario,
        policy=policy,
        analysis_schedulable=sched_a,
        des_schedulable=des.schedulable,
        server_bounded=server_bounded,
        tasks=tuple(task_rows),
        violations=tuple(violations),
        trace_diff=diff,
        # rtlint: disable=clock-domain -- harness self-timing (see t_start)
        wall_seconds=time.perf_counter() - t_start,
    )


# ---------------------------------------------------------------------------
# the sharded case: K pipeline shards, each held to the full contract
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardedCaseResult:
    """One scenario placed across K pipeline shards, every shard run
    through the full three-layer `run_case` plus a bit-exactness check
    of its per-shard O(stages) admission verdict."""

    scenario: str
    policy: str
    n_shards: int
    placement: str
    assignment: tuple[int, ...]
    cases: tuple[CaseResult, ...]  # one per non-empty shard
    admission_violations: tuple[Violation, ...]

    @property
    def violations(self) -> tuple[Violation, ...]:
        return self.admission_violations + tuple(
            v for c in self.cases for v in c.violations
        )

    @property
    def ok(self) -> bool:
        return not self.violations


def run_sharded_case(
    built,
    policy: str,
    *,
    shards: int,
    placement="least_loaded",
    cfg: ConformanceConfig | None = None,
) -> ShardedCaseResult:
    """Place ``built``'s tenants across ``shards`` replicas of its
    pipeline and hold **every shard** to the whole conformance
    contract: each shard's tenant subset runs through analysis, DES and
    virtual runtime (`run_case` on `BuiltScenario.subset` — same
    design, same traffic, restricted tenant set), and each shard's
    incremental Eq. 3 admission verdict is checked bit-exact against a
    full `srt_schedulable` re-analysis of the subset
    (``verdict_shard_admission`` on disagreement). With ``shards == 1``
    this degenerates to exactly `run_case` plus the admission check —
    the K=1 equivalence the tests pin."""
    from repro.traffic.admission import AdmissionController
    from repro.traffic.shard import plan_shards

    cfg = cfg or ConformanceConfig()
    preemptive = policy == "edf"
    # the same plan-construction path ShardedGateway.from_built uses,
    # so the contract checked here is the plan the gateway runs
    placement, plan = plan_shards(
        built.requests,
        shards,
        placement,
        n_stages=built.design.n_stages,
        preemptive=preemptive,
    )
    cases: list[CaseResult] = []
    adm_violations: list[Violation] = []
    for k, members in enumerate(plan.members):
        if not members:
            continue
        sub = built.subset(
            members, name=f"{built.scenario.name}#s{k}of{shards}"
        )
        cases.append(run_case(sub, policy, cfg=cfg))
        ctl = AdmissionController(
            [0.0] * built.design.n_stages, preemptive=preemptive
        )
        for r in sub.requests:
            ctl.admit(r)
        if not ctl.verify():
            adm_violations.append(
                Violation(
                    sub.scenario.name, policy, "*",
                    "verdict_shard_admission",
                    1.0, 0.0,
                    f"shard {k}'s cached Eq. 3 verdict disagrees with "
                    "the full re-analysis of its tenant subset",
                )
            )
    return ShardedCaseResult(
        scenario=built.scenario.name,
        policy=policy,
        n_shards=shards,
        placement=placement.name,
        assignment=plan.assignment,
        cases=tuple(cases),
        admission_violations=tuple(adm_violations),
    )


# ---------------------------------------------------------------------------
# the DSE case: every claimed-feasible design held to the serving stack
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DSECaseResult:
    """`run_dse_case` result: the DSE's feasibility claims checked
    against analysis, DES, runtime **and** a provisioned
    `ShardedGateway` serving the scenario's traffic."""

    scenario: str
    policy: str
    method: str
    #: feasible designs the search claimed in total
    n_claimed: int
    #: max_util of each design actually pushed through the three layers
    checked_utils: tuple[float, ...]
    n_shards: int
    placement: str
    assignment: tuple[int, ...]
    admitted: int
    released: int
    #: one full three-layer `run_case` per checked design
    cases: tuple[CaseResult, ...]
    dse_violations: tuple[Violation, ...]

    @property
    def violations(self) -> tuple[Violation, ...]:
        return self.dse_violations + tuple(
            v for c in self.cases for v in c.violations
        )

    @property
    def ok(self) -> bool:
        return not self.violations


def run_dse_case(
    scenario,
    policy: str = "edf",
    *,
    platform=None,
    shards: int = 2,
    placement="least_loaded",
    check_top: int = 2,
    max_m: int = 3,
    beam_width: int = 4,
    cfg: ConformanceConfig | None = None,
) -> DSECaseResult:
    """Differentially verify the DSE's feasibility claims end to end.

    The PHAROS pitch is that the SRT-guided DSE finds *feasible*
    designs — so every design it claims feasible must actually be
    feasible in the deployed stack, not just under Eq. 3 on the design
    table. This case:

    1. runs `explore` on the scenario's provisioning problem and takes
       the best ``check_top`` claimed-feasible designs;
    2. materializes each one (`traffic.scenarios.materialize`) and runs
       the full three-layer `run_case` on it — the analysis leg must
       agree the design is schedulable (``verdict_dse_claim``), and the
       usual bound/ordering checks must hold;
    3. provisions the best design into a `ShardedGateway`
       (`repro.core.dse.provision`) and serves the scenario's traffic:
       every tenant must be admitted on its shard
       (``verdict_dse_admission``), each shard's cached Eq. 3 verdict
       must survive full re-analysis (``verdict_dse_verify``), every
       shard must complete work inside the horizon (``dse_no_jobs``),
       and no shard may accumulate backlog (``verdict_dse_backlog``).
    """
    from repro.core.dse.explore import explore
    from repro.core.dse.provision import provision
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import (
        get_scenario,
        materialize,
        resolve_problem,
    )

    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    platform = platform or paper_platform(16)
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    workloads, taskset = resolve_problem(scenario, platform)
    res = explore(
        workloads,
        taskset,
        platform,
        method="beam",
        max_m=max_m,
        beam_width=beam_width,
    )
    if res.best is None:
        raise ValueError(
            f"scenario {scenario.name!r} has no feasible design to check"
        )
    claimed = [res.best] + [
        dp for dp in res.succ_pts if dp is not res.best
    ]
    claimed = claimed[: max(1, check_top)]

    violations: list[Violation] = []
    cases: list[CaseResult] = []
    for rank, dp in enumerate(claimed):
        built = materialize(
            scenario, workloads, taskset, dp, seed=cfg.seed
        )
        case = run_case(built, policy, cfg=cfg)
        cases.append(case)
        if not case.analysis_schedulable:
            violations.append(
                Violation(
                    scenario.name, policy, "*", "verdict_dse_claim",
                    dp.max_util, 1.0,
                    f"DSE claimed design #{rank} feasible "
                    f"(max_util={dp.max_util:.4f}) but the serve-path "
                    "analysis disagrees",
                )
            )

    # -- the provisioned gateway: DSE design -> shard plan -> traffic --
    plan = provision(
        scenario,
        platform,
        design=res.best,
        shards=shards,
        placement=placement,
        policy=policy,
        seed=cfg.seed,
    )
    gw = plan.sharded_gateway(max_dim=cfg.max_dim)
    decisions = gw.open()
    admitted = sum(1 for d in decisions if d.admitted)
    for d in decisions:
        if not d.admitted:
            violations.append(
                Violation(
                    scenario.name, policy, d.request.name,
                    "verdict_dse_admission",
                    d.max_util, 1.0,
                    "DSE-provisioned tenant rejected by its shard's "
                    f"Eq. 3 admission: {d.reason}",
                )
            )
    if not gw.verify():
        violations.append(
            Violation(
                scenario.name, policy, "*", "verdict_dse_verify",
                1.0, 0.0,
                "a shard's cached Eq. 3 verdict disagrees with the "
                "full re-analysis of its provisioned contract",
            )
        )
    horizon = cfg.horizon_periods * max(t.period for t in taskset.tasks)
    report = gw.run(horizon)
    released = report.total_released()
    for rep in report.reports:
        if rep is None:
            continue
        sr = rep.server_report
        worst = max(sr.in_flight.values(), default=0)
        if sr.jobs_completed == 0:
            violations.append(
                Violation(
                    scenario.name, policy, "*", "dse_no_jobs",
                    0.0, 1.0,
                    "a DSE-provisioned shard completed no jobs inside "
                    "the horizon",
                )
            )
        elif worst > cfg.backlog_limit:
            violations.append(
                Violation(
                    scenario.name, policy, "*", "verdict_dse_backlog",
                    float(worst), float(cfg.backlog_limit),
                    "a DSE-provisioned shard accumulated backlog the "
                    "claimed-feasible analysis says cannot happen",
                )
            )
    return DSECaseResult(
        scenario=scenario.name,
        policy=policy,
        method=res.method,
        n_claimed=len(res.succ_pts),
        checked_utils=tuple(dp.max_util for dp in claimed),
        n_shards=plan.n_shards,
        placement=plan.placement,
        assignment=plan.plan.assignment,
        admitted=admitted,
        released=released,
        cases=tuple(cases),
        dse_violations=tuple(violations),
    )


# ---------------------------------------------------------------------------
# the shedding case: overdriven traffic, shedding armed in DES & runtime
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SheddingTaskRow:
    """Per-task view of one overload-conformance case."""

    task: str
    des_completed: int
    des_shed: int
    server_completed: int
    server_shed: int
    matched_jobs: int
    des_max: float
    server_max: float
    in_flight: int


@dataclass(frozen=True)
class SheddingCaseResult:
    """DES-with-shedding vs runtime-with-shedding on overdriven traffic
    (`run_shedding_case`)."""

    scenario: str
    policy: str
    shed_policy: str
    analysis_schedulable: bool
    des_overloaded: bool
    server_bounded: bool
    tasks: tuple[SheddingTaskRow, ...]
    violations: tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def total_shed(self) -> tuple[int, int]:
        """(DES, runtime) shed totals."""
        return (
            sum(t.des_shed for t in self.tasks),
            sum(t.server_shed for t in self.tasks),
        )


def run_shedding_case(
    built,
    policy: str = "edf",
    *,
    shed_policy: str = "reject_newest",
    cfg: ConformanceConfig | None = None,
) -> SheddingCaseResult:
    """Overload conformance: drive **unregulated** (overdriven) traffic
    through the DES and the virtual runtime with the *same* shedding
    machinery armed in both — identical policy, identical analysis-
    derived engage limits (`des_release_shedding` mirrors what
    `TrafficGateway.open` computes) — and check that the layers still
    agree:

    - the analysis's restored promise: the provisioned set is Eq. 3
      schedulable, so shedding must keep the DES backlog bounded
      (``verdict_shed_des``) and the runtime backlog bounded whenever
      the DES's is (``verdict_shed_server``) — the PR-3 verdict chain
      under overload;
    - job-wise ordering on the *surviving* traffic: jobs are matched
      across layers by their release time (the shed sets may differ —
      each layer sheds against its own backlog observations), and every
      matched job's runtime response must not exceed its DES response
      beyond the shedding tolerance (``shed_des_vs_server``,
      `ConformanceConfig.shed_tol_rel` / ``shed_quantum_slack``).
    """
    from repro.pipeline.serve import PharosServer
    from repro.traffic.admission import AdmissionController
    from repro.traffic.arrival import TraceArrivals
    from repro.traffic.clock import VirtualClock
    from repro.traffic.gateway import TrafficGateway
    from repro.traffic.shedding import (
        BacklogMonitor,
        des_release_shedding,
        get_policy,
    )

    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    scenario = built.scenario.name
    taskset = built.taskset
    preemptive = policy == "edf"
    policy_obj = get_policy(shed_policy)

    serve_tasks, _requests, _arrivals = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    cm = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    table = SegmentTable(
        base=cm.segment_table().base,
        overhead=[0.0] * cm.n_stages,
    )
    periods = [t.period for t in taskset.tasks]
    horizon = cfg.horizon_periods * max(periods)
    # deliberately NOT regulated: overdriven traffic contradicting the
    # analysis is this case's whole premise
    traces = built.des_arrivals(horizon)
    quanta = cm.stage_window_quantum()

    sched_a = srt_schedulable(table, taskset, preemptive)

    # one seed controller defines the shedding limits both layers use
    seed_ctl = AdmissionController(
        [0.0] * built.design.n_stages, preemptive=preemptive
    )
    for r in built.requests:
        seed_ctl.admit(r)

    des: SimResult = simulate_taskset(
        table,
        taskset,
        policy,
        horizon=horizon,
        overheads=None,
        arrivals=traces,
        chunk_schedules=cm.chunk_schedule(),
        preemption="window",
        shedding=des_release_shedding(
            policy_obj, seed_ctl, built.requests, monitor=BacklogMonitor()
        ),
    )

    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        built.design.n_stages,
        policy=policy,
        cost_model=cm,
        clock=clk.now,
        sleep=clk.sleep,
    )
    gateway = TrafficGateway(
        srv,
        AdmissionController(
            [0.0] * built.design.n_stages, preemptive=preemptive
        ),
        list(built.requests),
        [TraceArrivals(times=tuple(tr)) for tr in traces],
        shedding=policy_obj,
        monitor=BacklogMonitor(),
        clock=clk,
    )
    report = gateway.run(horizon, warmup=True)
    sr = report.server_report

    visit_quanta = [
        sum(q for q, b in zip(quanta, row) if b > 0.0)
        for row in table.base
    ]
    violations: list[Violation] = []
    rows: list[SheddingTaskRow] = []
    for i, t in enumerate(taskset.tasks):
        r_des = des.response_times[i]
        # match "the same job" across layers by release time: both
        # sides release the identical trace floats, so equality is
        # exact. Completions are re-sorted by release first — a
        # demoted (best-effort) job may legitimately be overtaken by a
        # later guaranteed job of its own task, so completion order is
        # not release order under degrade policies.
        des_pairs = sorted(zip(des.completed_releases[i], r_des))
        srv_pairs = sorted(
            zip(
                sr.completed_releases.get(t.name, []),
                sr.response_times.get(t.name, []),
            )
        )
        r_srv = sr.response_times.get(t.name, [])
        des_max = max(r_des) if r_des else 0.0
        allow = (
            des_max * cfg.shed_tol_rel
            + cfg.shed_quantum_slack * visit_quanta[i]
        )
        matched = 0
        worst = None  # (excess, release, rs, rd)
        di = 0
        for rel, rs in srv_pairs:
            while di < len(des_pairs) and des_pairs[di][0] < rel:
                di += 1
            if di >= len(des_pairs) or des_pairs[di][0] != rel:
                continue  # the DES shed (or never finished) this one
            rd = des_pairs[di][1]
            di += 1
            matched += 1
            if rs > rd + allow and (worst is None or rs - rd > worst[0]):
                worst = (rs - rd, rel, rs, rd)
        if worst is not None:
            violations.append(
                Violation(
                    scenario, policy, t.name, "shed_des_vs_server",
                    worst[2], worst[3],
                    f"surviving job released at {worst[1]:.6g} responds "
                    "later in the runtime than in the DES beyond the "
                    "shedding tolerance",
                )
            )
        if matched == 0 and r_des and r_srv:
            # the join is by exact release-float equality; both layers
            # completing jobs with zero overlap means the stamps have
            # drifted (e.g. a non-zero clock origin) and the per-job
            # check above is comparing nothing — fail loudly instead
            # of green-lighting a vacuous case
            violations.append(
                Violation(
                    scenario, policy, t.name, "shed_no_matched_jobs",
                    float(len(r_srv)), 0.0,
                    "both layers completed jobs but none matched by "
                    "release time — the DES and runtime release stamps "
                    "have diverged and the survivor comparison is "
                    "vacuous",
                )
            )
        rows.append(
            SheddingTaskRow(
                task=t.name,
                des_completed=len(r_des),
                des_shed=des.shed_per_task[i],
                server_completed=len(r_srv),
                server_shed=report.tenant(t.name).shed,
                matched_jobs=matched,
                des_max=des_max,
                server_max=max(r_srv) if r_srv else 0.0,
                in_flight=sr.in_flight.get(t.name, 0),
            )
        )

    # only a *dropping* policy can restore the analysis's boundedness
    # promise under sustained overdrive — demote-only policies keep all
    # the work, so both layers legitimately diverge (together); the
    # matched-job and server-verdict checks above/below still hold them
    # to each other
    if (
        sched_a
        and getattr(policy_obj, "drops", True)
        and des.overload_detected
    ):
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_shed_des",
                1.0, 0.0,
                "provisioned set is Eq. 3 schedulable but the DES "
                "backlog diverged despite release-time (drop) shedding",
            )
        )
    server_bounded = sr.jobs_completed > 0 and all(
        r.in_flight <= cfg.backlog_limit for r in rows
    )
    if not des.overload_detected and not server_bounded:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_shed_server",
                float(max((r.in_flight for r in rows), default=0)),
                float(cfg.backlog_limit),
                "DES-with-shedding stayed bounded but the runtime "
                "accumulated backlog",
            )
        )
    return SheddingCaseResult(
        scenario=scenario,
        policy=policy,
        shed_policy=shed_policy,
        analysis_schedulable=sched_a,
        des_overloaded=des.overload_detected,
        server_bounded=server_bounded,
        tasks=tuple(rows),
        violations=tuple(violations),
    )


# ---------------------------------------------------------------------------
# the migration case: live tenant re-homing under the co-simulation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MigrationTenantRow:
    """Per-tenant view of one migration conformance case. Survivor
    counts are completed jobs inside the compared window (releases at
    least one analytic response bound before the horizon — the tail a
    layer may legitimately leave in flight is excluded)."""

    tenant: str
    migrated: bool
    donor: int
    target: int | None
    committed: bool
    aborted: bool
    held: int
    runtime_survivors: int
    des_survivors: int
    runtime_misses: int
    des_misses: int


@dataclass(frozen=True)
class MigrationCaseResult:
    """`run_migration_case` result: live migrations executed on the
    shared-clock co-simulated elastic gateway, replayed shard-by-shard
    through the DES on the *realized* release stamps, and held to:
    zero deadline violations in either layer during any handover,
    exact DES/runtime survivor-set agreement for every tenant, a
    committed Eq. 3 proof behind every re-home, and bit-exact per-shard
    admission verdicts after all the churn."""

    scenario: str
    policy: str
    n_shards: int
    commits: int
    aborts: int
    final_assignment: tuple[tuple[str, int], ...]
    tenants: tuple[MigrationTenantRow, ...]
    violations: tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations


def run_migration_case(
    built,
    policy: str = "edf",
    *,
    shards: int = 2,
    placement="least_loaded",
    plans=None,
    cfg: ConformanceConfig | None = None,
) -> MigrationCaseResult:
    """Live-migration conformance: run ``built`` on an **elastic**
    `ShardedGateway` (shared-clock co-simulation) with a
    `MigrationController` executing ``plans`` (default: re-home the
    first tenant slack-aware at 30% of the horizon), then replay each
    shard through the DES using the runtime's own realized release
    stamps as explicit arrival traces — the cross-layer join is the
    release float, exactly as in `run_shedding_case`.

    Checks, each a named `Violation` on failure:

    - ``migration_no_commit``   — vacuity: at least one plan committed.
    - ``migration_drain_stuck`` — every started drain finished inside
      the horizon.
    - ``migration_uncommitted_member`` — every committed tenant is an
      admitted member of its target shard (proof-before-commit held).
    - ``migration_survivor_mismatch`` — per tenant and shard, the DES
      and the runtime completed exactly the same job set (release
      stamps) outside the horizon tail.
    - ``migration_deadline_miss_runtime`` / ``..._des`` — zero
      deadline violations in either layer, handovers included.
    - ``migration_no_post_commit_service`` — each migrated tenant
      completed at least one job on its target shard (the post-commit
      Eq. 3 contract was actually exercised).
    - ``verdict_shard_admission`` — after all churn, every shard's
      cached Eq. 3 verdict survives full re-analysis.
    """
    from repro.traffic.migration import MigrationController, MigrationPlan
    from repro.traffic.shard import ShardedGateway

    cfg = cfg or ConformanceConfig()
    scenario = built.scenario.name
    periods = [t.period for t in built.taskset.tasks]
    horizon = cfg.horizon_periods * max(periods)
    names = [r.name for r in built.requests]
    n = len(names)

    rec = TraceRecorder()
    gw = ShardedGateway.from_built(
        built,
        shards=shards,
        placement=placement,
        policy=policy,
        seed=cfg.seed,
        max_dim=cfg.max_dim,
        elastic=True,
        trace=rec,
    )
    if plans is None:
        plans = [MigrationPlan(tenant=names[0], at=0.3 * horizon)]
    ctl = MigrationController(plans, trace=rec)
    gw.run(horizon, shared_clock=True, controller=ctl)

    violations: list[Violation] = []
    commits = len(ctl.committed)
    aborts = len(ctl.aborted)
    if commits == 0:
        violations.append(
            Violation(
                scenario, policy, "*", "migration_no_commit",
                0.0, 1.0,
                "no migration committed — the case proves nothing",
            )
        )
    for tenant in ctl.in_progress():
        violations.append(
            Violation(
                scenario, policy, tenant, "migration_drain_stuck",
                1.0, 0.0,
                "drain did not complete inside the horizon",
            )
        )
    for r in ctl.committed:
        target_gw = gw.gateways[r.target]
        if r.tenant not in target_gw.admission.names():
            violations.append(
                Violation(
                    scenario, policy, r.tenant,
                    "migration_uncommitted_member",
                    1.0, 0.0,
                    f"committed to shard {r.target} but not an admitted "
                    "member there",
                )
            )

    # ---- the DES replay: per shard, on the realized release stamps ----
    serve_tasks, _reqs, _arr = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    cm = built.conformance_cost_model(serve_tasks)
    table = SegmentTable(
        base=cm.segment_table().base,
        overhead=[0.0] * cm.n_stages,
    )
    idx = {nm: i for i, nm in enumerate(names)}
    realized: list[list[list[float]]] = [
        [[] for _ in range(n)] for _ in range(shards)
    ]
    for e in rec.events:
        if e.layer == "gateway" and e.kind == "release":
            realized[e.shard][idx[e.task]].append(e.release)
    des_runs = [
        simulate_taskset(
            table,
            built.taskset,
            policy,
            horizon=horizon,
            overheads=None,
            arrivals=[sorted(tr) for tr in realized[k]],
            chunk_schedules=cm.chunk_schedule(),
            preemption="window",
        )
        for k in range(shards)
    ]

    # tail: a release may legitimately still be in flight at the
    # horizon; outside one analytic response bound the layers must
    # agree exactly on who survived
    bounds = end_to_end_bounds(
        table, built.taskset, policy, blocking=cm.stage_window_quantum()
    )
    by_record = {r.tenant: r for r in ctl.records}
    rows: list[MigrationTenantRow] = []
    for i, nm in enumerate(names):
        cutoff = horizon - bounds[i]
        deadline = built.taskset.tasks[i].deadline
        rt_surv: set[tuple[int, float]] = set()
        rt_misses = 0
        for k in range(shards):
            sr = gw.gateways[k].server.report
            rt_surv |= {
                (k, rel)
                for rel in sr.completed_releases.get(nm, [])
                if rel <= cutoff
            }
            rt_misses += gw.gateways[k].server.report.deadline_misses.get(
                nm, 0
            )
        des_surv: set[tuple[int, float]] = set()
        des_misses = 0
        for k, des in enumerate(des_runs):
            des_surv |= {
                (k, rel)
                for rel in des.completed_releases[i]
                if rel <= cutoff
            }
            des_misses += sum(
                1
                for rel, resp in zip(
                    des.completed_releases[i], des.response_times[i]
                )
                if rel <= cutoff and resp > deadline + 1e-9
            )
        if rt_surv != des_surv:
            delta = rt_surv.symmetric_difference(des_surv)
            violations.append(
                Violation(
                    scenario, policy, nm, "migration_survivor_mismatch",
                    float(len(delta)), 0.0,
                    f"DES and runtime disagree on {len(delta)} completed "
                    f"jobs (runtime {len(rt_surv)}, DES {len(des_surv)})",
                )
            )
        if rt_misses:
            violations.append(
                Violation(
                    scenario, policy, nm,
                    "migration_deadline_miss_runtime",
                    float(rt_misses), 0.0,
                    "runtime violated a deadline during the migrated run",
                )
            )
        if des_misses:
            violations.append(
                Violation(
                    scenario, policy, nm, "migration_deadline_miss_des",
                    float(des_misses), 0.0,
                    "DES violated a deadline during the migrated run",
                )
            )
        r = by_record.get(nm)
        if r is not None and r.committed:
            post = [
                (k, rel)
                for (k, rel) in sorted(rt_surv)
                if k == r.target and rel >= (r.committed_at or 0.0)
            ]
            if not post:
                violations.append(
                    Violation(
                        scenario, policy, nm,
                        "migration_no_post_commit_service",
                        0.0, 1.0,
                        "no job completed on the target shard after the "
                        "commit — the re-homed contract was never "
                        "exercised",
                    )
                )
        rows.append(
            MigrationTenantRow(
                tenant=nm,
                migrated=r is not None,
                donor=r.donor if r is not None else -1,
                target=r.target if r is not None else None,
                committed=bool(r is not None and r.committed),
                aborted=bool(r is not None and r.aborted),
                held=r.held if r is not None else 0,
                runtime_survivors=len(rt_surv),
                des_survivors=len(des_surv),
                runtime_misses=rt_misses,
                des_misses=des_misses,
            )
        )

    if not gw.verify():
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_shard_admission",
                1.0, 0.0,
                "a shard's cached Eq. 3 verdict disagrees with the full "
                "re-analysis after migration churn",
            )
        )
    return MigrationCaseResult(
        scenario=scenario,
        policy=policy,
        n_shards=shards,
        commits=commits,
        aborts=aborts,
        final_assignment=tuple(sorted(ctl.final_assignment().items())),
        tenants=tuple(rows),
        violations=tuple(violations),
    )


# ---------------------------------------------------------------------------
# the mode-switch case: mixed-criticality overload transitions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModeSwitchTaskRow:
    """Per-task view of one mode-switch conformance case.

    The ``*_misses`` columns count **per-class guarantee** violations
    in the SRT sense: jobs whose response exceeds the survivor set's
    analytic bound plus the transition allowance (see
    `run_mode_switch_case`). Tenants outside the survivor set carry no
    guarantee in HI mode, so their columns are definitionally zero."""

    task: str
    criticality: str
    des_completed: int
    des_shed: int
    des_degraded: int
    des_misses: int
    server_completed: int
    server_shed: int
    server_degraded: int
    server_misses: int
    matched_jobs: int
    des_max: float
    server_max: float


@dataclass(frozen=True)
class ModeSwitchCaseResult:
    """DES-with-modes vs runtime-with-modes on overdriven
    mixed-criticality traffic (`run_mode_switch_case`)."""

    scenario: str
    policy: str
    action: str
    analysis_schedulable: bool
    #: every committed HI entry carried a schedulable Eq. 3 re-proof of
    #: its survivor set (in both layers)
    hi_proof_schedulable: bool
    #: committed transitions, ``(t, mode, survivors)`` per layer
    des_switches: tuple[tuple[float, str, tuple[str, ...]], ...]
    server_switches: tuple[tuple[float, str, tuple[str, ...]], ...]
    #: the agreed HI-mode guarantee set (first HI entry)
    survivors: tuple[str, ...]
    tasks: tuple[ModeSwitchTaskRow, ...]
    violations: tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def hi_miss_totals(self) -> tuple[int, int]:
        """(DES, runtime) deadline-miss totals over the HI class."""
        hi = [t for t in self.tasks if t.criticality == "HI"]
        return (
            sum(t.des_misses for t in hi),
            sum(t.server_misses for t in hi),
        )


def _hi_entries(switches):
    """The HI-entry transitions of one layer's switch log."""
    return [s for s in switches if s[1] == "hi"]


def run_mode_switch_case(
    built,
    policy: str = "edf",
    *,
    action: str = "degrade",
    cfg: ConformanceConfig | None = None,
) -> ModeSwitchCaseResult:
    """Mixed-criticality mode-switch conformance: drive **unregulated**
    overdriven traffic through the DES and the virtual runtime with a
    `repro.traffic.modes.ModeController` armed in both — identical
    criticality contracts, identical analysis-derived engage limits —
    and check that the overload mode machinery tells one story:

    - **switches happen**: both layers must commit at least one HI
      entry (``mode_no_switch``) — an overdriven scenario that never
      trips the monitor makes every other check vacuous;
    - **survivor agreement**: every HI entry's survivor set — the Eq. 3
      re-proved HI guarantee set — must be identical in both layers and
      across repeated entries (``mode_survivor_mismatch``). Survivors
      are a pure function of the criticality contracts and the
      admission analysis, never of the traffic, so this holds exactly
      even when the two layers switch at slightly different times;
    - **the proof is real**: every committed HI entry must carry a
      schedulable re-proof (``mode_unschedulable_survivors``);
    - **per-class Eq. 3 guarantee**: zero HI deadline misses in either
      layer over the whole run, transitions included
      (``mode_hi_miss_des`` / ``mode_hi_miss_server``). "Miss" is the
      SRT (bounded-tardiness) sense every other case in this harness
      uses: a HI job misses when its response exceeds the **survivor
      set's own analytic bound** (`end_to_end_bounds` over the HI
      subset, blocking-aware) plus the **transition allowance** — the
      LO backlog the `BacklogMonitor` hysteresis tolerates before the
      switch commits (engage limit x per-job service, summed over the
      LO tenants) — plus the case's overload schedule-noise tolerance.
      The gate applies where the action can actually protect the HI
      class: a *dropping* action under any policy, a *demoting* action
      only under EDF (demotion works by deadline ordering; FIFO keeps
      demoted jobs in their pool positions, so degrade-under-FIFO
      carries no HI guarantee and the rows report misses without
      gating them — the same carve-out `run_shedding_case` makes for
      demote-only boundedness);
    - job-wise ordering on matched HI jobs (release-time join, same as
      `run_shedding_case`, under the same overload tolerances
      `ConformanceConfig.shed_tol_rel`/``shed_quantum_slack``):
      ``mode_des_vs_server``, with the ``mode_no_matched_jobs``
      vacuity guard.
    """
    from repro.pipeline.serve import PharosServer
    from repro.traffic.admission import CRITICALITY_HI, AdmissionController
    from repro.traffic.arrival import TraceArrivals
    from repro.traffic.clock import VirtualClock
    from repro.traffic.gateway import TrafficGateway
    from repro.traffic.modes import ModeController

    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    scenario = built.scenario.name
    taskset = built.taskset
    preemptive = policy == "edf"

    serve_tasks, _requests, _arrivals = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    cm = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    table = SegmentTable(
        base=cm.segment_table().base,
        overhead=[0.0] * cm.n_stages,
    )
    periods = [t.period for t in taskset.tasks]
    horizon = cfg.horizon_periods * max(periods)
    # unregulated on purpose: the LO overdrive is what trips the mode
    traces = built.des_arrivals(horizon)
    quanta = cm.stage_window_quantum()

    sched_a = srt_schedulable(table, taskset, preemptive)

    # twin mode controllers, one per layer, over that layer's own
    # admission state — identical contracts in, so identical limits
    # and identical survivor proofs out
    des_ctl = AdmissionController(
        [0.0] * built.design.n_stages, preemptive=preemptive
    )
    for r in built.requests:
        des_ctl.admit(r)
    des_modes = ModeController(
        des_ctl, list(built.requests), action=action
    )

    des: SimResult = simulate_taskset(
        table,
        taskset,
        policy,
        horizon=horizon,
        overheads=None,
        arrivals=traces,
        chunk_schedules=cm.chunk_schedule(),
        preemption="window",
        shedding=des_modes,
    )

    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        built.design.n_stages,
        policy=policy,
        cost_model=cm,
        clock=clk.now,
        sleep=clk.sleep,
    )
    gw_ctl = AdmissionController(
        [0.0] * built.design.n_stages, preemptive=preemptive
    )
    gw_modes = ModeController(
        gw_ctl, list(built.requests), action=action
    )
    gateway = TrafficGateway(
        srv,
        gw_ctl,
        list(built.requests),
        [TraceArrivals(times=tuple(tr)) for tr in traces],
        modes=gw_modes,
        clock=clk,
    )
    report = gateway.run(horizon, warmup=True)
    sr = report.server_report

    visit_quanta = [
        sum(q for q, b in zip(quanta, row) if b > 0.0)
        for row in table.base
    ]
    crit = {r.name: r.criticality for r in built.requests}
    violations: list[Violation] = []

    # -- transition agreement ----------------------------------------
    des_hi = _hi_entries(des.mode_switches)
    srv_hi = _hi_entries(report.mode_switches)
    if not des_hi or not srv_hi:
        violations.append(
            Violation(
                scenario, policy, "*", "mode_no_switch",
                float(bool(des_hi)) + float(bool(srv_hi)), 2.0,
                "overdriven scenario never committed a HI entry in "
                f"{'the DES' if not des_hi else 'the runtime'} — the "
                "mode-switch case is vacuous",
            )
        )
    survivor_sets = {s[2] for s in des_hi} | {s[2] for s in srv_hi}
    survivors = des_hi[0][2] if des_hi else (
        srv_hi[0][2] if srv_hi else ()
    )
    if len(survivor_sets) > 1:
        violations.append(
            Violation(
                scenario, policy, "*", "mode_survivor_mismatch",
                float(len(survivor_sets)), 1.0,
                "HI-entry survivor sets disagree across layers or "
                f"entries: {sorted(survivor_sets)}",
            )
        )
    hi_proof = all(
        s.schedulable
        for mc in (des_modes, gw_modes)
        for s in mc.switches
        if s.mode == "hi"
    )
    if not hi_proof:
        violations.append(
            Violation(
                scenario, policy, "*", "mode_unschedulable_survivors",
                0.0, 1.0,
                "a committed HI entry carried a failing Eq. 3 re-proof "
                "— the HI guarantee is vacuous",
            )
        )

    # -- per-class guarantee allowance -------------------------------
    # the survivor subset's own analytic bounds (blocking-aware, same
    # formula as `run_case`) ...
    name_to_idx = {t.name: i for i, t in enumerate(taskset.tasks)}
    surv_idx = [name_to_idx[n] for n in survivors if n in name_to_idx]
    hi_bounds: dict[str, float] = {}
    if surv_idx:
        hi_table = SegmentTable(
            base=[table.base[i] for i in surv_idx],
            overhead=list(table.overhead),
        )
        hi_ts = TaskSet(tasks=tuple(taskset.tasks[i] for i in surv_idx))
        for t2, b in zip(
            hi_ts.tasks,
            end_to_end_bounds(hi_table, hi_ts, policy, blocking=quanta),
        ):
            hi_bounds[t2.name] = b
    # ... plus the transition allowance: the backlog (engage limit x
    # per-job service) the hysteresis tolerates from each non-survivor
    # before the switch commits — work the HI class may still sit
    # behind across the transition
    limits = des_modes.limits()
    carryover = sum(
        limits[i] * sum(table.base[i])
        for i, r in enumerate(built.requests)
        if r.name not in hi_bounds
    )
    # where the action can actually protect the HI class: dropping
    # removes LO work under any policy; demotion works through
    # deadline ordering, so it only bites under EDF (see docstring)
    guarantee_armed = action == "drop" or preemptive

    # -- per-task rows + per-class guarantees ------------------------
    rows: list[ModeSwitchTaskRow] = []
    for i, t in enumerate(taskset.tasks):
        r_des = des.response_times[i]
        r_srv = sr.response_times.get(t.name, [])
        des_pairs = sorted(zip(des.completed_releases[i], r_des))
        srv_pairs = sorted(
            zip(
                sr.completed_releases.get(t.name, []),
                r_srv,
            )
        )
        des_max = max(r_des) if r_des else 0.0
        allow = (
            des_max * cfg.shed_tol_rel
            + cfg.shed_quantum_slack * visit_quanta[i]
        )
        # SRT "miss": response beyond the survivor-set bound plus the
        # transition allowance (non-survivors carry no guarantee)
        miss_allow = hi_bounds.get(t.name, math.inf) + carryover + allow
        des_misses = sum(1 for r in r_des if r > miss_allow)
        srv_misses = sum(1 for r in r_srv if r > miss_allow)
        matched = 0
        worst = None
        di = 0
        for rel, rs in srv_pairs:
            while di < len(des_pairs) and des_pairs[di][0] < rel:
                di += 1
            if di >= len(des_pairs) or des_pairs[di][0] != rel:
                continue
            rd = des_pairs[di][1]
            di += 1
            matched += 1
            if (
                crit[t.name] == CRITICALITY_HI
                and rs > rd + allow
                and (worst is None or rs - rd > worst[0])
            ):
                worst = (rs - rd, rel, rs, rd)
        if worst is not None:
            violations.append(
                Violation(
                    scenario, policy, t.name, "mode_des_vs_server",
                    worst[2], worst[3],
                    f"HI job released at {worst[1]:.6g} responds later "
                    "in the runtime than in the DES beyond the "
                    "overload tolerance",
                )
            )
        if matched == 0 and r_des and r_srv:
            violations.append(
                Violation(
                    scenario, policy, t.name, "mode_no_matched_jobs",
                    float(len(r_srv)), 0.0,
                    "both layers completed jobs but none matched by "
                    "release time — the release stamps have diverged "
                    "and the HI-job comparison is vacuous",
                )
            )
        if t.name in hi_bounds and guarantee_armed:
            if des_misses:
                violations.append(
                    Violation(
                        scenario, policy, t.name, "mode_hi_miss_des",
                        float(des_misses), 0.0,
                        "HI tenant exceeded its survivor-set bound "
                        "plus the transition allowance in the DES — "
                        "the per-class Eq. 3 guarantee is broken at "
                        "the model layer",
                    )
                )
            if srv_misses:
                violations.append(
                    Violation(
                        scenario, policy, t.name, "mode_hi_miss_server",
                        float(srv_misses), 0.0,
                        "HI tenant exceeded its survivor-set bound "
                        "plus the transition allowance in the runtime "
                        "— the per-class Eq. 3 guarantee is broken at "
                        "the serving layer",
                    )
                )
        rows.append(
            ModeSwitchTaskRow(
                task=t.name,
                criticality=crit[t.name],
                des_completed=len(r_des),
                des_shed=des.shed_per_task[i],
                des_degraded=des.degraded_per_task[i],
                des_misses=des_misses,
                server_completed=len(r_srv),
                server_shed=report.tenant(t.name).shed,
                server_degraded=report.tenant(t.name).degraded,
                server_misses=srv_misses,
                matched_jobs=matched,
                des_max=des_max,
                server_max=max(r_srv) if r_srv else 0.0,
            )
        )

    return ModeSwitchCaseResult(
        scenario=scenario,
        policy=policy,
        action=action,
        analysis_schedulable=sched_a,
        hi_proof_schedulable=hi_proof,
        des_switches=tuple(des.mode_switches),
        server_switches=tuple(report.mode_switches),
        survivors=survivors,
        tasks=tuple(rows),
        violations=tuple(violations),
    )


# ---------------------------------------------------------------------------
# the wall-clock case: calibrated CostModel vs the real clock
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class WallClockTask:
    """Per-task view of one wall-clock conformance case (wall seconds)."""

    task: str
    measured_median: float
    measured_max: float
    jobs: int
    predicted_des_max: float
    predicted_bound: float
    in_flight: int


@dataclass(frozen=True)
class WallClockCase:
    """One `run_wallclock_case` result: the gateway on a real clock vs
    the calibrated `CostModel`'s predictions."""

    scenario: str
    policy: str
    #: model-seconds -> wall-seconds conversion applied to periods
    period_scale: float
    margin: float
    horizon_s: float
    tasks: tuple[WallClockTask, ...]
    violations: tuple[Violation, ...]
    #: which WCETs tenancy admission ran against ("model"/"calibrated")
    admission_mode: str = "model"

    @property
    def ok(self) -> bool:
        return not self.violations


def run_wallclock_case(
    built,
    policy: str = "edf",
    *,
    cfg: ConformanceConfig | None = None,
    trace=None,
) -> WallClockCase:
    """ROADMAP's calibrated wall-clock conformance case: run the
    `TrafficGateway` on a **real** `WallClock` and check the observed
    response times against the *calibrated* `CostModel`'s predictions.

    Procedure:

    1. calibrate per-(task, layer) window WCETs on this host
       (`CostModel.calibrate` — measured, not modeled);
    2. rescale the scenario's periods onto the wall timebase with
       `wall_scale_headroom` of utilization slack (the probes measure
       pure window execution; the serving loop adds Python overhead the
       model cannot see);
    3. release the contract-regulated traces through the gateway on the
       wall clock, executing real GEMM windows;
    4. compare each task's **median** measured response against the
       blocking-aware analytic bound on the *measured* WCET table,
       under the explicit `wall_margin` (the host is not an RTOS: a GC
       pause or scheduler throttle can blow any single job's response,
       so the per-job max is reported but only the typical-path median
       gates — this leg checks calibrated-model fidelity, not hard
       real-time).

    The DES prediction on the measured chunks is reported alongside for
    reference. Violations use kind ``wall_vs_model`` (median response
    above margin * bound), ``wall_no_jobs`` (a tenant finished nothing
    inside the horizon) and ``verdict_wall_backlog`` (runtime
    accumulated backlog the measured-WCET analysis says cannot happen).

    With ``cfg.calibrated_admission`` the gateway's tenancy admission
    runs against the **measured** WCET contracts
    (`repro.traffic.admission.calibrated_requests` on the calibrated
    `CostModel`) instead of the modeled ones — the ROADMAP's
    calibrated-cost-model admission mode. Two extra violation kinds
    guard it: ``calibrated_admission_reject`` (a tenant the measured
    analysis must fit was rejected) and
    ``verdict_calibrated_admission`` (cached verdict vs full measured
    re-analysis disagree).

    ``trace`` (a `repro.obs.TraceRecorder`) captures the wall run's
    gateway and server schedule events. Callers that retry on host
    throttle should pass one shared recorder across attempts (tagging
    each via `repro.obs.TraceRecorder.annotate`), so a discarded first
    attempt's measurements stay visible instead of being lost.
    """
    from repro.core.rt.task import Task, TaskSet
    from repro.pipeline.serve import PharosServer
    from repro.traffic.admission import AdmissionController
    from repro.traffic.arrival import TraceArrivals
    from repro.traffic.clock import WallClock
    from repro.traffic.gateway import TrafficGateway

    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    scenario = built.scenario.name

    # 1. calibrate on the same GEMM geometry the wall run will execute
    serve_model, _req, _arr = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    probe = PharosServer(
        serve_model, built.design.n_stages, policy=policy
    )
    measured = CostModel.calibrate(probe, reps=cfg.wall_reps)
    modeled = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_model
    )

    # 2. wall timebase: scale every period by headroom x the worst
    # measured/modeled segment ratio, so measured utilization is at
    # most modeled utilization / headroom on every stage
    ratio = max(
        measured.segment_cost(i, k) / modeled.segment_cost(i, k)
        for i in range(modeled.n_tasks)
        for k in range(modeled.n_stages)
        if modeled.segment_cost(i, k) > 0.0
    )
    scale = cfg.wall_scale_headroom * ratio
    serve_tasks, requests, arrivals = built.serve_bundle(
        period_scale=scale, seed=cfg.seed, max_dim=cfg.max_dim
    )
    wall_taskset = TaskSet(
        tasks=tuple(
            Task(
                workload=w,
                period=t.period * scale,
                deadline=t.deadline * scale,
                sporadic=t.sporadic,
                name=t.name,
            )
            for w, t in zip(built.workloads, built.taskset.tasks)
        )
    )
    periods = [t.period for t in wall_taskset.tasks]
    horizon = cfg.wall_horizon_periods * max(periods)

    # 3. predictions from the measured model (wall seconds throughout)
    table = SegmentTable(
        base=measured.segment_table().base,
        overhead=[0.0] * measured.n_stages,
    )
    quanta = measured.stage_window_quantum()
    bounds = end_to_end_bounds(table, wall_taskset, policy, blocking=quanta)
    traces = [p.arrivals(horizon) for p in arrivals]
    if cfg.regulate:
        traces = [
            [x for x in regulate_trace(tr, p) if x < horizon]
            for tr, p in zip(traces, periods)
        ]
    des: SimResult = simulate_taskset(
        table,
        wall_taskset,
        policy,
        horizon=horizon,
        overheads=None,
        arrivals=traces,
        chunk_schedules=measured.chunk_schedule(),
        preemption="window",
    )

    # 4. the wall run: same regulated traces, replayed on the real
    # clock. Admission runs on raw WCETs (zero inserted overhead):
    # window-boundary deferral blocks, it does not inflate utilization
    # — the same premise every other conformance leg uses. In
    # calibrated-admission mode the contracts are re-based onto the
    # *measured* WCETs first, so tenancy admission answers against
    # what this host actually does.
    from repro.traffic.admission import calibrated_requests

    if cfg.calibrated_admission:
        gw_requests = list(calibrated_requests(measured, requests))
    else:
        gw_requests = list(requests)
    srv = PharosServer(
        serve_tasks, built.design.n_stages, policy=policy, trace=trace
    )
    admission = AdmissionController(
        [0.0] * built.design.n_stages,
        preemptive=(policy == "edf"),
    )
    gateway = TrafficGateway(
        srv,
        admission,
        gw_requests,
        [TraceArrivals(times=tuple(tr)) for tr in traces],
        clock=WallClock(),
        trace=trace,
    )
    report = gateway.run(horizon, warmup=True)
    sr = report.server_report

    violations: list[Violation] = []
    if cfg.calibrated_admission:
        # the measured analysis at `wall_scale_headroom` slack must
        # admit every tenant, and the cached verdict must agree with a
        # full re-analysis of the measured contracts
        for d in report.decisions:
            if not d.admitted:
                violations.append(
                    Violation(
                        scenario, policy, d.request.name,
                        "calibrated_admission_reject",
                        d.max_util, 1.0,
                        "measured-WCET contract rejected despite the "
                        f"{cfg.wall_scale_headroom:g}x provisioning "
                        f"headroom: {d.reason}",
                    )
                )
        if not admission.verify():
            violations.append(
                Violation(
                    scenario, policy, "*",
                    "verdict_calibrated_admission",
                    1.0, 0.0,
                    "calibrated admission's cached Eq. 3 verdict "
                    "disagrees with the full measured re-analysis",
                )
            )
    task_rows: list[WallClockTask] = []
    for i, t in enumerate(wall_taskset.tasks):
        rts = sorted(sr.response_times.get(t.name, []))
        measured_median = rts[len(rts) // 2] if rts else 0.0
        des_r = des.response_times[i]
        row = WallClockTask(
            task=t.name,
            measured_median=measured_median,
            measured_max=rts[-1] if rts else 0.0,
            jobs=len(rts),
            predicted_des_max=max(des_r) if des_r else 0.0,
            predicted_bound=bounds[i],
            in_flight=sr.in_flight.get(t.name, 0),
        )
        task_rows.append(row)
        if not rts:
            violations.append(
                Violation(
                    scenario, policy, t.name, "wall_no_jobs",
                    0.0, 1.0,
                    "tenant completed no jobs inside the wall horizon",
                )
            )
        elif (
            math.isfinite(bounds[i])
            and measured_median > cfg.wall_margin * bounds[i]
        ):
            violations.append(
                Violation(
                    scenario, policy, t.name, "wall_vs_model",
                    measured_median, cfg.wall_margin * bounds[i],
                    "median wall-clock response exceeds the calibrated "
                    f"analytic bound x{cfg.wall_margin:g} margin",
                )
            )
    worst_backlog = max((r.in_flight for r in task_rows), default=0)
    if sr.jobs_completed == 0 or worst_backlog > cfg.backlog_limit:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_wall_backlog",
                float(worst_backlog), float(cfg.backlog_limit),
                "measured-WCET analysis says bounded but the wall run "
                "accumulated backlog",
            )
        )
    return WallClockCase(
        scenario=scenario,
        policy=policy,
        period_scale=scale,
        margin=cfg.wall_margin,
        horizon_s=horizon,
        tasks=tuple(task_rows),
        violations=tuple(violations),
        admission_mode=(
            "calibrated" if cfg.calibrated_admission else "model"
        ),
    )


def run_conformance(
    scenarios=DEFAULT_SCENARIOS,
    policies=POLICIES,
    *,
    platform=None,
    cfg: ConformanceConfig | None = None,
    max_m: int = 3,
    beam_width: int = 4,
    prebuilt: dict | None = None,
) -> ConformanceReport:
    """Sweep ``scenarios x policies`` and collect every violation.

    Each scenario is resolved once (`traffic.scenarios.build` runs the
    DSE) and reused across policies; ``prebuilt`` maps scenario names
    to already-resolved `BuiltScenario`s to skip their DSE entirely.
    """
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import build, get_scenario

    platform = platform or paper_platform(16)
    cfg = cfg or ConformanceConfig()
    cases = []
    for name in scenarios:
        built = (prebuilt or {}).get(name) or build(
            get_scenario(name),
            platform,
            max_m=max_m,
            beam_width=beam_width,
            seed=cfg.seed,
        )
        for policy in policies:
            cases.append(run_case(built, policy, cfg=cfg))
    return ConformanceReport(cases=tuple(cases))
