"""Cross-layer conformance harness: analysis vs DES vs serving runtime.

PHAROS's safety story rests on three layers telling the same story
about one scenario:

1. the **analysis** (`core.rt`): Eq. 3 schedulability + busy-period
   response bounds — sound upper bounds;
2. the **DES** (`scheduler.des`): event-driven simulation on the same
   WCETs — tighter, still model-level;
3. the **runtime** (`pipeline.serve` on a `VirtualClock` driven by a
   `CostModel`): the executing control flow, real GEMM windows, virtual
   time charged per window from the same WCETs.

The harness runs one scenario through all three under one policy and
enforces the soundness ordering

    analytical bound  >=  DES response  >=  runtime response (~)

together with verdict agreement: analysis-schedulable implies
DES-schedulable implies the runtime accumulates no backlog. Every
failure is reported as a `Violation` naming the two layers that
disagree and by how much — this is the differential-oracle methodology
real-time frameworks (Cheddar, MAST) use to validate analyses against
simulation, applied across our stack.

Modeling notes that make the comparison apples-to-apples:

- All three layers read their WCETs from the same `CostModel`
  (`segment_table()` for analysis/DES, per-window costs for the
  runtime), so a disagreement is a *semantics* bug, never a unit skew.
- The virtual runtime preempts only at window boundaries, but that
  deferral inserts **no extra work** (the in-flight window completes
  useful work; accumulators stay resident, so there is no spill/reload
  xi). The layers therefore compare on *raw* WCETs — Eq. 3 on raw
  utilization is the sound verdict for every layer — and the window
  quantum enters as the DES-vs-runtime comparison tolerance instead of
  as Eq. 4 inflation. (`CostModel.segment_table`/`des_overheads` still
  expose the conservative inserted-overhead accounting for admission
  users that want Eq. 4 margins.)
- Traffic is **regulated** to the admission contract before the run
  (`regulate_trace`): the analytic layer's premise is a minimum
  inter-arrival of one provisioned period, which raw Poisson/MMPP
  traces violate with probability 1. Unregulated overload is the
  shedding layer's test surface, not conformance's.
- The DES >= runtime comparison carries a small schedule-noise
  tolerance (`tol_rel`, plus `quantum_slack` windows absolute): the
  runtime resolves simultaneous-event ties by stage iteration order
  and defers preemption to window boundaries, which can locally
  reorder two equal-priority jobs without breaking soundness.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.conformance.costmodel import CostModel
from repro.core.rt.response_time import end_to_end_bounds
from repro.core.rt.schedulability import srt_schedulable
from repro.core.rt.task import SegmentTable
from repro.scheduler.des import SimResult, simulate_taskset


#: the registry scenarios whose traffic honours its own contract
#: (overdrive == 1) — the conformance acceptance sweep
DEFAULT_SCENARIOS = (
    "steady_city",
    "rush_hour",
    "sensor_fusion",
    "copilot_decode",
)

POLICIES = ("fifo", "edf")


def regulate_trace(times, min_gap: float) -> list[float]:
    """Clamp a release trace to the admission contract: consecutive
    gaps of at least ``min_gap`` (a leaky-bucket regulator — arrivals
    are delayed, never dropped)."""
    out: list[float] = []
    prev = None
    for t in times:
        t = float(t) if prev is None else max(float(t), prev + min_gap)
        out.append(t)
        prev = t
    return out


@dataclass(frozen=True)
class ConformanceConfig:
    #: simulated horizon, in multiples of the longest tenant period
    horizon_periods: float = 40.0
    #: enforce the min-inter-arrival contract on stochastic traces
    regulate: bool = True
    #: DES-vs-runtime schedule-noise tolerance (relative on the DES max)
    tol_rel: float = 0.02
    #: plus this many worst-case windows of absolute slack
    quantum_slack: float = 2.0
    #: analysis-vs-DES tolerance (bounds are sound: float noise only)
    analysis_tol_rel: float = 1e-9
    #: runtime backlog divergence threshold (mirrors the DES's
    #: `SimConfig.backlog_limit` default)
    backlog_limit: int = 64
    #: surrogate-GEMM dimension cap for the virtual-server leg: timing
    #: comes from the CostModel, so the executed GEMMs only preserve
    #: window/stage structure (keeps LM-tenant chains host-runnable)
    max_dim: int = 512
    seed: int = 0


@dataclass(frozen=True)
class TaskConformance:
    """Per-task view of one conformance case."""

    task: str
    analytic_bound: float
    des_max: float
    des_jobs: int
    server_max: float
    server_jobs: int
    in_flight: int


@dataclass(frozen=True)
class Violation:
    """Two adjacent layers disagree; ``lhs`` should not exceed ``rhs``."""

    scenario: str
    policy: str
    task: str
    kind: str  # analytic_vs_des | des_vs_server | verdict_*
    lhs: float
    rhs: float
    detail: str

    @property
    def margin(self) -> float:
        return self.lhs - self.rhs

    def __str__(self) -> str:
        return (
            f"[{self.scenario}/{self.policy}] {self.kind} ({self.task}): "
            f"{self.lhs:.6g} > {self.rhs:.6g} — {self.detail}"
        )


@dataclass(frozen=True)
class CaseResult:
    scenario: str
    policy: str
    analysis_schedulable: bool
    des_schedulable: bool
    server_bounded: bool
    tasks: tuple[TaskConformance, ...]
    violations: tuple[Violation, ...]

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclass(frozen=True)
class ConformanceReport:
    """Sweep result: scenarios x policies, one `CaseResult` each."""

    cases: tuple[CaseResult, ...]

    @property
    def violations(self) -> tuple[Violation, ...]:
        return tuple(v for c in self.cases for v in c.violations)

    @property
    def ok(self) -> bool:
        return not self.violations

    def case(self, scenario: str, policy: str) -> CaseResult:
        for c in self.cases:
            if c.scenario == scenario and c.policy == policy:
                return c
        raise KeyError((scenario, policy))

    def summary(self) -> str:
        lines = [
            f"{'scenario':14s} {'policy':6s} {'A-sched':7s} "
            f"{'DES-sched':9s} {'srv-ok':6s} {'worst des/bound':15s} "
            f"{'worst srv/des':13s} viol"
        ]
        for c in self.cases:
            r_ad = max(
                (
                    t.des_max / t.analytic_bound
                    for t in c.tasks
                    if math.isfinite(t.analytic_bound)
                    and t.analytic_bound > 0
                ),
                default=float("nan"),
            )
            r_sd = max(
                (
                    t.server_max / t.des_max
                    for t in c.tasks
                    if t.des_max > 0 and t.server_jobs
                ),
                default=float("nan"),
            )
            lines.append(
                f"{c.scenario:14s} {c.policy:6s} "
                f"{str(c.analysis_schedulable):7s} "
                f"{str(c.des_schedulable):9s} "
                f"{str(c.server_bounded):6s} "
                f"{r_ad:15.4f} {r_sd:13.4f} {len(c.violations)}"
            )
        for v in self.violations:
            lines.append(f"  VIOLATION {v}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the virtual-server leg
# ---------------------------------------------------------------------------
def run_virtual_server(
    serve_tasks,
    n_stages: int,
    policy: str,
    cost_model: CostModel,
    traces,
    horizon: float,
):
    """Drive a cost-model `PharosServer` with explicit release traces on
    a `VirtualClock`, event-to-event (no quantization, no shedding — the
    conformance leg must see the raw runtime)."""
    from repro.pipeline.serve import PharosServer
    from repro.traffic.clock import VirtualClock

    clk = VirtualClock()
    srv = PharosServer(
        serve_tasks,
        n_stages,
        policy=policy,
        cost_model=cost_model,
        clock=clk.now,
        sleep=clk.sleep,
    )
    sched = sorted(
        (t, i) for i, trace in enumerate(traces) for t in trace
    )
    pos = 0
    while True:
        now = clk.now()
        while pos < len(sched) and sched[pos][0] <= now:
            srv.submit(sched[pos][1], sched[pos][0])
            pos += 1
        if now >= horizon:
            break
        srv.step()
        nxt = srv.next_completion_time()
        if pos < len(sched):
            nxt = min(nxt, sched[pos][0])
        nxt = min(nxt, horizon)
        now2 = clk.now()
        if nxt > now2:
            clk.advance(nxt - now2)
    return srv.finalize_report(horizon)


# ---------------------------------------------------------------------------
# one case: scenario x policy through all three layers
# ---------------------------------------------------------------------------
def run_case(
    built,
    policy: str,
    *,
    cfg: ConformanceConfig | None = None,
) -> CaseResult:
    """Run one `BuiltScenario` through analysis, DES and the virtual
    runtime under ``policy`` and compare."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    cfg = cfg or ConformanceConfig()
    scenario = built.scenario.name
    taskset = built.taskset
    preemptive = policy == "edf"

    serve_tasks, _requests, _arrivals = built.serve_bundle(
        period_scale=1.0, seed=cfg.seed, max_dim=cfg.max_dim
    )
    cm = CostModel.from_exec_model(
        built.design, list(built.workloads), serve_tasks
    )
    # zero-overhead WCET view: window-boundary deferral inserts no work
    # (see module docstring), so analysis and DES run on raw WCETs and
    # the quantum shows up only in the DES-vs-runtime tolerance
    table = SegmentTable(
        base=cm.segment_table().base,
        overhead=[0.0] * cm.n_stages,
    )
    periods = [t.period for t in taskset.tasks]
    horizon = cfg.horizon_periods * max(periods)

    traces = built.des_arrivals(horizon)
    if cfg.regulate:
        traces = [
            [t for t in regulate_trace(tr, p) if t < horizon]
            for tr, p in zip(traces, periods)
        ]

    # layer 1: analysis
    sched_a = srt_schedulable(table, taskset, preemptive)
    bounds = end_to_end_bounds(table, taskset, policy)

    # layer 2: DES on the same WCETs (immediate preemption, zero xi —
    # the runtime's deferred-preemption divergence from this ideal is
    # bounded by the window quantum and absorbed below)
    des: SimResult = simulate_taskset(
        table,
        taskset,
        policy,
        horizon=horizon,
        overheads=None,
        arrivals=traces,
    )

    # layer 3: the executing runtime in model-driven virtual time
    srv = run_virtual_server(
        serve_tasks, built.design.n_stages, policy, cm, traces, horizon
    )

    # ---- compare ----
    # per-task deferral allowance: at each visited stage the runtime
    # may hold an urgent job behind (at most) one in-flight window
    quanta = cm.stage_window_quantum()
    visit_quanta = [
        sum(q for q, b in zip(quanta, row) if b > 0.0)
        for row in table.base
    ]
    violations: list[Violation] = []
    task_rows: list[TaskConformance] = []
    for i, t in enumerate(taskset.tasks):
        r_des = des.response_times[i]
        r_srv = srv.response_times.get(t.name, [])
        des_max = max(r_des) if r_des else 0.0
        bound = bounds[i]
        if r_des and math.isfinite(bound):
            lhs = des_max
            if lhs > bound * (1.0 + cfg.analysis_tol_rel) + 1e-12:
                violations.append(
                    Violation(
                        scenario, policy, t.name, "analytic_vs_des",
                        lhs, bound,
                        "DES response exceeds the analytical bound",
                    )
                )
        # Same-task jobs complete in release order in both layers, so
        # index j names the *same job* on each side — compare job-wise.
        # A job only one side completed carries no ordering claim: the
        # other side not finishing it by the horizon means it was the
        # slower one on exactly that job (the runtime-slower direction
        # is still caught through in_flight/backlog below).
        allow = des_max * cfg.tol_rel + cfg.quantum_slack * visit_quanta[i]
        worst = None  # (excess, job index)
        for j, (rd, rs) in enumerate(zip(r_des, r_srv)):
            if rs > rd + allow and (worst is None or rs - rd > worst[0]):
                worst = (rs - rd, j)
        if worst is not None:
            j = worst[1]
            violations.append(
                Violation(
                    scenario, policy, t.name, "des_vs_server",
                    r_srv[j], r_des[j],
                    f"runtime response of job {j} exceeds the DES "
                    "beyond the window-quantization tolerance",
                )
            )
        task_rows.append(
            TaskConformance(
                task=t.name,
                analytic_bound=bound,
                des_max=des_max,
                des_jobs=len(r_des),
                server_max=max(r_srv) if r_srv else 0.0,
                server_jobs=len(r_srv),
                in_flight=srv.in_flight.get(t.name, 0),
            )
        )

    server_bounded = srv.jobs_completed > 0 and all(
        row.in_flight <= cfg.backlog_limit for row in task_rows
    )
    if sched_a and not des.schedulable:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_analysis_des",
                1.0, 0.0,
                "analysis says schedulable but the DES detected "
                f"divergence (overload={des.overload_detected}, "
                f"growth={des.growth_detected})",
            )
        )
    if des.schedulable and not server_bounded:
        violations.append(
            Violation(
                scenario, policy, "*", "verdict_des_server",
                float(max((r.in_flight for r in task_rows), default=0)),
                float(cfg.backlog_limit),
                "DES says schedulable but the runtime accumulated "
                "backlog",
            )
        )
    return CaseResult(
        scenario=scenario,
        policy=policy,
        analysis_schedulable=sched_a,
        des_schedulable=des.schedulable,
        server_bounded=server_bounded,
        tasks=tuple(task_rows),
        violations=tuple(violations),
    )


def run_conformance(
    scenarios=DEFAULT_SCENARIOS,
    policies=POLICIES,
    *,
    platform=None,
    cfg: ConformanceConfig | None = None,
    max_m: int = 3,
    beam_width: int = 4,
    prebuilt: dict | None = None,
) -> ConformanceReport:
    """Sweep ``scenarios x policies`` and collect every violation.

    Each scenario is resolved once (`traffic.scenarios.build` runs the
    DSE) and reused across policies; ``prebuilt`` maps scenario names
    to already-resolved `BuiltScenario`s to skip their DSE entirely.
    """
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import build, get_scenario

    platform = platform or paper_platform(16)
    cfg = cfg or ConformanceConfig()
    cases = []
    for name in scenarios:
        built = (prebuilt or {}).get(name) or build(
            get_scenario(name),
            platform,
            max_m=max_m,
            beam_width=beam_width,
            seed=cfg.seed,
        )
        for policy in policies:
            cases.append(run_case(built, policy, cfg=cfg))
    return ConformanceReport(cases=tuple(cases))
