"""Cross-layer conformance: one scenario, three layers, one verdict.

- `costmodel` — `CostModel`: per-(task, layer) virtual WCETs from the
  exec model or from wall-clock calibration probes; drives the serving
  runtime's virtual time and exports the same WCETs to the analysis
  (`segment_table`) and the DES (`des_overheads`).
- `harness` — `run_conformance` / `run_case`: differential testing of
  `core.rt` analysis vs `scheduler.des` vs a virtual-clock
  `PharosServer`, enforcing ``analytic bound >= DES >= runtime`` and
  verdict agreement, reporting every `Violation` with its margin.
"""
from repro.conformance.costmodel import CostModel
from repro.conformance.harness import (
    DEFAULT_SCENARIOS,
    POLICIES,
    CaseResult,
    ConformanceConfig,
    ConformanceReport,
    TaskConformance,
    Violation,
    regulate_trace,
    run_case,
    run_conformance,
    run_virtual_server,
)

__all__ = [
    "CostModel",
    "DEFAULT_SCENARIOS",
    "POLICIES",
    "CaseResult",
    "ConformanceConfig",
    "ConformanceReport",
    "TaskConformance",
    "Violation",
    "regulate_trace",
    "run_case",
    "run_conformance",
    "run_virtual_server",
]
