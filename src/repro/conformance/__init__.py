"""Cross-layer conformance: one scenario, three layers, one verdict.

- `costmodel` — `CostModel`: per-(task, layer) virtual WCETs from the
  exec model or from wall-clock calibration probes; drives the serving
  runtime's virtual time and exports the same WCETs to the analysis
  (`segment_table`), the DES's limited-preemption chunk schedules
  (`chunk_schedule`) and its overhead accounting (`des_overheads`).
- `harness` — `run_conformance` / `run_case`: differential testing of
  `core.rt` analysis vs the window-boundary `scheduler.des` vs a
  virtual-clock `PharosServer`, enforcing ``analytic bound >= DES >=
  runtime`` and verdict agreement, reporting every `Violation` with
  its margin; `run_sharded_case` (every shard of a placed tenant set
  held to the full contract + bit-exact per-shard admission);
  `run_shedding_case` (overdriven traffic with identical shedding
  armed in DES and runtime, release-matched surviving jobs);
  `run_mode_switch_case` (mixed-criticality overload: twin
  `ModeController`s in DES and runtime must agree on the Eq. 3
  re-proved HI survivor set and lose zero HI deadlines across every
  transition);
  `run_migration_case` (live tenant re-homing on the shared-clock
  co-simulated elastic gateway, DES replayed on the realized release
  stamps: exact survivor-set agreement, zero deadline violations
  during any handover, proof-before-commit membership);
  `run_dse_case` (every DSE-claimed-feasible design held to the three
  layers, and the best design provisioned into a `ShardedGateway`
  that must serve the scenario's traffic violation-free); plus
  `run_wallclock_case`, the calibrated real-clock leg (gateway on
  `WallClock` vs the measured `CostModel`, optionally with
  calibrated-admission mode: tenancy admitted against measured WCETs).

See ``docs/conformance.md`` for the full contract and tolerance model.
"""
from repro.conformance.costmodel import CostModel
from repro.conformance.harness import (
    DEFAULT_SCENARIOS,
    POLICIES,
    PR2_QUANTUM_SLACK,
    PR2_TOL_REL,
    PR3_QUANTUM_SLACK,
    CaseResult,
    ConformanceConfig,
    ConformanceReport,
    DSECaseResult,
    MigrationCaseResult,
    MigrationTenantRow,
    ModeSwitchCaseResult,
    ModeSwitchTaskRow,
    ShardedCaseResult,
    SheddingCaseResult,
    SheddingTaskRow,
    TaskConformance,
    Violation,
    WallClockCase,
    WallClockTask,
    regulate_trace,
    run_case,
    run_conformance,
    run_dse_case,
    run_migration_case,
    run_mode_switch_case,
    run_sharded_case,
    run_shedding_case,
    run_virtual_server,
    run_wallclock_case,
)

__all__ = [
    "CostModel",
    "DEFAULT_SCENARIOS",
    "POLICIES",
    "PR2_QUANTUM_SLACK",
    "PR2_TOL_REL",
    "PR3_QUANTUM_SLACK",
    "CaseResult",
    "ConformanceConfig",
    "ConformanceReport",
    "DSECaseResult",
    "MigrationCaseResult",
    "MigrationTenantRow",
    "ModeSwitchCaseResult",
    "ModeSwitchTaskRow",
    "ShardedCaseResult",
    "SheddingCaseResult",
    "SheddingTaskRow",
    "TaskConformance",
    "Violation",
    "WallClockCase",
    "WallClockTask",
    "regulate_trace",
    "run_case",
    "run_conformance",
    "run_dse_case",
    "run_migration_case",
    "run_mode_switch_case",
    "run_sharded_case",
    "run_shedding_case",
    "run_virtual_server",
    "run_wallclock_case",
]
