"""Trace-level differential diagnosis: the first event where two
layers' schedules part ways.

The conformance harness compares end-of-run response aggregates under a
tolerance; when the tolerance trips, the aggregate says *that* the
layers disagree but not *where*. `trace_diff` aligns two event streams
(canonically DES vs runtime) job-by-job — the join key is ``(task,
release stamp, kind)``, the same exact-float release identity
`run_shedding_case` matches jobs with — and reports the **first**
divergent event in the reference stream's order:

- ``missing_in_b`` / ``missing_in_a`` — a job event one layer emitted
  and the other never did (a shed/lost/unfinished job);
- ``time_skew``   — both emitted it, but the timestamps differ by more
  than the allowance (scalar, or per-task dict — the harness passes
  the case's own per-task conformance allowance so "identical" and
  "conformance-clean" mean the same thing).

Only job-scoped, order-pinned kinds participate by default
(``release`` and ``complete``): dispatch/preemption events are
schedule *mechanism*, timing of which legitimately differs at
simultaneous-event tie-breaks without any response-visible effect.
Pass ``kinds=...`` to widen the comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

#: kinds compared by default: job-scoped and order-pinned across layers
DEFAULT_DIFF_KINDS = ("release", "complete")


@dataclass(frozen=True)
class Divergence:
    """The first point of disagreement between two streams."""

    reason: str  # "missing_in_a" | "missing_in_b" | "time_skew"
    task: str
    kind: str
    release: float | None
    t_a: float | None
    t_b: float | None
    allow: float

    def __str__(self) -> str:
        where = f"{self.kind}({self.task}, release={self.release:.6g})" \
            if self.release is not None else f"{self.kind}({self.task})"
        if self.reason == "time_skew":
            return (
                f"first divergence: {where} at {self.t_a:.6g} vs "
                f"{self.t_b:.6g} (|dt|={abs(self.t_a - self.t_b):.3g} "
                f"> allow={self.allow:.3g})"
            )
        missing = "b" if self.reason == "missing_in_b" else "a"
        t = self.t_a if missing == "b" else self.t_b
        return f"first divergence: {where} at {t:.6g} missing in '{missing}'"


@dataclass(frozen=True)
class TraceDiff:
    """`trace_diff` result; ``identical`` means every compared event
    matched within the allowance."""

    identical: bool
    compared: int
    names: tuple[str, str]
    divergence: Divergence | None = None
    #: worst matched-timestamp skew observed (diagnostic, even when
    #: identical)
    max_skew: float = 0.0

    def summary(self) -> str:
        if self.identical:
            return (
                f"identical ({self.compared} events matched, "
                f"max skew {self.max_skew:.3g})"
            )
        return f"{self.divergence} [{self.names[0]} vs {self.names[1]}]"


def _key(e) -> tuple:
    return (e.task, e.release, e.kind)


def trace_diff(
    events_a,
    events_b,
    *,
    kinds=DEFAULT_DIFF_KINDS,
    time_tol=0.0,
    names: tuple[str, str] = ("des", "runtime"),
) -> TraceDiff:
    """Align two schedule-event streams and report the first divergent
    event (see module docstring). ``events_*`` are `TraceRecorder`s or
    event lists; ``time_tol`` is a scalar allowance or a per-task dict
    (missing tasks fall back to 0)."""
    kinds = set(kinds)
    a = [e for e in getattr(events_a, "events", events_a) if e.kind in kinds]
    b = [e for e in getattr(events_b, "events", events_b) if e.kind in kinds]

    def allow_for(task: str) -> float:
        if isinstance(time_tol, dict):
            return float(time_tol.get(task, 0.0))
        return float(time_tol)

    b_by_key: dict[tuple, list] = {}
    for e in b:
        b_by_key.setdefault(_key(e), []).append(e)

    compared = 0
    max_skew = 0.0
    first: Divergence | None = None
    matched_b: set[int] = set()
    for e in a:
        peers = b_by_key.get(_key(e))
        if not peers:
            first = Divergence(
                "missing_in_b", e.task, e.kind, e.release,
                e.t, None, allow_for(e.task),
            )
            break
        peer = peers.pop(0)
        # rtlint: disable=determinism -- pure identity membership (which
        # exact event objects were matched); never ordered or persisted
        matched_b.add(id(peer))
        compared += 1
        skew = abs(e.t - peer.t)
        max_skew = max(max_skew, skew)
        allow = allow_for(e.task)
        if skew > allow + 1e-12:
            first = Divergence(
                "time_skew", e.task, e.kind, e.release,
                e.t, peer.t, allow,
            )
            break
    if first is None:
        for e in b:
            # rtlint: disable=determinism -- identity membership test
            # against matched_b above; see rationale there
            if id(e) not in matched_b:
                first = Divergence(
                    "missing_in_a", e.task, e.kind, e.release,
                    None, e.t, allow_for(e.task),
                )
                break
    if first is None and math.isnan(max_skew):
        max_skew = 0.0
    return TraceDiff(
        identical=first is None,
        compared=compared,
        names=tuple(names),
        divergence=first,
        max_skew=max_skew,
    )
