"""Observability layer: cross-layer schedule tracing, deadline
metrics, Perfetto export and trace-level differential diagnosis.

- `TraceRecorder` / `TraceEvent` — one zero-overhead-when-disabled
  event API shared by the DES, the serving runtime and the gateway
  (`repro.obs.trace`).
- `MetricsRegistry` (+ `percentile`) — the deadline-compliance metrics
  catalog rolled up from a trace (`repro.obs.metrics`).
- `to_chrome_trace` / `write_chrome_trace` — Chrome-trace-event JSON,
  loadable in Perfetto / chrome://tracing.
- `trace_diff` — first-divergence diagnosis between two layers' event
  streams (`repro.obs.diff`), wired into the conformance harness.

See docs/observability.md for the event schema and metric catalog.
"""
from repro.obs.diff import (
    DEFAULT_DIFF_KINDS,
    Divergence,
    TraceDiff,
    trace_diff,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
    percentile_summary,
)
from repro.obs.trace import (
    EVENT_KINDS,
    LAYERS,
    TraceEvent,
    TraceRecorder,
    to_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "DEFAULT_DIFF_KINDS",
    "Divergence",
    "TraceDiff",
    "trace_diff",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "percentile_summary",
    "EVENT_KINDS",
    "LAYERS",
    "TraceEvent",
    "TraceRecorder",
    "to_chrome_trace",
    "write_chrome_trace",
]
