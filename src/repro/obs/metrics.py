"""Deadline-compliance metrics: counters, gauges, histograms and the
registry that rolls a schedule trace up into them.

The catalog `MetricsRegistry.from_trace` populates (names are
``<metric>/<label>``):

- counters  — ``releases/<task>``, ``completions/<task>``,
  ``deadline_misses/<task>``, ``shed/<task>``, ``rate_limited/<task>``,
  ``preemptions/stage<k>``; ``xi_charged/stage<k>`` accumulates the
  Eq. 5 store+load seconds charged on that stage.
- histograms — ``response/<task>`` and ``tardiness/<task>`` (seconds;
  tardiness is ``max(0, completion - absolute deadline)``), exposing
  p50/p95/p99 via `Histogram.percentile`.
- gauges    — ``backlog/<task>`` (in-flight at trace end: releases
  minus completions), ``xi_overhead_fraction`` (total xi seconds over
  the trace makespan), and — set by the caller from the analysis side,
  not derivable from a trace — ``eq3_slack/stage<k>``
  (`set_eq3_slacks`, the per-stage Eq. 3 slack ``1 - u^k``).

Percentiles use the nearest-rank method (`percentile`) so results are
always actual observed values; `SimResult.response_percentiles` /
`ServerReport.response_percentiles` and `benchmarks/shard_bench.py`
share this one implementation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (``q`` in [0, 100]) of ``values``.

    Returns ``nan`` for an empty sequence. The nearest-rank method
    always returns an observed value — no interpolation — which keeps
    tail percentiles honest on the small per-task samples a bounded
    horizon produces.
    """
    vals = sorted(values)
    if not vals:
        return math.nan
    if not 0.0 <= q <= 100.0:
        raise ValueError("percentile q must be in [0, 100]")
    rank = max(1, math.ceil(q / 100.0 * len(vals)))
    return vals[rank - 1]


def percentile_summary(values, qs=(50, 95, 99)) -> dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` via `percentile`."""
    return {f"p{q:g}": percentile(values, q) for q in qs}


@dataclass
class Counter:
    """Monotone accumulator (float-valued: xi seconds count too)."""

    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


@dataclass
class Gauge:
    """Last-write-wins instantaneous value."""

    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


@dataclass
class Histogram:
    """Raw-sample histogram with nearest-rank percentiles."""

    samples: list[float] = field(default_factory=list)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return sum(self.samples)

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self, qs=(50, 95, 99)) -> dict:
        out = {"count": self.count, "sum": self.sum}
        if self.samples:
            out["max"] = max(self.samples)
        out.update(percentile_summary(self.samples, qs))
        return out


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self.histograms.setdefault(name, Histogram())

    def set_eq3_slacks(self, slacks) -> None:
        """Publish per-stage Eq. 3 slack gauges (``eq3_slack/stage<k>``)
        from the analysis side (`repro.core.rt.stage_slacks`) — the one
        catalog entry a trace cannot produce on its own."""
        for k, s in enumerate(slacks):
            self.gauge(f"eq3_slack/stage{k}").set(s)

    def snapshot(self) -> dict:
        """JSON-able dump: counters/gauges flat, histograms summarized
        (count, sum, max, p50/p95/p99)."""
        return {
            "counters": {
                k: c.value for k, c in sorted(self.counters.items())
            },
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self.histograms.items())
            },
        }

    # ------------------------------------------------------------------
    @classmethod
    def from_trace(cls, events) -> "MetricsRegistry":
        """Roll a `TraceRecorder` (or event list) up into the standard
        deadline-compliance catalog (module docstring). Multi-layer
        traces are fine — pre-filter with `TraceRecorder.stream` when a
        single layer's view is wanted."""
        events = list(getattr(events, "events", events))
        reg = cls()
        t_min = math.inf
        t_max = -math.inf
        xi_total = 0.0
        for e in events:
            t_min = min(t_min, e.t)
            t_max = max(t_max, e.t)
            if e.kind == "release":
                reg.counter(f"releases/{e.task}").inc()
            elif e.kind == "complete":
                reg.counter(f"completions/{e.task}").inc()
                # response/tardiness derive from the event itself: the
                # emitters carry only {"deadline": ...} (hot-path economy)
                if e.release is not None:
                    reg.histogram(f"response/{e.task}").observe(
                        e.t - e.release
                    )
                dl = e.get("deadline")
                if dl is not None and dl != math.inf:
                    reg.histogram(f"tardiness/{e.task}").observe(
                        max(0.0, e.t - dl)
                    )
                    if e.t > dl:
                        # completed-job misses are derived, not emitted
                        # (see repro.obs.trace event vocabulary)
                        reg.counter(f"deadline_misses/{e.task}").inc()
            elif e.kind == "deadline_miss":
                # explicit events cover only in-flight horizon-end
                # misses, so this never double-counts the derived ones
                reg.counter(f"deadline_misses/{e.task}").inc()
            elif e.kind == "shed":
                reg.counter(f"shed/{e.task}").inc()
            elif e.kind == "rate_limited":
                reg.counter(f"rate_limited/{e.task}").inc()
            elif e.kind == "preempt_store":
                reg.counter(f"preemptions/stage{e.stage}").inc()
                xi_total += e.get("xi", 0.0)
            elif e.kind == "preempt_load":
                xi_total += e.get("xi", 0.0)
        for name, c in sorted(reg.counters.items()):
            if name.startswith("releases/"):
                task = name.split("/", 1)[1]
                done = reg.counters.get(f"completions/{task}")
                reg.gauge(f"backlog/{task}").set(
                    c.value - (done.value if done else 0.0)
                )
        if xi_total > 0.0:
            for e in events:
                if e.kind == "preempt_store":
                    reg.counter(f"xi_charged/stage{e.stage}").inc(
                        e.get("xi", 0.0)
                    )
                elif e.kind == "preempt_load":
                    reg.counter(f"xi_charged/stage{e.stage}").inc(
                        e.get("xi", 0.0)
                    )
        makespan = (t_max - t_min) if t_max > t_min else 0.0
        reg.gauge("xi_overhead_fraction").set(
            xi_total / makespan if makespan > 0.0 else 0.0
        )
        return reg
