"""Cross-layer schedule tracing: one event stream for DES, runtime and
gateway.

PHAROS's conformance story compares three layers of the same schedule
(analysis >= DES >= runtime); until now the comparison happened on
end-of-run aggregates. `TraceRecorder` captures the *schedule itself*
as structured events so a disagreement can be pinpointed to the first
divergent event (`repro.obs.diff.trace_diff`), rendered as a per-stage
timeline (`to_chrome_trace`, loadable in Perfetto / chrome://tracing),
or rolled up into deadline-compliance metrics
(`repro.obs.metrics.MetricsRegistry.from_trace`).

Event vocabulary (``TraceEvent.kind``):

- ``release``        — a job entered the system (DES release, runtime
                       ``PharosServer.submit``, gateway submit path).
- ``dispatch``       — a stage server started (or resumed) serving a
                       job; ``attrs["resumed"]`` marks a
                       post-preemption resume.
- ``preempt_store``  — a running job was preempted at a window
                       boundary; ``attrs["xi"]`` is the store-side
                       charge serialized before the preemptor starts
                       (Eq. 5 ``e_store``; the idealized instant model
                       charges ``e_tile + e_store``).
- ``preempt_load``   — the matching resume-side charge of the same
                       preemption, ``attrs["xi"]`` = ``e_load``
                       (instant model: ``e_load``). Emitted at the
                       preemption instant — the charge is *owed* from
                       that point and paid when the job resumes.
- ``segment_end``    — a job finished a non-final segment and forwards
                       to its next stage (closes the stage span).
- ``complete``       — a job finished its last segment;
                       ``attrs["deadline"]`` carries the absolute
                       deadline so response (``t - release``) and
                       tardiness (``max(0, t - deadline)``) derive at
                       read time with no hot-path arithmetic.
- ``deadline_miss``  — an *in-flight* job is past its finite absolute
                       deadline at horizon/run end
                       (``attrs["in_flight"]``). Completed-job misses
                       are **not** separately emitted: they derive from
                       ``complete`` (``t > attrs["deadline"]``), and
                       `MetricsRegistry.from_trace` / `to_chrome_trace`
                       perform that derivation — one fewer hot-path
                       emission per late job.
- ``shed``           — a release dropped by the shedding policy.
- ``rate_limited``   — a release refused by a dry token bucket.
- ``admit``/``reject`` — tenancy admission decisions (gateway).
- ``place``          — tenant -> shard placement (sharded gateway).
- ``mode_switch``    — a committed mixed-criticality mode transition
                       (`repro.traffic.modes.ModeController`);
                       ``attrs["mode"]`` is the mode entered,
                       ``attrs["survivors"]`` the re-proved guarantee
                       set, ``attrs["schedulable"]`` the Eq. 3
                       re-proof verdict that gated the commit.
- ``migrate_start``  — a live tenant migration began draining
                       (`repro.traffic.migration.MigrationController`):
                       new releases stop on the donor shard (``shard``)
                       while in-flight jobs complete;
                       ``attrs["held"]`` counts the withheld releases.
- ``migrate_commit`` — the drained tenant passed the target shard's
                       Eq. 3 admit and was re-homed; ``shard`` is the
                       target, ``attrs["donor"]`` the shard it left,
                       ``attrs["held"]`` the re-stamped releases
                       injected on the target.
- ``migrate_abort``  — no target could prove the tenant's contract;
                       the tenant was restored onto its donor shard
                       (``shard``) with its held releases re-injected —
                       ``attrs["reason"]`` says why.

Identity and ordering: events carry the emitting ``layer`` ("des",
"runtime" or "gateway"), the tenant/task ``task`` name, the job's
``release`` stamp (the cross-layer join key — both model layers release
the identical trace floats), the ``stage`` index and the ``shard``
(``-1`` unsharded). ``seq`` is the recorder-global emission order;
within one ``(layer, shard)`` stream timestamps are non-decreasing and
mirror the DES heap's ``(t, kind, prio, seq)`` tie-break: at one
instant all releases are emitted before any completion (the property
tests pin this).

Zero overhead when disabled: instrumented layers resolve their trace
handle once per run — ``tr = trace if trace is not None and
trace.enabled else None`` — and guard every emission with ``if tr is
not None``. A disabled recorder is never even called, so tracing off
means literally zero events and no per-event work (asserted by
``benchmarks/obs_bench.py`` in CI).

The module is dependency-free (stdlib only): every layer can accept a
recorder without import cycles, and the DES keeps treating it as an
opaque duck-typed handle.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

#: every event kind a recorder may carry, in no particular order
EVENT_KINDS = (
    "release",
    "dispatch",
    "preempt_store",
    "preempt_load",
    "segment_end",
    "complete",
    "deadline_miss",
    "shed",
    "rate_limited",
    "admit",
    "reject",
    "place",
    "mode_switch",
    "migrate_start",
    "migrate_commit",
    "migrate_abort",
)

#: layer tags of the three instrumented layers
LAYERS = ("des", "runtime", "gateway")

#: the scalar-payload key per event kind for compact `TraceRecorder.sink`
#: rows — a bare float in the row's payload slot means this attribute
_VAL_KEY = {
    "complete": "deadline",
    "preempt_store": "xi",
    "preempt_load": "xi",
}


@dataclass(frozen=True, slots=True)
class TraceEvent:
    """One structured schedule event (see module docstring)."""

    seq: int
    t: float
    layer: str
    kind: str
    task: str = ""
    stage: int = -1
    shard: int = -1
    #: the job's release stamp — the cross-layer join key; None for
    #: events that are not job-scoped (admit/reject/place)
    release: float | None = None
    attrs: dict | None = None

    def get(self, key: str, default=None):
        """Attribute lookup that tolerates a missing attrs dict."""
        if self.attrs is None:
            return default
        return self.attrs.get(key, default)


class TraceRecorder:
    """Append-only event sink shared by all instrumented layers.

    ``enabled`` is resolved *once* by each instrumented run (the layers
    cache ``trace if trace.enabled else None``), so toggling it
    mid-run has no effect on a run already started — construct one
    recorder per traced run.

    ``annotate(**kv)`` sets sticky attributes merged into every
    subsequent event's ``attrs`` — e.g. the wall-clock conformance
    bench tags each retry attempt with ``annotate(attempt=n)`` so
    host-throttle retries stay visible in the trace instead of
    overwriting each other.

    The hot path appends plain tuples (a `TraceEvent` per emission
    would triple the DES's per-decision cost and blow the <5% budget
    ``benchmarks/obs_bench.py`` enforces); `events` materializes the
    `TraceEvent` view lazily on first read.
    """

    __slots__ = ("enabled", "_buf", "_events", "_sticky", "_hot_tag")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        # mixed row forms, emission order: 8-tuples from `emit` (full
        # TraceEvent field order sans seq) and 5/6-tuples from a
        # `sink` handle (compact hot form, expanded lazily by `events`)
        self._buf: list[tuple] = []
        self._events: list[TraceEvent] = []  # lazy materialized view
        self._sticky: dict = {}
        self._hot_tag: tuple[str, int] | None = None  # sink (layer, shard)

    def emit(
        self,
        kind: str,
        t: float,
        layer: str,
        task: str = "",
        stage: int = -1,
        shard: int = -1,
        release: float | None = None,
        attrs: dict | None = None,
    ) -> None:
        if not self.enabled:
            return
        sticky = self._sticky
        if sticky:
            attrs = {**sticky, **attrs} if attrs else dict(sticky)
        # seq is implicit: the row's buffer index (events materializes it)
        self._buf.append(
            (t, layer, kind, task, stage, shard, release, attrs)
        )

    def sink(self, layer: str = "des", shard: int = -1):
        """Lowest-overhead emission handle for hot loops (the DES).

        Returns ``None`` when disabled; otherwise a callable taking one
        compact row ``(t, kind, task, stage, release[, payload])``: the
        constant ``layer``/``shard`` are curried here and re-attached
        when `events` materializes, and the optional sixth element is
        either an attrs dict or — for the kinds in ``_VAL_KEY`` — the
        bare scalar attribute (``complete`` -> ``deadline``,
        ``preempt_*`` -> ``xi``), so the hot path never builds a dict.
        With no sticky annotations armed the handle *is* the buffer's
        bound ``append``: a hot loop pays one call and one small tuple
        per event. Like ``enabled``, the sticky set is resolved at
        ``sink()`` time: annotations made after a run resolved its sink
        do not retroactively apply to that run (consistent with the
        resolve-once contract in the module docstring).

        One recorder supports one sink tag: a second ``sink()`` with a
        different ``(layer, shard)`` raises — hand each hot layer its
        own recorder (the conformance harness already does).
        """
        if not self.enabled:
            return None
        tag = (layer, shard)
        if self._hot_tag is None:
            self._hot_tag = tag
        elif self._hot_tag != tag:
            raise ValueError(
                f"recorder already has sink tag {self._hot_tag}, "
                f"cannot also serve {tag}"
            )
        if not self._sticky:
            return self._buf.append
        sticky = dict(self._sticky)
        buf_append = self._buf.append

        def append(row):
            if len(row) == 6:
                v = row[5]
                attrs = (
                    {**sticky, **v}
                    if isinstance(v, dict)
                    else {**sticky, _VAL_KEY[row[1]]: v}
                )
            else:
                attrs = dict(sticky)
            buf_append(row[:5] + (attrs,))

        return append

    def annotate(self, **kv) -> None:
        """Merge sticky attributes into every future event."""
        self._sticky.update(kv)

    def clear_annotations(self) -> None:
        self._sticky.clear()

    # -- read side -----------------------------------------------------
    @property
    def events(self) -> list[TraceEvent]:
        """The emitted events as `TraceEvent` objects, emission order."""
        ev, buf = self._events, self._buf
        if len(ev) != len(buf):
            layer, shard = self._hot_tag or ("des", -1)
            for i in range(len(ev), len(buf)):
                row = buf[i]
                if len(row) == 8:  # full `emit` row
                    ev.append(TraceEvent(i, *row))
                    continue
                attrs = None
                if len(row) == 6:
                    v = row[5]
                    attrs = (
                        v
                        if isinstance(v, dict)
                        else {_VAL_KEY[row[1]]: v}
                    )
                ev.append(
                    TraceEvent(
                        i, row[0], layer, row[1], row[2], row[3],
                        shard, row[4], attrs,
                    )
                )
        return ev

    def stream(
        self,
        *,
        layer: str | None = None,
        kind: str | None = None,
        task: str | None = None,
        shard: int | None = None,
    ) -> list[TraceEvent]:
        """Events filtered by layer/kind/task/shard, emission order."""
        return [
            e
            for e in self.events
            if (layer is None or e.layer == layer)
            and (kind is None or e.kind == kind)
            and (task is None or e.task == task)
            and (shard is None or e.shard == shard)
        ]

    def counts(self) -> dict[str, int]:
        """Event count per kind (only kinds that occurred)."""
        out: dict[str, int] = {}
        for row in self._buf:
            kind = row[2] if len(row) == 8 else row[1]
            out[kind] = out.get(kind, 0) + 1
        return out


# ---------------------------------------------------------------------------
# Chrome trace event (Perfetto-loadable) export
# ---------------------------------------------------------------------------
def _track(e: TraceEvent) -> tuple:
    """(pid-ish, tid-ish) grouping of one event's timeline row."""
    return (e.layer, e.shard, e.stage)


def to_chrome_trace(
    events, *, time_scale: float = 1e6
) -> dict:
    """Render a trace as Chrome trace-event JSON (the ``traceEvents``
    dict form chrome://tracing and Perfetto load directly).

    Layout: one process per ``(layer, shard)``, one thread per stage.
    Stage occupancy becomes complete ("X") duration events — a span
    opens at ``dispatch`` and closes at the next ``preempt_store``,
    ``segment_end``, ``complete`` or ``dispatch`` on the same stage —
    so preemption windows are visible as span boundaries with the xi
    charges attached. Releases, sheds, misses and the other
    stage-less events render as instant ("i") marks.

    ``time_scale`` converts model seconds to the format's microsecond
    timestamps (default: 1 model second -> 1 trace second).
    """
    events = list(getattr(events, "events", events))
    out: list[dict] = []
    pids: dict[tuple, int] = {}

    def pid_of(layer: str, shard: int) -> int:
        key = (layer, shard)
        if key not in pids:
            pids[key] = len(pids) + 1
            name = layer if shard < 0 else f"{layer}/shard{shard}"
            out.append(
                {
                    "ph": "M",
                    "pid": pids[key],
                    "name": "process_name",
                    "args": {"name": name},
                }
            )
        return pids[key]

    # open span per (layer, shard, stage): [start_t, task, release, attrs]
    open_span: dict[tuple, list] = {}
    last_t = 0.0

    def close_span(track: tuple, t: float) -> None:
        span = open_span.pop(track, None)
        if span is None:
            return
        t0, task, release, attrs = span
        layer, shard, stage = track
        out.append(
            {
                "ph": "X",
                "pid": pid_of(layer, shard),
                "tid": stage,
                "ts": t0 * time_scale,
                "dur": max(0.0, (t - t0)) * time_scale,
                "name": task,
                "cat": "occupancy",
                "args": {"release": release, **(attrs or {})},
            }
        )

    for e in sorted(events, key=lambda e: (e.t, e.seq)):
        last_t = max(last_t, e.t)
        track = _track(e)
        if e.kind == "dispatch":
            close_span(track, e.t)
            open_span[track] = [e.t, e.task, e.release, e.attrs]
            continue
        if e.kind in ("preempt_store", "segment_end", "complete"):
            close_span(track, e.t)
        out.append(
            {
                "ph": "i",
                "pid": pid_of(e.layer, e.shard),
                "tid": e.stage if e.stage >= 0 else 0,
                "ts": e.t * time_scale,
                "name": f"{e.kind}:{e.task}" if e.task else e.kind,
                "cat": e.kind,
                "s": "t",
                "args": {"release": e.release, **(e.attrs or {})},
            }
        )
        if e.kind == "complete":
            # completed-job misses are derived, not emitted (module
            # docstring) — synthesize the instant so timelines still
            # flag them
            dl = e.get("deadline")
            if dl is not None and e.t > dl:
                out.append(
                    {
                        "ph": "i",
                        "pid": pid_of(e.layer, e.shard),
                        "tid": e.stage if e.stage >= 0 else 0,
                        "ts": e.t * time_scale,
                        "name": f"deadline_miss:{e.task}",
                        "cat": "deadline_miss",
                        "s": "t",
                        "args": {
                            "release": e.release,
                            "tardiness": e.t - dl,
                        },
                    }
                )
    for track in sorted(open_span):
        close_span(track, last_t)  # still-running at trace end
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events, path, *, time_scale: float = 1e6) -> dict:
    """`to_chrome_trace` straight to a JSON file; returns the document."""
    doc = to_chrome_trace(events, time_scale=time_scale)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
