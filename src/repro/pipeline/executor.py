"""SPMD pipeline executor: the PHAROS chained topology on a TPU mesh.

The paper's spatial architecture — M accelerators, each owning a
consecutive layer segment, jobs streaming through FIFO links — maps to
a ``stage`` mesh axis under `shard_map`:

- stage k holds repeats ``[k*R/M, (k+1)*R/M)`` of the block stack
  (parameters sharded on their leading repeats axis);
- activations advance stage->stage with ``lax.ppermute`` (the HLS
  stream of paper Fig. 2);
- microbatches play the role of jobs: after the M-1-tick fill phase,
  every stage computes a different microbatch each tick — the paper's
  pipelined execution model (one job per accelerator, §3.3).

GPipe-style schedule: ``n_ticks = n_micro + M - 1``; stage M-1's output
at tick t is microbatch ``t - (M-1)``. The executor covers the backbone
(B, S, d) -> (B, S, d); embed/head run outside (they belong to the
first/last stage in a deployment and are not part of the repeat stack).

Equal segments are required (`n_repeats % n_stages == 0`) — the
asymmetric-resource designs from the DSE run through the host runtime
(`pipeline.serve`) and the DES; see DESIGN.md §2.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.lm import NO_POLICY

# --- version compatibility: shard_map moved to jax.*, check_rep was
# renamed check_vma, and set_mesh/use_mesh only exist on newer JAX ---
if hasattr(jax, "shard_map"):

    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )

else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def _shard_map(f, mesh, in_specs, out_specs):
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


def use_mesh(mesh):
    """Context manager activating ``mesh`` across JAX versions."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # legacy: Mesh itself is a context manager


def make_stage_mesh(n_stages: int):
    return jax.make_mesh((n_stages,), ("stage",))


def _segment_apply(cfg: ArchConfig, params_seg, x, positions):
    """Run this stage's repeats (a scan over its slice of the stack)."""
    pattern = cfg.pattern()

    def body(x, rep):
        for j, kind in enumerate(pattern):
            x = lm._apply_block(
                kind, rep[j]["mixer"], rep[j]["ffn"], x, cfg, positions,
                NO_POLICY,
            )
        return x, None

    x, _ = jax.lax.scan(body, x, params_seg)
    return x


def pipeline_backbone(cfg: ArchConfig, mesh, n_stages: int):
    """Build ``fn(stacked_blocks, microbatches) -> outputs``.

    ``stacked_blocks``: block params with leading repeats axis R,
    sharded R over ``stage`` (R % n_stages == 0).
    ``microbatches``: (n_micro, B_mb, S, d) embedded inputs.
    Returns (n_micro, B_mb, S, d) — the backbone output per microbatch.
    """
    if cfg.n_repeats % n_stages:
        raise ValueError(
            f"n_repeats={cfg.n_repeats} not divisible by stages={n_stages}"
        )

    def staged(blocks_local, micro):
        # blocks_local: repeats slice (R/M, ...); micro: (n_micro, B, S, d)
        stage = jax.lax.axis_index("stage")
        n_micro, B, S, d = micro.shape
        n_ticks = n_micro + n_stages - 1
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S)
        )
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (or zeros past the stream)
            inject = micro[jnp.minimum(t, n_micro - 1)]
            x_in = jnp.where(stage == 0, inject, buf)
            y = _segment_apply(cfg, blocks_local, x_in, positions)
            # last stage records its finished microbatch
            out_idx = t - (n_stages - 1)
            record = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                record,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # forward activations down the chain (FIFO stream)
            buf_next = jax.lax.ppermute(y, "stage", perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros((B, S, d), micro.dtype)
        outs0 = jnp.zeros((n_micro, B, S, d), micro.dtype)
        (buf, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_ticks)
        )
        return outs[None]  # leading stage axis for the out_spec

    fn = _shard_map(
        staged,
        mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P("stage"),
    )

    def run(blocks_stacked, micro):
        outs = fn(blocks_stacked, micro)  # (n_stages, n_micro, B, S, d)
        return outs[-1]

    return run


def split_blocks_for_stages(params, n_stages: int):
    """Slice the (R, ...) block stack into the stage-sharded layout.

    Identity reshape — the repeats axis is already the pipeline order;
    with the mesh sharding R over ``stage`` each stage holds its
    consecutive slice, matching the paper's consecutive-layer mapping.
    """
    return params["blocks"]


def reference_backbone(cfg: ArchConfig, params, micro):
    """Non-pipelined oracle: same stacked params, scan over all repeats."""
    outs = []
    for i in range(micro.shape[0]):
        x = micro[i]
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None],
            (x.shape[0], x.shape[1]),
        )
        pattern = cfg.pattern()

        def body(x, rep):
            for j, kind in enumerate(pattern):
                x = lm._apply_block(
                    kind, rep[j]["mixer"], rep[j]["ffn"], x, cfg, positions,
                    NO_POLICY,
                )
            return x, None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        outs.append(x)
    return jnp.stack(outs)
