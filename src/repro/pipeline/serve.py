"""PHAROS serving runtime: per-stage FIFO/EDF scheduling with
tile-window preemption — the paper's §3.2 control flow executing real
compute.

Entities map 1:1 onto the paper's hardware (Fig. 2):

- ``ServeTask``     — a task: an ordered GEMM chain (the DNN layers),
                      period/deadline, and a layer->stage map obeying
                      the pipelined-topology constraint.
- ``StageRuntime``  — one accelerator: a job pool (FIFO deque / EDF
                      heap), a progress table (per-job, per-layer
                      `MatmulProgress`), and the window executor.
- ``PharosServer``  — the decentralized control flow: jobs released by
                      period, forwarded stage->stage when their segment
                      completes (the HLS FIFO streams), preempted
                      between tile windows when EDF priority demands.

Preemption fidelity: a job is only ever interrupted at a *window*
boundary — the running window always completes (``e_tile``), the fp32
partial accumulator already lives in the job's buffer (``e_store``),
and resumption re-streams the operand tiles (``e_load``) — exactly the
Eq. 5 cost structure, realized by `kernels.preemptible_matmul`.

Window executors: ``backend="jnp"`` (jitted masked-GEMM windows — fast,
used by examples/benchmarks) or ``backend="pallas"`` (the real kernel in
interpret mode — bit-identical semantics, used by the fidelity tests).
"""
from __future__ import annotations

import heapq
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.preemptible_matmul import (
    grid_geometry,
    matmul_window,
    pick_window,
)

DEFAULT_BLOCK = (128, 128, 128)

#: Degenerate safety tick (seconds): the smallest forced clock advance
#: of a serving loop iteration that made no progress — no window ran
#: and the next modeled event is not in the future (a float-equality
#: corner the event-driven advance cannot cross on its own). Advancing
#: by this epsilon guarantees a zero-progress step still terminates
#: instead of spinning; it is far below any modeled window cost, so it
#: never perturbs response times. Shared with the gateway's
#: cost-driven loop (`repro.traffic.gateway`).
DEGENERATE_SAFETY_TICK_S = 1e-9


def window_plan(
    M: int, N: int, K: int, *, block, backend: str, window_tiles: int
) -> tuple[int, int]:
    """(window size, window count) the executor runs for one
    ``(M,K) @ (K,N)`` layer — the single source of truth for window
    geometry, shared by `_window_for`, the cost-model validation in
    `PharosServer.__init__` and `repro.conformance.CostModel`. The jnp
    backend serves one output-tile row per window (exact-FLOP fast
    path); the pallas backend honours the configured tile count."""
    _, n_n, _, total = grid_geometry(M, N, K, block)
    window = n_n if backend == "jnp" else pick_window(total, window_tiles)
    return window, -(-total // window)


# ---------------------------------------------------------------------------
# window executors
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("n_tiles_m", "n_tiles_n", "block", "window"))
def _jnp_window(a, b, c_acc, start, *, n_tiles_m, n_tiles_n, block, window):
    """Masked-GEMM window: same tile semantics as the Pallas kernel."""
    bm, _, bn = block
    full = jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    flat = jnp.arange(n_tiles_m * n_tiles_n).reshape(n_tiles_m, n_tiles_n)
    active = (flat >= start) & (flat < start + window)
    mask = jnp.repeat(jnp.repeat(active, bm, 0), bn, 1)
    return c_acc + jnp.where(mask, full, 0.0)


@partial(jax.jit, static_argnames=("bm",))
def _jnp_row_strip(a, b, c_acc, row, *, bm):
    """Fast exact path for window == one row of output tiles: compute
    ``a[row*bm:(row+1)*bm] @ b`` only (the window's actual FLOPs)."""
    a_strip = jax.lax.dynamic_slice_in_dim(a, row * bm, bm, 0)
    strip = jnp.dot(
        a_strip.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return jax.lax.dynamic_update_slice_in_dim(
        c_acc, jax.lax.dynamic_slice_in_dim(c_acc, row * bm, bm, 0) + strip,
        row * bm, 0,
    )


def _run_window(a, b, c_acc, start, *, block, window, backend):
    M, K = a.shape
    _, N = b.shape
    n_m, n_n, _, total = grid_geometry(M, N, K, block)
    if backend == "pallas":
        return matmul_window(
            a, b, c_acc, start, block=block, window_tiles=window
        )
    if window == n_n and start % n_n == 0:
        c = _jnp_row_strip(a, b, c_acc, jnp.int32(start // n_n), bm=block[0])
    else:
        c = _jnp_window(
            a, b, c_acc, jnp.int32(start),
            n_tiles_m=n_m, n_tiles_n=n_n, block=block, window=window,
        )
    return c, min(start + window, total)


# ---------------------------------------------------------------------------
# tasks / jobs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ServeTask:
    """A periodic inference task: GEMM-chain layers mapped to stages."""

    name: str
    weights: tuple  # tuple of (K, N) jnp weight matrices, chained
    stage_of_layer: tuple[int, ...]  # non-decreasing (pipelined topology)
    period: float  # seconds
    deadline: float = 0.0  # 0 -> implicit
    input_rows: int = 128  # M of the chain input

    def __post_init__(self):
        if len(self.weights) != len(self.stage_of_layer):
            raise ValueError("one stage per layer required")
        if any(
            b < a
            for a, b in zip(self.stage_of_layer, self.stage_of_layer[1:])
        ):
            raise ValueError("stage map must be non-decreasing (no backtrack)")
        if self.deadline == 0.0:
            object.__setattr__(self, "deadline", self.period)


class Job:
    """One released inference + its progress-table rows.

    ``best_effort`` jobs carry an infinite absolute deadline: EDF orders
    them after every guaranteed job and they never count as deadline
    misses — the degraded service class the traffic layer's shedding
    policies demote to under overload.
    """

    _ids = itertools.count()

    def __init__(
        self,
        task_id: int,
        task: ServeTask,
        release: float,
        x0,
        *,
        best_effort: bool = False,
    ):
        self.uid = next(Job._ids)
        self.task_id = task_id
        self.release = release
        self.best_effort = best_effort
        self.abs_deadline = (
            float("inf") if best_effort else release + task.deadline
        )
        self.layer = 0  # next/current layer index
        self.x = x0  # current activation (input of self.layer)
        self.c_acc = None  # partial fp32 accumulator of current layer
        self.next_tile = 0
        self.done_at: float | None = None
        self.preemptions = 0

    def __repr__(self):
        return f"Job(t{self.task_id}#{self.uid} layer={self.layer})"


class StageRuntime:
    """One accelerator: job pool + running-job slot (paper Fig. 2).

    Best-effort jobs are genuinely demoted under both policies: EDF
    orders their infinite deadline after every guaranteed job, and FIFO
    keeps them in a second queue served only when no guaranteed job is
    waiting.
    """

    def __init__(self, idx: int, policy: str):
        self.idx = idx
        self.policy = policy
        self.fifo: deque[Job] = deque()
        self.fifo_be: deque[Job] = deque()  # best-effort background
        self.edf: list[tuple[float, int, Job]] = []
        self.running: Job | None = None
        # cost-model (virtual-time) mode: end of the window in flight
        self.busy_until = 0.0

    def jobs(self) -> list[Job]:
        """Every job currently resident on this stage (pool + running)."""
        out = list(self.fifo) + list(self.fifo_be)
        out += [j for _, _, j in self.edf]
        if self.running is not None:
            out.append(self.running)
        return out

    def push(self, job: Job) -> None:
        if self.policy == "fifo":
            (self.fifo_be if job.best_effort else self.fifo).append(job)
        else:
            heapq.heappush(self.edf, (job.abs_deadline, job.uid, job))

    def pop(self) -> Job | None:
        if self.policy == "fifo":
            if self.fifo:
                return self.fifo.popleft()
            return self.fifo_be.popleft() if self.fifo_be else None
        return heapq.heappop(self.edf)[2] if self.edf else None

    def head_deadline(self) -> float:
        return self.edf[0][0] if self.edf else float("inf")

    def busy(self) -> bool:
        return (
            self.running is not None
            or bool(self.fifo)
            or bool(self.fifo_be)
            or bool(self.edf)
        )


@dataclass
class ServerReport:
    response_times: dict[str, list[float]]
    #: release times of the completed jobs, aligned 1:1 with
    #: ``response_times`` — the join key for matching "the same job"
    #: across runs whose shed sets differ (conformance under overload)
    completed_releases: dict[str, list[float]]
    deadline_misses: dict[str, int]
    preemptions: int
    jobs_completed: int
    jobs_released: int
    windows_executed: int
    #: released-but-unfinished jobs per task at the last
    #: `PharosServer.finalize_report` — the same number the gateway's
    #: backlog monitor polls via `pending`, so overload verdicts and
    #: conformance checks read one counter
    in_flight: dict[str, int] = field(default_factory=dict)

    def max_response(self, name: str) -> float:
        r = self.response_times.get(name, [])
        return max(r) if r else 0.0

    def total_in_flight(self) -> int:
        return sum(self.in_flight.values())

    def response_percentiles(
        self, name: str, qs=(50, 95, 99)
    ) -> dict[str, float]:
        """Nearest-rank response-time percentiles of one tenant
        (`repro.obs.metrics.percentile` — the one shared
        implementation)."""
        from repro.obs.metrics import percentile_summary

        return percentile_summary(self.response_times.get(name, []), qs)

    def tardiness_percentiles(
        self, name: str, deadline: float, qs=(50, 95, 99)
    ) -> dict[str, float]:
        """Per-tenant tardiness (``max(0, response - deadline)``)
        percentiles against the given relative deadline."""
        from repro.obs.metrics import percentile_summary

        return percentile_summary(
            [
                max(0.0, r - deadline)
                for r in self.response_times.get(name, [])
            ],
            qs,
        )


class PharosServer:
    """Decentralized pipelined serving with FIFO/EDF + preemption.

    ``clock``/``sleep`` are injectable (defaults: wall clock). All
    timestamps inside one serving step come from the same clock, so a
    virtual clock (repro.traffic.clock.VirtualClock) makes the runtime
    fully deterministic for tests and for the traffic gateway.

    ``cost_model`` (repro.conformance.CostModel) switches virtual-time
    service from wall-side quantization to model-driven timing: every
    executed tile window occupies its stage for exactly the model's
    per-window WCET on the injected clock, preemption waits for the
    window boundary, and completions are stamped at the modeled finish
    time. Requires an injected (virtual) clock — advancing a wall clock
    by modeled WCETs would be meaningless.

    ``trace`` (a `repro.obs.TraceRecorder`) captures the runtime's
    schedule as structured events — release / dispatch /
    preempt_store / preempt_load (xi = 0: the virtual executor keeps
    accumulators resident, nothing spills) / segment_end / complete /
    deadline_miss — stamped on the injected clock; ``trace_shard`` tags
    every event with the shard index when the server backs one
    `ShardedGateway` replica. None (the default) emits nothing.
    """

    def __init__(
        self,
        tasks: list[ServeTask],
        n_stages: int,
        *,
        policy: str = "edf",
        block=DEFAULT_BLOCK,
        window_tiles: int = 4,
        backend: str = "jnp",
        seed: int = 0,
        clock=None,
        sleep=None,
        cost_model=None,
        trace=None,
        trace_shard: int = -1,
    ):
        if policy not in ("fifo", "edf"):
            raise ValueError(policy)
        if cost_model is not None:
            if clock is None:
                raise ValueError(
                    "cost_model-driven serving needs an injected "
                    "(virtual) clock"
                )
            if cost_model.n_tasks != len(tasks) or any(
                len(cost_model.layer_costs[i]) != len(t.weights)
                for i, t in enumerate(tasks)
            ):
                raise ValueError("cost model does not match the task set")
            # window counts must match the executor's real geometry or
            # per-window charges silently mis-time the whole run
            for i, t in enumerate(tasks):
                for j, w in enumerate(t.weights):
                    K, N = w.shape
                    _, expect = window_plan(
                        t.input_rows, N, K,
                        block=block, backend=backend,
                        window_tiles=window_tiles,
                    )
                    have = cost_model.layer_windows[i][j]
                    if have != expect:
                        raise ValueError(
                            f"cost model window count for task {i} "
                            f"layer {j} is {have}, executor runs "
                            f"{expect}"
                        )
        self.tasks = tasks
        self.policy = policy
        self.block = block
        self.window_tiles = window_tiles
        self.backend = backend
        self.cost_model = cost_model
        # rtlint: disable=clock-domain -- injectable wall-clock defaults
        # for live serving; the DES and tests inject virtual clocks
        self.clock = clock if clock is not None else time.perf_counter
        # rtlint: disable=clock-domain -- same: live-serving default
        self.sleep = sleep if sleep is not None else time.sleep
        # schedule-trace handle (repro.obs.TraceRecorder), resolved
        # once: disabled tracing emits nothing and costs nothing
        self._tr = (
            trace
            if trace is not None and getattr(trace, "enabled", False)
            else None
        )
        self._tr_shard = trace_shard
        self._missed_in_flight: set[int] = set()
        self.released_per_task = [0] * len(tasks)
        self.completed_per_task = [0] * len(tasks)
        self.stages = [StageRuntime(k, policy) for k in range(n_stages)]
        key = jax.random.PRNGKey(seed)
        self.inputs = []
        for t in tasks:
            key, sub = jax.random.split(key)
            k_dim = t.weights[0].shape[0]
            self.inputs.append(
                jax.random.normal(sub, (t.input_rows, k_dim), jnp.float32)
            )
        self.report = ServerReport(
            response_times={t.name: [] for t in tasks},
            completed_releases={t.name: [] for t in tasks},
            deadline_misses={t.name: 0 for t in tasks},
            preemptions=0,
            jobs_completed=0,
            jobs_released=0,
            windows_executed=0,
        )

    # ------------------------------------------------------------------
    def _start_layer(self, job: Job) -> None:
        t = self.tasks[job.task_id]
        w = t.weights[job.layer]
        M, N = job.x.shape[0], w.shape[1]
        job.c_acc = jnp.zeros((M, N), jnp.float32)
        job.next_tile = 0

    def _layer_tiles(self, job: Job) -> int:
        t = self.tasks[job.task_id]
        w = t.weights[job.layer]
        M, K = job.x.shape
        _, _, _, total = grid_geometry(M, w.shape[1], K, self.block)
        return total

    def _window_for(self, job: Job) -> int:
        """Preemption quantum of the current layer (see `window_plan`)."""
        t = self.tasks[job.task_id]
        w = t.weights[job.layer]
        M, K = job.x.shape
        window, _ = window_plan(
            M, w.shape[1], K,
            block=self.block, backend=self.backend,
            window_tiles=self.window_tiles,
        )
        return window

    def _finish_layer_or_forward(self, job: Job, now: float) -> None:
        """Layer done: advance; forward to next stage / complete job."""
        t = self.tasks[job.task_id]
        job.x = job.c_acc  # fp32 activation chains to the next GEMM
        job.c_acc = None
        prev_stage = t.stage_of_layer[job.layer]
        job.layer += 1
        if job.layer >= len(t.weights):
            job.done_at = now
            self.report.jobs_completed += 1
            self.completed_per_task[job.task_id] += 1
            rt = now - job.release
            self.report.response_times[t.name].append(rt)
            self.report.completed_releases[t.name].append(job.release)
            missed = (
                now > job.abs_deadline
                and job.uid not in self._missed_in_flight
            )
            if missed:
                # not already counted by a mid-run finalize_report
                self.report.deadline_misses[t.name] += 1
            if self._tr is not None:
                # response/tardiness/missed derive from (t, release,
                # deadline) at read time — same complete-event schema
                # as the DES; completed-job misses are not separately
                # emitted (only in-flight ones at finalize are)
                self._tr.emit(
                    "complete", now, "runtime", t.name,
                    prev_stage, self._tr_shard, release=job.release,
                    attrs={"deadline": job.abs_deadline},
                )
            return
        nxt = t.stage_of_layer[job.layer]
        self._start_layer(job)
        if nxt == prev_stage:
            # same accelerator: continue immediately (still its segment)
            self.stages[nxt].running = job
        else:
            # release to successor via the inter-stage FIFO (paper §3.2)
            if self._tr is not None:
                self._tr.emit(
                    "segment_end", now, "runtime", t.name,
                    prev_stage, self._tr_shard, release=job.release,
                )
            self.stages[nxt].push(job)

    def _preempt_if_due(self, st: StageRuntime, now: float) -> None:
        """EDF preemption check between windows (tile boundary)."""
        if (
            self.policy == "edf"
            and st.running is not None
            and st.head_deadline() < st.running.abs_deadline
        ):
            preempted = st.running
            preempted.preemptions += 1
            self.report.preemptions += 1
            if self._tr is not None:
                name = self.tasks[preempted.task_id].name
                # xi = 0: the virtual executor's accumulator stays
                # resident, so the boundary preemption spills nothing
                # (the conformance premise — raw-WCET comparison)
                self._tr.emit(
                    "preempt_store", now, "runtime", name,
                    st.idx, self._tr_shard, release=preempted.release,
                    attrs={"xi": 0.0},
                )
                self._tr.emit(
                    "preempt_load", now, "runtime", name,
                    st.idx, self._tr_shard, release=preempted.release,
                    attrs={"xi": 0.0},
                )
            st.push(preempted)  # progress table keeps (layer, next_tile)
            st.running = None

    def _emit_dispatch(self, st: StageRuntime, now: float) -> None:
        """Trace a stage server picking a job (fresh or resumed)."""
        if self._tr is None:
            return
        job = st.running
        self._tr.emit(
            "dispatch", now, "runtime",
            self.tasks[job.task_id].name,
            st.idx, self._tr_shard, release=job.release,
            # c_acc still set => mid-layer resume after a preemption
            attrs={"resumed": True} if job.c_acc is not None else None,
        )

    def _exec_window(self, job: Job) -> int:
        """Execute one tile window of ``job``'s current layer; returns
        the layer's total tile count."""
        t = self.tasks[job.task_id]
        w = t.weights[job.layer]
        total = self._layer_tiles(job)
        window = self._window_for(job)
        job.c_acc, job.next_tile = _run_window(
            job.x,
            w,
            job.c_acc,
            job.next_tile,
            block=self.block,
            window=window,
            backend=self.backend,
        )
        self.report.windows_executed += 1
        return total

    def _step_stage(self, st: StageRuntime, now: float) -> bool:
        """Run one tile window on stage ``st``. Returns True if it ran."""
        self._preempt_if_due(st, now)
        if st.running is None:
            st.running = st.pop()
            if st.running is None:
                return False
            self._emit_dispatch(st, now)
            if st.running.c_acc is None:
                self._start_layer(st.running)
        job = st.running
        total = self._exec_window(job)
        if job.next_tile >= total:
            st.running = None
            # Completion is stamped off the *injected* clock (the window
            # just executed, so re-read rather than reuse loop-entry
            # `now`) — keeps all timestamps on one timebase.
            self._finish_layer_or_forward(job, self.clock())
        return True

    def _step_stage_virtual(self, st: StageRuntime, now: float) -> bool:
        """Cost-model stepping: the stage is occupied until the modeled
        end of the window in flight; compute runs eagerly at window
        start, completion bookkeeping is stamped at ``busy_until``."""
        job = st.running
        if job is not None:
            if now < st.busy_until - 1e-18:
                return False  # mid-window in virtual time
            if job.next_tile >= self._layer_tiles(job):
                st.running = None
                self._finish_layer_or_forward(job, st.busy_until)
                # a same-stage next layer re-occupies `running`; a
                # forwarded/finished job frees the stage for the pool
        self._preempt_if_due(st, now)
        if st.running is None:
            st.running = st.pop()
            if st.running is None:
                return False
            self._emit_dispatch(st, now)
            if st.running.c_acc is None:
                self._start_layer(st.running)
        job = st.running
        self._exec_window(job)
        st.busy_until = now + self.cost_model.window_cost(
            job.task_id, job.layer
        )
        return True

    # ------------------------------------------------------------------
    # traffic-layer API: explicit release / single-step / backlog probes
    # ------------------------------------------------------------------
    def submit(
        self,
        task_id: int,
        release: float | None = None,
        *,
        best_effort: bool = False,
    ) -> Job:
        """Release one job of ``task_id`` (the TrafficGateway entry
        point; `run` uses it for its own periodic releases)."""
        t = self.tasks[task_id]
        job = Job(
            task_id,
            t,
            self.clock() if release is None else release,
            self.inputs[task_id],
            best_effort=best_effort,
        )
        self.stages[t.stage_of_layer[0]].push(job)
        self.report.jobs_released += 1
        self.released_per_task[task_id] += 1
        if self._tr is not None:
            # stamped at the *clock* instant of submission (monotone
            # within the stream); `release` carries the nominal stamp —
            # the cross-layer join key
            self._tr.emit(
                "release", self.clock(), "runtime", t.name,
                t.stage_of_layer[0], self._tr_shard,
                release=job.release,
                attrs={"best_effort": True} if best_effort else None,
            )
        return job

    def step(self) -> bool:
        """Run at most one tile window on every stage; True if any ran."""
        ran = False
        now = self.clock()
        stepper = (
            self._step_stage_virtual
            if self.cost_model is not None
            else self._step_stage
        )
        for st in self.stages:
            ran |= stepper(st, now)
        return ran

    def next_completion_time(self) -> float:
        """Earliest modeled window-boundary across busy stages (inf when
        every stage is idle) — the event a cost-model-driven caller
        should advance its virtual clock to."""
        ends = [
            st.busy_until for st in self.stages if st.running is not None
        ]
        return min(ends) if ends else float("inf")

    def pending(self, task_id: int) -> int:
        """Jobs of ``task_id`` released but not yet completed."""
        return (
            self.released_per_task[task_id]
            - self.completed_per_task[task_id]
        )

    def queue_depths(self) -> list[int]:
        """Per-stage backlog (pool + in-flight) — the observable the
        traffic layer checks against the analysis."""
        return [
            len(st.fifo)
            + len(st.fifo_be)
            + len(st.edf)
            + (1 if st.running else 0)
            for st in self.stages
        ]

    # ------------------------------------------------------------------
    def warmup(self) -> None:
        """Pre-compile every (layer geometry x window) the run will use —
        JIT stalls inside the serving loop would otherwise blow every
        deadline in the first hyperperiod."""
        for i, t in enumerate(self.tasks):
            x = self.inputs[i]
            for w in t.weights:
                M, N = x.shape[0], w.shape[1]
                window, _ = window_plan(
                    M, N, x.shape[1],
                    block=self.block, backend=self.backend,
                    window_tiles=self.window_tiles,
                )
                c = jnp.zeros((M, N), jnp.float32)
                c, _ = _run_window(
                    x, w, c, 0,
                    block=self.block, window=window, backend=self.backend,
                )
                jax.block_until_ready(c)
                x = c  # chain shapes like the real execution

    def finalize_report(self, now: float | None = None) -> ServerReport:
        """Horizon-end accounting: expose per-task in-flight counts and
        count deadline misses of jobs still executing past their
        absolute deadline — an overloaded run would otherwise report
        zero misses because unfinished jobs were never examined.
        Idempotent: each in-flight job is counted as a miss once."""
        now = self.clock() if now is None else now
        self.report.in_flight = {
            t.name: self.pending(i) for i, t in enumerate(self.tasks)
        }
        for st in self.stages:
            for job in st.jobs():
                if (
                    now > job.abs_deadline
                    and job.uid not in self._missed_in_flight
                ):
                    self._missed_in_flight.add(job.uid)
                    name = self.tasks[job.task_id].name
                    self.report.deadline_misses[name] += 1
                    if self._tr is not None:
                        self._tr.emit(
                            "deadline_miss", now, "runtime", name,
                            st.idx, self._tr_shard,
                            release=job.release,
                            attrs={"in_flight": True},
                        )
        return self.report

    def run(self, horizon_s: float) -> ServerReport:
        """Serve for ``horizon_s`` clock seconds (periodic releases)."""
        self.warmup()
        t0 = self.clock()
        next_release = [t0 for _ in self.tasks]
        while True:
            now = self.clock()
            if now - t0 >= horizon_s:
                break
            for i, t in enumerate(self.tasks):
                while next_release[i] <= now:
                    self.submit(i, next_release[i])
                    next_release[i] += t.period
            ran = self.step()
            if self.cost_model is not None:
                # event-driven virtual time: jump to the next modeled
                # window boundary or the next periodic release
                nxt = min(
                    self.next_completion_time(),
                    min(next_release),
                    t0 + horizon_s,
                )
                now2 = self.clock()
                if nxt > now2:
                    self.sleep(nxt - now2)
                elif not ran:
                    self.sleep(DEGENERATE_SAFETY_TICK_S)
            elif not ran:
                self.sleep(1e-4)  # idle
        return self.finalize_report(t0 + horizon_s)
