"""PHAROS pipelined execution on TPU meshes + the serving runtime.

- `executor`: the SPMD realization of the paper's chained-accelerator
  topology — equal stage submeshes on a ``stage`` mesh axis, activations
  forwarded with ``lax.ppermute`` (the HLS FIFO streams of paper Fig. 2).
- `serve`: the host-level runtime: per-stage FIFO/EDF schedulers, job
  pools, progress table, and tile-window preemption via the
  `preemptible_matmul` kernel — the paper's control flow (§3.2, §3.4).
- `stage_split`: DSE design points -> per-stage layer segments.
"""
from repro.pipeline.serve import (
    Job,
    PharosServer,
    ServeTask,
    ServerReport,
)
from repro.pipeline.stage_split import design_to_segments

__all__ = [
    "Job",
    "PharosServer",
    "ServeTask",
    "ServerReport",
    "design_to_segments",
]
