"""DSE design points -> runnable stage segments.

Bridges `core.dse` (which plans over `LayerDesc` chains) to the serving
runtime (which executes GEMM weights) and the SPMD executor (which needs
per-stage repeat counts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dse.space import DesignPoint
from repro.core.rt.task import TaskSet, Workload
from repro.pipeline.serve import ServeTask


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def design_to_segments(
    design: DesignPoint,
    workloads: list[Workload],
    taskset: TaskSet,
    *,
    key=None,
    block=(128, 128, 128),
    rows: int = 128,
    dtype=jnp.float32,
    period_scale: float = 1.0,
    max_dim: int | None = None,
) -> list[ServeTask]:
    """Materialize each task's layer chain as chained GEMM weights with
    the design's stage map (block-aligned so the preemptible kernel's
    window grid is exact).

    The chain contract: layer j's K equals layer j-1's N (activations
    flow through). Layer shapes are block-rounded; the *stage map* and
    period come straight from the design point. ``period_scale``
    rescales the analytic (TPU-model) periods to the host's wall-clock
    timebase — the schedule structure (ratios, utilization) is
    preserved, only the unit changes.

    ``max_dim`` caps each layer's K/N at a block-multiple — surrogate
    weights for cost-model-driven virtual serving, where timing comes
    from the model and the executed GEMM only has to preserve the
    window/stage structure (clamping K/N changes neither the window
    grid rows nor the stage map; it keeps a many-GB LM chain runnable
    on the host). Leave ``None`` whenever the computed *values* matter.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    bm, bk, bn = block
    cap = None if max_dim is None else _round_up(max_dim, max(bk, bn))
    out = []
    for i, (w, t) in enumerate(zip(workloads, taskset.tasks)):
        stage_of_layer = []
        for k in range(design.n_stages):
            stage_of_layer += [k] * design.splits[k][i]
        dims = []  # chained (K, N) per layer
        prev_n = _round_up(w.layers[0].K, bk)
        if cap is not None:
            prev_n = min(prev_n, cap)
        for l in w.layers:
            n = _round_up(l.N, bn)
            if cap is not None:
                n = min(n, cap)
            dims.append((prev_n, n))
            prev_n = n
        weights = []
        for (kd, nd) in dims:
            key, sub = jax.random.split(key)
            weights.append(
                jax.random.normal(sub, (kd, nd), dtype) / jnp.sqrt(kd)
            )
        out.append(
            ServeTask(
                name=t.name,
                weights=tuple(weights),
                stage_of_layer=tuple(stage_of_layer),
                period=t.period * period_scale,
                input_rows=_round_up(rows, bm),
            )
        )
    return out
