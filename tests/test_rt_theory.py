"""Property tests for the real-time core (Eqs. 2-5 + response bounds +
DES consistency with the guideline theory)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rt.response_time import busy_period, end_to_end_bounds
from repro.core.rt.schedulability import (
    max_utilization,
    srt_schedulable,
    stage_utilizations,
    utilization_headroom,
)
from repro.core.rt.task import (
    LayerDesc,
    SegmentTable,
    Task,
    TaskSet,
    Workload,
    chain_wcets,
)
from repro.scheduler.des import SimConfig, SimTask, StageOverhead, simulate, simulate_taskset


def _mk_workload(n=2):
    return Workload("w", tuple(LayerDesc(f"l{i}", 64, 64, 64) for i in range(n)))


# ---------------------------------------------------------------------------
# strategies: random chained segment tables with controlled utilization
# ---------------------------------------------------------------------------
@st.composite
def chained_system(draw, max_tasks=3, max_stages=3, u_cap=0.75):
    n_tasks = draw(st.integers(1, max_tasks))
    n_stages = draw(st.integers(1, max_stages))
    periods = [
        draw(st.floats(0.5, 4.0, allow_nan=False)) for _ in range(n_tasks)
    ]
    base = []
    for i in range(n_tasks):
        # per-stage budget keeps every stage utilization under u_cap
        budget = u_cap * periods[i] / n_tasks
        row = [
            draw(st.floats(0.0, budget, allow_nan=False))
            for _ in range(n_stages)
        ]
        if sum(row) == 0.0:
            row[0] = budget / 2
        base.append(row)
    overhead = [draw(st.floats(0.0, 0.01)) for _ in range(n_stages)]
    table = SegmentTable(base=base, overhead=overhead)
    tasks = tuple(
        Task(workload=_mk_workload(), period=p, name=f"t{i}")
        for i, p in enumerate(periods)
    )
    return table, TaskSet(tasks=tasks)


@settings(max_examples=60, deadline=None)
@given(chained_system())
def test_eq3_iff_max_util(sys_):
    table, ts = sys_
    mu = max_utilization(table, ts, preemptive=False)
    assert srt_schedulable(table, ts, preemptive=False) == (mu <= 1.0 + 1e-12)


@settings(max_examples=60, deadline=None)
@given(chained_system(), st.floats(0.3, 3.0))
def test_utilization_scales_inversely_with_periods(sys_, scale):
    """Paper §4.1: shrinking periods to x% scales u by 1/x%."""
    table, ts = sys_
    u0 = stage_utilizations(table, ts, preemptive=False)
    ts2 = TaskSet(
        tasks=tuple(
            Task(workload=t.workload, period=t.period * scale, name=t.name)
            for t in ts.tasks
        )
    )
    u1 = stage_utilizations(table, ts2, preemptive=False)
    for a, b in zip(u0, u1):
        assert b == pytest.approx(a / scale, rel=1e-9)


@settings(max_examples=60, deadline=None)
@given(chained_system())
def test_headroom_is_inverse_max_util(sys_):
    table, ts = sys_
    mu = max_utilization(table, ts, preemptive=False)
    assert utilization_headroom(table, ts, preemptive=False) == pytest.approx(
        1.0 / mu, rel=1e-9
    )


@settings(max_examples=60, deadline=None)
@given(chained_system())
def test_eq4_overhead_only_when_preemptive_and_active(sys_):
    table, ts = sys_
    for i in range(table.n_tasks):
        for k in range(table.n_stages):
            e_f = table.wcet(i, k, preemptive=False)
            e_p = table.wcet(i, k, preemptive=True)
            if table.base[i][k] <= 0:
                assert e_f == e_p == 0.0  # skipped stage -> zero WCET
            else:
                assert e_p == pytest.approx(e_f + table.overhead[k])
    for i in range(table.n_tasks):
        assert chain_wcets(table, i, False) == pytest.approx(
            sum(table.wcet(i, k, False) for k in range(table.n_stages))
        )


# ---------------------------------------------------------------------------
# busy period
# ---------------------------------------------------------------------------
def test_busy_period_basics():
    assert busy_period([], []) == 0.0
    # single task: busy period == wcet
    assert busy_period([0.2], [1.0]) == pytest.approx(0.2)
    # u >= 1 diverges
    assert busy_period([1.0], [1.0]) == math.inf
    # two-task fixed point: L=0.8 -> ceil(.8/1)*.4 + ceil(.8/1.5)*.4 = 0.8
    L = busy_period([0.4, 0.4], [1.0, 1.5])
    assert L == pytest.approx(0.8)
    # denser system iterates past one period: e=(0.5,0.4), p=(1,1.5):
    # L=0.9 -> 0.9; check against manual fixed point
    L2 = busy_period([0.5, 0.4], [1.0, 1.5])
    assert L2 == pytest.approx(0.9)


def test_busy_period_blocking_term():
    # the limited-preemption B enters once: L = B + sum ceil(L/p)*e
    assert busy_period([0.2], [1.0], blocking=0.1) == pytest.approx(0.3)
    # blocking alone (no competing work) is still a busy interval
    assert busy_period([], [], blocking=0.25) == pytest.approx(0.25)
    # blocking can push the fixed point over a period boundary:
    # L = 0.3 + ceil(L/1)*0.4 + ceil(L/1.5)*0.4 -> 1.1 -> 1.5 -> 1.9
    assert busy_period([0.4, 0.4], [1.0, 1.5], blocking=0.3) == (
        pytest.approx(1.9)
    )
    # divergence is unchanged by blocking
    assert busy_period([1.0], [1.0], blocking=0.1) == math.inf


def test_end_to_end_bounds_blocking_monotone_and_fifo_invariant():
    w = _mk_workload()
    table = SegmentTable(
        base=[[0.2, 0.1], [0.1, 0.2]], overhead=[0.0, 0.0]
    )
    ts = TaskSet(
        tasks=(
            Task(workload=w, period=1.0, name="a"),
            Task(workload=w, period=1.5, name="b"),
        )
    )
    blocking = [0.05, 0.08]
    for policy in ("fifo", "edf"):
        plain = end_to_end_bounds(table, ts, policy)
        blocked = end_to_end_bounds(table, ts, policy, blocking=blocking)
        if policy == "fifo":
            # FIFO never preempts: chunk granularity is unobservable
            assert blocked == plain
        else:
            # EDF pays for the blocking at every visited stage (jitter
            # chaining may compound it further downstream) — the bound
            # must grow, monotonically in B
            for p, b in zip(plain, blocked):
                assert b > p
            half = end_to_end_bounds(
                table, ts, policy, blocking=[x / 2 for x in blocking]
            )
            for h, b in zip(half, blocked):
                assert h <= b + 1e-12
    with pytest.raises(ValueError, match="blocking"):
        end_to_end_bounds(table, ts, "edf", blocking=[0.1])


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.floats(0.01, 0.3), min_size=1, max_size=4),
    st.floats(0.0, 2.0),
)
def test_busy_period_jitter_monotone(wcets, jitter):
    periods = [1.0 + i for i in range(len(wcets))]
    base = busy_period(wcets, periods)
    jittered = busy_period(wcets, periods, [jitter] * len(wcets))
    assert jittered >= base - 1e-12
    assert base >= sum(wcets) - 1e-12


# ---------------------------------------------------------------------------
# DES vs theory
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(chained_system(u_cap=0.6))
def test_des_schedulable_when_eq3_holds(sys_):
    """Guideline theory: chained + u<=1 -> bounded response (both
    policies). The DES must agree on comfortably-feasible systems."""
    table, ts = sys_
    for policy in ("fifo", "edf"):
        res = simulate_taskset(table, ts, policy, horizon=150 * max(
            t.period for t in ts.tasks
        ))
        assert res.schedulable, (policy, res.max_response)
        # analytic bound is an upper bound on simulated response
        bounds = end_to_end_bounds(table, ts, policy)
        for i in range(len(ts)):
            if res.max_response[i] > 0 and bounds[i] != math.inf:
                assert res.max_response[i] <= bounds[i] + 1e-6


def test_des_detects_overload():
    # u = 1.2: backlog grows one job per 2.5 periods; a 250 s horizon
    # pushes pending jobs past the backlog limit (the paper's detector)
    t = SimTask(segments=((0, 0.6),), period=0.5)
    res = simulate(
        [t], SimConfig(policy="fifo", horizon=250.0)
    )
    assert not res.schedulable
    assert res.overload_detected


def test_des_edf_preempts_and_fifo_does_not():
    # one long low-priority task + frequent urgent task on one stage
    long = SimTask(segments=((0, 0.50),), period=2.0, phase=0.0)
    urgent = SimTask(segments=((0, 0.05),), period=0.25, phase=0.01)
    ov = [StageOverhead(e_tile=0.005, e_store=0.005, e_load=0.005)]
    edf = simulate([long, urgent], SimConfig(policy="edf", horizon=20.0, overheads=ov))
    fifo = simulate([long, urgent], SimConfig(policy="fifo", horizon=20.0))
    assert edf.preemptions > 0
    assert fifo.preemptions == 0
    # EDF keeps the urgent task responsive; FIFO blocks it behind `long`
    assert edf.max_response[1] < fifo.max_response[1]


def test_des_fifo_polling_beats_no_polling():
    """Paper §5.2: FIFO w/o polling blocks new jobs on old ones even
    when the accelerator is idle -> worse response."""
    # two stages; task revisits stage 0 (backtracking, TG-style)
    t = SimTask(segments=((0, 0.1), (1, 0.3), (0, 0.1)), period=0.45)
    poll = simulate([t], SimConfig(policy="fifo", horizon=40.0))
    nopoll = simulate([t], SimConfig(policy="fifo_no_polling", horizon=40.0))
    assert poll.max_response_overall() <= nopoll.max_response_overall() + 1e-9


def test_des_preemption_overhead_inflates_response():
    long = SimTask(segments=((0, 0.50),), period=2.0)
    urgent = SimTask(segments=((0, 0.05),), period=0.25, phase=0.01)
    no_ov = simulate([long, urgent], SimConfig(policy="edf", horizon=30.0))
    with_ov = simulate(
        [long, urgent],
        SimConfig(
            policy="edf",
            horizon=30.0,
            overheads=[StageOverhead(0.02, 0.02, 0.02)],
        ),
    )
    assert with_ov.max_response[0] >= no_ov.max_response[0] - 1e-9
