"""Batched-vs-scalar bit-exactness, and the beam regression pins.

The vectorized DSE rests on one contract: the batched evaluators
(`repro.core.dse.batch_eval`, `repro.core.rt.batch`) return **the same
float64 bits** as the scalar routines they replace, so swapping them
into the search changes zero decisions. The property suite here
asserts exact ``==`` (not approx) across randomized design points, and
the regression pins hold the searched winners to the values the
pre-refactor scalar code produced on the Fig. 9 problems.
"""
import math
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dse.batch_eval import BatchedDesignEvaluator, resolve_acc
from repro.core.dse.beam import beam_search
from repro.core.dse.brute import brute_force_search
from repro.core.dse.create_acc import LatencyCache, create_acc
from repro.core.dse.space import design_from_splits
from repro.core.perfmodel.hardware import paper_platform
from repro.core.rt.batch import (
    batched_busy_period,
    batched_end_to_end_bounds,
    batched_max_utilization,
    batched_srt_schedulable,
    batched_stage_slacks,
    batched_stage_utilizations,
)
from repro.core.rt.response_time import busy_period, end_to_end_bounds
from repro.core.rt.schedulability import (
    max_utilization,
    srt_schedulable,
    stage_slacks,
    stage_utilizations,
)
from repro.core.rt.task import LayerDesc, SegmentTable, Task, TaskSet, Workload
from repro.core.workloads import PAPER_WORKLOADS, make_taskset

PLAT16 = paper_platform(16)
COMBO = ("pointnet", "deit_t", "resmlp")
WLS = [PAPER_WORKLOADS[c] for c in COMBO]
TS = make_taskset(COMBO, (0.8, 0.6, 0.5), PLAT16)

_W = Workload("w", (LayerDesc("l", 8, 8, 8),))


def _same(a: float, b: float) -> bool:
    """Exact equality, treating inf == inf as equal."""
    return a == b or (math.isinf(a) and math.isinf(b))


# ---------------------------------------------------------------------------
# property: batched create_acc == scalar create_acc, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.property
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_property_batched_create_acc_bit_identical(seed):
    rng = random.Random(seed)
    cache = LatencyCache(WLS)
    ev = BatchedDesignEvaluator(WLS, TS, cache=cache)
    spans, chips = [], []
    for _ in range(64):
        spans.append(
            [
                (a, rng.randint(a, w.num_layers))
                for w in WLS
                for a in (rng.randint(0, w.num_layers),)
            ]
        )
        # includes the degenerate chips <= 0 branch
        chips.append(rng.randint(-1, PLAT16.total_chips))
    util, block_idx, lats = ev.evaluate(np.array(spans), np.array(chips))
    for j, (sp, ch) in enumerate(zip(spans, chips)):
        acc, s_util, s_lats = create_acc(tuple(sp), ch, TS, cache)
        assert _same(s_util, float(util[j]))
        assert acc == resolve_acc(ch, int(block_idx[j]))
        assert all(_same(a, b) for a, b in zip(s_lats, lats[j]))


# ---------------------------------------------------------------------------
# property: batched Eq. 2/3 + slacks + bounds == scalar, bitwise
# ---------------------------------------------------------------------------
@st.composite
def table_batch(draw):
    n = draw(st.integers(1, 4))
    K = draw(st.integers(1, 4))
    periods = [draw(st.floats(0.01, 2.0, allow_nan=False)) for _ in range(n)]
    C = draw(st.integers(1, 6))
    base = [
        [
            [
                draw(st.floats(0.0, 1.2, allow_nan=False)) * p
                if draw(st.integers(0, 1))
                else 0.0
                for _ in range(K)
            ]
            for p in periods
        ]
        for _ in range(C)
    ]
    overhead = [draw(st.floats(0.0, 0.01, allow_nan=False)) for _ in range(K)]
    blocking = [draw(st.floats(0.0, 0.02, allow_nan=False)) for _ in range(K)]
    return periods, base, overhead, blocking


@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(table_batch())
def test_property_batched_rt_analysis_bit_identical(tb):
    periods, base, overhead, blocking = tb
    ts = TaskSet(tasks=tuple(Task(workload=_W, period=p) for p in periods))
    for preemptive in (False, True):
        b_util = batched_stage_utilizations(base, overhead, ts, preemptive)
        b_max = batched_max_utilization(base, overhead, ts, preemptive)
        b_ok = batched_srt_schedulable(base, overhead, ts, preemptive)
        b_slack = batched_stage_slacks(base, overhead, ts, preemptive)
        for c, rows in enumerate(base):
            t = SegmentTable(
                base=[list(r) for r in rows], overhead=list(overhead)
            )
            assert list(b_util[c]) == stage_utilizations(t, ts, preemptive)
            assert b_max[c] == max_utilization(t, ts, preemptive)
            assert bool(b_ok[c]) == srt_schedulable(t, ts, preemptive)
            assert list(b_slack[c]) == stage_slacks(t, ts, preemptive)
    for policy in ("fifo", "edf"):
        bb = batched_end_to_end_bounds(
            base, overhead, ts, policy, blocking=blocking
        )
        for c, rows in enumerate(base):
            t = SegmentTable(
                base=[list(r) for r in rows], overhead=list(overhead)
            )
            sb = end_to_end_bounds(t, ts, policy, blocking=blocking)
            assert all(_same(x, y) for x, y in zip(bb[c], sb))


@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_property_batched_busy_period_bit_identical(seed):
    rng = random.Random(seed)
    n = rng.randint(1, 5)
    periods = [rng.uniform(0.01, 2.0) for _ in range(n)]
    C = 8
    e = [
        [rng.choice([0.0, rng.uniform(0.0, p)]) for p in periods]
        for _ in range(C)
    ]
    j = [[rng.uniform(0.0, 0.5) for _ in periods] for _ in range(C)]
    blk = rng.uniform(0.0, 0.1)
    out = batched_busy_period(np.array(e), periods, np.array(j), blk)
    for c in range(C):
        assert _same(float(out[c]), busy_period(e[c], periods, j[c], blocking=blk))


# ---------------------------------------------------------------------------
# property: batched design_max_utils == design_from_splits, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.property
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_design_max_utils_bit_identical(seed):
    rng = random.Random(seed)
    ev = BatchedDesignEvaluator(WLS, TS)
    from repro.core.dse.create_acc import _VALID_BLOCKS
    from repro.core.perfmodel.exec_model import AccDesign

    designs = []
    for _ in range(32):
        n_stages = rng.randint(1, 4)
        accs = tuple(
            AccDesign(
                chips=rng.randint(1, 6),
                block=rng.choice(_VALID_BLOCKS),
            )
            for _ in range(n_stages)
        )
        splits = []
        for w in WLS:
            cuts = sorted(
                rng.randint(0, w.num_layers) for _ in range(n_stages - 1)
            )
            edges = [0] + cuts + [w.num_layers]
            splits.append(
                [edges[k + 1] - edges[k] for k in range(n_stages)]
            )
        splits = tuple(
            tuple(splits[i][k] for i in range(len(WLS)))
            for k in range(n_stages)
        )
        designs.append((accs, splits))
    mus = ev.design_max_utils(designs)
    for (accs, splits), mu in zip(designs, mus):
        dp = design_from_splits(accs, splits, WLS, TS)
        assert dp.max_util == float(mu)


# ---------------------------------------------------------------------------
# whole-search equivalence: batched and scalar evaluators, same search
# ---------------------------------------------------------------------------
def test_beam_search_scalar_and_batched_evaluators_agree():
    plat = paper_platform(8)
    combo = ("pointnet", "deit_t")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    ts = make_taskset(combo, (0.8, 0.8), plat)
    rb = beam_search(wls, ts, plat, max_m=4, beam_width=8, evaluator="batched")
    rs = beam_search(wls, ts, plat, max_m=4, beam_width=8, evaluator="scalar")
    assert rb.stats.create_acc_calls == rs.stats.create_acc_calls
    assert rb.best.max_util == rs.best.max_util
    assert rb.best.splits == rs.best.splits
    assert rb.best.accs == rs.best.accs
    assert [
        (d.max_util, d.splits, d.accs) for d in rb.succ_pts
    ] == [(d.max_util, d.splits, d.accs) for d in rs.succ_pts]
    with pytest.raises(ValueError, match="evaluator"):
        beam_search(wls, ts, plat, evaluator="vectorized")


# ---------------------------------------------------------------------------
# fixed-seed regression pins: the Fig. 9 problems' exact winners,
# recorded from the pre-refactor scalar implementation
# ---------------------------------------------------------------------------
#: (beam width -> (max_util, splits, chips)) on pointnet+deit_t,
#: paper_platform(8), ratios (0.8, 0.8), max_m=4
FIG9_PINS = {
    1: (
        0.6658158891586672,
        ((4, 1), (2, 0), (0, 4), (2, 5)),
        (1, 1, 2, 4),
    ),
    4: (
        0.6522945815752179,
        ((4, 1), (3, 0), (1, 3), (0, 6)),
        (1, 1, 1, 5),
    ),
    8: (
        0.6502023895711038,
        ((4, 1), (4, 0), (0, 3), (0, 6)),
        (1, 1, 1, 5),
    ),
    16: (
        0.5727108411007862,
        ((1, 2), (3, 3), (2, 1), (2, 4)),
        (2, 1, 1, 4),
    ),
}


@pytest.mark.parametrize("width", sorted(FIG9_PINS))
def test_fig9_beam_winner_pinned(width):
    """The refactor must not move a single winner: these exact floats,
    splits and chip allocations came from the seed-era scalar search on
    the Fig. 9 problem."""
    plat = paper_platform(8)
    combo = ("pointnet", "deit_t")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    ts = make_taskset(combo, (0.8, 0.8), plat)
    exp_util, exp_splits, exp_chips = FIG9_PINS[width]
    res = beam_search(wls, ts, plat, max_m=4, beam_width=width)
    assert res.best is not None
    assert res.best.max_util == exp_util
    assert res.best.splits == exp_splits
    assert tuple(a.chips for a in res.best.accs) == exp_chips


def test_small_brute_force_winner_pinned():
    """Brute-force pin on the 6-chip slice (the test-suite-sized BFS
    problem), recorded pre-refactor."""
    plat = paper_platform(6)
    combo = ("pointnet", "deit_t")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    ts = make_taskset(combo, (0.8, 0.8), plat)
    res = beam_search(wls, ts, plat, max_m=3, beam_width=2)
    assert res.best.max_util == 0.8208713754508719
    assert res.best.splits == ((2, 7), (6, 3))
    assert tuple(a.chips for a in res.best.accs) == (5, 1)
    brute = brute_force_search(wls, ts, plat, max_m=3)
    assert brute.best.max_util <= res.best.max_util


def test_beam_stats_report_eval_rate():
    plat = paper_platform(8)
    combo = ("pointnet", "deit_t")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    ts = make_taskset(combo, (0.8, 0.8), plat)
    res = beam_search(wls, ts, plat, max_m=3, beam_width=4)
    st_ = res.stats
    assert st_.evaluator == "batched"
    assert st_.candidates_evaluated == st_.create_acc_calls > 0
    assert 0.0 < st_.eval_seconds <= st_.wall_time_s
    assert st_.candidates_per_sec > 0.0
