"""Mixed-criticality mode switching (`repro.traffic.modes`).

Unit semantics of the `ModeController` state machine — the pre-commit
Eq. 3 re-proof of the HI survivor set, symmetric recovery, drop vs
degrade verdicts, HI-mode rate-limit costs — plus its DES duck-type
integration (`SimConfig.shedding` + `mode_switch` trace emission), and
the property battery the issue asked for: randomized overload traces
through the DES asserting survivor-set invariance across every
transition, HI-class preservation, twin-controller agreement, and
bit-identical reruns under the same seed. The cross-layer (DES vs
gateway) agreement leg runs once on the registry's ``av_stack``
scenario through `run_mode_switch_case`.
"""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.obs.trace import EVENT_KINDS, TraceRecorder
from repro.scheduler.des import SimConfig, SimTask, simulate
from repro.traffic.admission import (
    CRITICALITY_HI,
    CRITICALITY_LO,
    AdmissionController,
    TaskRequest,
)
from repro.traffic.modes import (
    MODE_HI,
    MODE_NORMAL,
    ModeController,
    criticality_counts,
)
from repro.traffic.shedding import BEST_EFFORT, DROP, SUBMIT


def _controller(reqs, **kw):
    adm = AdmissionController(
        [0.0] * len(reqs[0].base), preemptive=True
    )
    for r in reqs:
        assert adm.admit(r).admitted
    return ModeController(adm, list(reqs), **kw)


def _mixed_requests():
    return [
        TaskRequest(
            "hi_a", (0.2,), period=1.0, value=5.0,
            criticality=CRITICALITY_HI,
        ),
        TaskRequest(
            "hi_b", (0.1,), period=1.0, value=3.0,
            criticality=CRITICALITY_HI,
        ),
        TaskRequest("lo_c", (0.3,), period=1.0, value=0.5),
    ]


def _overload(mc, lo_idx=2, n=30):
    """Push the LO tenant's observed backlog past its engage limit."""
    for step in range(n):
        for i in range(len(mc.requests)):
            mc.observe(i, step if i == lo_idx else 0)


def _drain(mc, n=30):
    for _ in range(n):
        for i in range(len(mc.requests)):
            mc.observe(i, 0)


# ---------------------------------------------------------------------------
# criticality contracts
# ---------------------------------------------------------------------------
def test_criticality_defaults_and_validation():
    r = TaskRequest("t", (0.1,), period=1.0)
    assert r.criticality == CRITICALITY_LO
    with pytest.raises(ValueError, match="criticality"):
        TaskRequest("t", (0.1,), period=1.0, criticality="SAFETY")
    assert criticality_counts(_mixed_requests()) == {
        CRITICALITY_HI: 2,
        CRITICALITY_LO: 1,
    }


def test_tenant_spec_carries_criticality():
    from repro.traffic.scenarios import TenantSpec, get_scenario

    spec = TenantSpec(
        "paper:deit_t", ratio=0.5, criticality=CRITICALITY_HI
    )
    assert spec.criticality == CRITICALITY_HI
    with pytest.raises(ValueError, match="criticality"):
        TenantSpec("paper:deit_t", ratio=0.5, criticality="MEDIUM")
    av = get_scenario("av_stack")
    counts = criticality_counts(av.tenants)
    assert counts[CRITICALITY_HI] == 2 and counts[CRITICALITY_LO] == 1


# ---------------------------------------------------------------------------
# the state machine
# ---------------------------------------------------------------------------
def test_hi_switch_commits_with_proof_and_recovers():
    mc = _controller(_mixed_requests())
    assert mc.mode == MODE_NORMAL
    assert mc.survivors == ("hi_a", "hi_b", "lo_c")

    _overload(mc)
    assert mc.mode == MODE_HI
    assert mc.survivors == ("hi_a", "hi_b")
    (sw,) = mc.switches
    assert sw.mode == MODE_HI
    assert sw.survivors == ("hi_a", "hi_b")
    assert sw.schedulable and 0.0 < sw.max_util < 1.0
    # the host layer drains each committed transition exactly once
    assert [s.mode for s in mc.drain_events()] == [MODE_HI]
    assert mc.drain_events() == []

    _drain(mc)
    assert mc.mode == MODE_NORMAL
    assert mc.survivors == ("hi_a", "hi_b", "lo_c")
    recovery = mc.switches[-1]
    assert recovery.mode == MODE_NORMAL
    assert recovery.survivors == ("hi_a", "hi_b", "lo_c")
    assert recovery.schedulable
    assert [s.mode for s in mc.drain_events()] == [MODE_NORMAL]


def test_classify_verdicts_per_action():
    for action, lo_verdict in (("degrade", BEST_EFFORT), ("drop", DROP)):
        mc = _controller(_mixed_requests(), action=action)
        assert mc.drops == (action == "drop")
        # normal mode: everything flows
        assert all(mc.classify(i, (2,)) == SUBMIT for i in range(3))
        _overload(mc)
        assert mc.classify(0, (2,)) == SUBMIT
        assert mc.classify(1, (2,)) == SUBMIT
        assert mc.classify(2, (2,)) == lo_verdict
        # the verdict keys on the committed mode, not on who is
        # overloaded right now
        assert mc.classify(2, ()) == lo_verdict


def test_constructor_validation():
    reqs = _mixed_requests()
    with pytest.raises(ValueError, match="mode action"):
        _controller(reqs, action="evict")
    with pytest.raises(ValueError, match="lo_release_cost"):
        _controller(reqs, lo_release_cost=0.5)


def test_release_cost_tightens_lo_only_in_hi_mode():
    mc = _controller(_mixed_requests(), lo_release_cost=3.0)
    assert [mc.release_cost(i) for i in range(3)] == [1.0, 1.0, 1.0]
    _overload(mc)
    assert [mc.release_cost(i) for i in range(3)] == [1.0, 1.0, 3.0]
    _drain(mc)
    assert [mc.release_cost(i) for i in range(3)] == [1.0, 1.0, 1.0]


def test_hi_util_cap_excludes_unprovable_hi_tenant():
    # a tightened HI-mode cap that hi_a (0.2 util) fits but the pair
    # (0.3) does not: the re-proof must exclude hi_b, flag the proof
    # as partial, and treat hi_b like LO work in HI mode
    mc = _controller(
        _mixed_requests(), hi_util_cap=0.25, action="degrade"
    )
    _overload(mc)
    (sw,) = mc.switches
    assert sw.survivors == ("hi_a",)
    assert not sw.schedulable
    assert mc.classify(0, (2,)) == SUBMIT
    assert mc.classify(1, (2,)) == BEST_EFFORT
    assert mc.release_cost(1) == mc.lo_release_cost


# ---------------------------------------------------------------------------
# DES integration
# ---------------------------------------------------------------------------
def _des_system():
    reqs = [
        TaskRequest(
            "hi", (0.2,), period=1.0, value=5.0,
            criticality=CRITICALITY_HI,
        ),
        TaskRequest("lo", (0.5,), period=1.0, value=0.5),
    ]
    hi = SimTask(segments=((0, 0.2),), period=1.0, name="hi")
    lo = SimTask(
        segments=((0, 0.5),),
        period=1.0,
        arrivals=tuple(0.2 * i for i in range(100)),
        name="lo",
    )
    return reqs, [hi, lo]


def test_des_emits_mode_switch_and_protects_hi():
    reqs, tasks = _des_system()
    mc = _controller(reqs, action="degrade")
    rec = TraceRecorder(enabled=True)
    res = simulate(
        tasks,
        SimConfig(policy="edf", horizon=20.0, shedding=mc, trace=rec),
    )
    assert res.mode_switches and res.mode_switches[0][1] == MODE_HI
    assert res.mode_switches[0][2] == ("hi",)
    # the HI tenant is never demoted or shed
    assert res.shed_per_task[0] == 0 and res.degraded_per_task[0] == 0
    assert res.degraded_per_task[1] > 0
    # the trace carries the canonical kind with stamped attrs,
    # mirroring SimResult.mode_switches one-to-one
    events = [e for e in rec.events if e.kind == "mode_switch"]
    assert {e.kind for e in rec.events} <= set(EVENT_KINDS)
    assert [
        (e.t, e.attrs["mode"], tuple(e.attrs["survivors"])) for e in events
    ] == list(res.mode_switches)
    assert all(e.attrs["schedulable"] for e in events)


def test_des_drop_mode_keeps_gating_chain_live():
    """Dropped LO releases must stay gate-transparent: with a two-stage
    LO chain under `fifo_no_polling`, jobs released after HI-mode drops
    still flow through both stages."""
    reqs = [
        TaskRequest(
            "hi", (0.2, 0.0), period=1.0, value=5.0,
            criticality=CRITICALITY_HI,
        ),
        TaskRequest("lo", (0.4, 0.1), period=1.0, value=0.5),
    ]
    adm = AdmissionController([0.0, 0.0], preemptive=False)
    for r in reqs:
        assert adm.admit(r).admitted
    mc = ModeController(adm, reqs, action="drop")
    hi = SimTask(segments=((0, 0.2),), period=1.0, name="hi")
    lo = SimTask(
        segments=((0, 0.4), (1, 0.1)),
        period=1.0,
        arrivals=tuple(0.25 * i for i in range(80)),
        name="lo",
    )
    res = simulate(
        [hi, lo],
        SimConfig(policy="fifo_no_polling", horizon=30.0, shedding=mc),
    )
    assert res.jobs_shed > 0
    # everything released finishes, modulo jobs caught mid-flight by
    # the horizon — a stalled gating chain would strand far more
    assert res.jobs_released - res.jobs_completed <= len(res.response_times)


# ---------------------------------------------------------------------------
# the property battery
# ---------------------------------------------------------------------------
@st.composite
def mixed_system(draw):
    """1-3 HI tenants plus one overdriven-then-quiet LO tenant on one
    stage, with the provisioned mix kept Eq. 3-admissible."""
    n_hi = draw(st.integers(1, 3))
    hi_w = [
        draw(st.floats(0.05, 0.15, allow_nan=False)) for _ in range(n_hi)
    ]
    lo_w = draw(st.floats(0.1, 0.4, allow_nan=False))
    overdrive = draw(st.floats(2.0, 3.0, allow_nan=False))
    burst_end = draw(st.floats(10.0, 20.0, allow_nan=False))
    seed = draw(st.integers(0, 10_000))
    action = draw(st.sampled_from(["drop", "degrade"]))
    policy = draw(st.sampled_from(["fifo", "edf"]))

    rng = random.Random(seed)
    gap = lo_w / overdrive
    t, arrivals = 0.0, []
    while t < burst_end:
        arrivals.append(t)
        t += gap * (0.5 + rng.random())
    reqs = [
        TaskRequest(
            f"hi{i}", (w,), period=1.0, value=5.0,
            criticality=CRITICALITY_HI,
        )
        for i, w in enumerate(hi_w)
    ] + [TaskRequest("lo", (lo_w,), period=1.0, value=0.5)]
    tasks = [
        SimTask(segments=((0, w),), period=1.0, name=f"hi{i}")
        for i, w in enumerate(hi_w)
    ] + [
        SimTask(
            segments=((0, lo_w),),
            period=1.0,
            arrivals=tuple(arrivals),
            name="lo",
        )
    ]
    return reqs, tasks, action, policy


def _run_mixed(reqs, tasks, action, policy, horizon=40.0):
    mc = _controller(reqs, action=action)
    rec = TraceRecorder(enabled=True)
    res = simulate(
        list(tasks),
        SimConfig(policy=policy, horizon=horizon, shedding=mc, trace=rec),
    )
    events = [
        (e.t, e.kind, e.task, e.stage, e.release, e.attrs)
        for e in rec.events
    ]
    return mc, res, events


@pytest.mark.property
@settings(max_examples=25, deadline=None)
@given(mixed_system())
def test_property_mode_switches_protect_hi_and_stay_invariant(sys_):
    """Every HI entry re-proves and commits the same survivor set (the
    full HI class), modes strictly alternate, and the HI class is never
    shed or demoted — across every randomized overload trace."""
    reqs, tasks, action, policy = sys_
    mc, res, _events = _run_mixed(reqs, tasks, action, policy)
    hi_names = tuple(r.name for r in reqs if r.criticality == CRITICALITY_HI)
    assert res.mode_switches, "overdriven LO never tripped the monitor"
    modes = [m for _, m, _ in res.mode_switches]
    assert modes[0] == MODE_HI
    assert all(a != b for a, b in zip(modes, modes[1:])), (
        "mode transitions must strictly alternate hi/normal"
    )
    for _, mode, survivors in res.mode_switches:
        if mode == MODE_HI:
            assert survivors == hi_names
        else:
            assert survivors == tuple(r.name for r in reqs)
    for s in mc.switches:
        assert s.schedulable
    for i, r in enumerate(reqs):
        if r.criticality == CRITICALITY_HI:
            assert res.shed_per_task[i] == 0
            assert res.degraded_per_task[i] == 0
    # the committed mode and the hysteresis state agree at rest
    assert any(mc.engaged.values()) == (mc.mode == MODE_HI)


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(mixed_system())
def test_property_mode_runs_are_bit_identical(sys_):
    """Same contracts, same trace, fresh controller: the transition
    log, the per-task counters and the full event stream reproduce
    bit-for-bit — mode switching adds no nondeterminism."""
    reqs, tasks, action, policy = sys_
    _mc1, res1, ev1 = _run_mixed(reqs, tasks, action, policy)
    _mc2, res2, ev2 = _run_mixed(reqs, tasks, action, policy)
    assert res1.mode_switches == res2.mode_switches
    assert res1.shed_per_task == res2.shed_per_task
    assert res1.degraded_per_task == res2.degraded_per_task
    assert res1.response_times == res2.response_times
    assert len(ev1) == len(ev2)
    for i, (a, b) in enumerate(zip(ev1, ev2)):
        assert a == b, f"first trace divergence at event {i}: {a} != {b}"


@pytest.mark.property
@settings(max_examples=25, deadline=None)
@given(mixed_system(), st.integers(0, 10_000))
def test_property_twin_controllers_agree_on_survivors(sys_, obs_seed):
    """Two fresh controllers over the same contracts — one per layer,
    as `run_mode_switch_case` arms them — commit identical transition
    sequences when fed the same backlog observations, even observed in
    a different task order within each step."""
    reqs, _tasks, action, _policy = sys_
    a = _controller(reqs, action=action)
    b = _controller(reqs, action=action)
    rng = random.Random(obs_seed)
    lo = len(reqs) - 1
    pending = 0
    for _ in range(60):
        pending = max(0, pending + rng.choice((-3, -1, 2, 4)))
        order = list(range(len(reqs)))
        rng.shuffle(order)
        for i in order:
            a.observe(i, pending if i == lo else 0)
        for i in range(len(reqs)):
            b.observe(i, pending if i == lo else 0)
    assert [
        (s.mode, s.survivors, s.schedulable) for s in a.switches
    ] == [(s.mode, s.survivors, s.schedulable) for s in b.switches]
    assert a.mode == b.mode and a.survivors == b.survivors


# ---------------------------------------------------------------------------
# cross-layer agreement on the registry scenario
# ---------------------------------------------------------------------------
def test_av_stack_mode_switch_case_is_green():
    """The conformance harness's own verdict on the registry's AV
    scenario: both layers switch, agree on the survivor set, and the
    HI class holds its per-class Eq. 3 guarantee across transitions."""
    from repro.conformance import ConformanceConfig, run_mode_switch_case
    from repro.core.perfmodel.hardware import paper_platform
    from repro.traffic.scenarios import build, get_scenario

    built = build(
        get_scenario("av_stack"), paper_platform(16), beam_width=4
    )
    cfg = ConformanceConfig(horizon_periods=24.0)
    res = run_mode_switch_case(built, "edf", action="degrade", cfg=cfg)
    assert res.ok, [str(v) for v in res.violations]
    assert res.survivors == ("lidar_perception", "camera_monitor")
    assert res.des_switches and res.server_switches
    assert res.hi_proof_schedulable
    assert res.hi_miss_totals() == (0, 0)
    lo_row = next(t for t in res.tasks if t.criticality == CRITICALITY_LO)
    assert lo_row.server_degraded > 0 and lo_row.des_degraded > 0
