"""Per-arch smoke tests (reduced same-family configs) + model math."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, smoke_config
from repro.models import layers as L
from repro.models import lm
from repro.models.extract import arch_workload

ARCHS = [
    "jamba_v0_1_52b",
    "granite_moe_3b_a800m",
    "dbrx_132b",
    "rwkv6_7b",
    "internvl2_76b",
    "qwen1_5_32b",
    "minitron_4b",
    "mistral_nemo_12b",
    "stablelm_1_6b",
    "musicgen_medium",
]


def _cfg(name):
    return importlib.import_module(f"repro.configs.{name}").CONFIG


def _batch(cfg, B, S, key):
    if cfg.frontend == "none":
        return {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    return {
        "embeds": jax.random.normal(key, (B, S, cfg.frontend_dim), jnp.bfloat16),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: shapes + finite."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = smoke_config(_cfg(arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, key)
    logits = lm.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    opt = adamw_init(params)
    params2, opt2, m = adamw_update(params, grads, opt, AdamWConfig())
    assert bool(jnp.isfinite(m["grad_norm"]))
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, params2,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ["qwen1_5_32b", "rwkv6_7b", "jamba_v0_1_52b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher forcing: decode logits at step S equal forward logits.

    The decode path recomputes recurrences stepwise (vs chunked in
    forward); bf16 + reassociation noise compounds over layers, so the
    check is relative-L2 + argmax agreement, not elementwise equality.
    """
    cfg = smoke_config(_cfg(arch))
    key = jax.random.PRNGKey(1)
    # fp32 params: this tests *path equivalence* (chunked-vs-stepwise
    # recurrences), not bf16 accumulation noise (covered elsewhere)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        lm.init_params(key, cfg),
    )
    B, S, L = 2, 16, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    # full forward over S+1 tokens: logits at position S-1 predict token S
    full = lm.forward(params, cfg, {"tokens": toks}, remat=False)
    # prefill S tokens, then decode token S
    logits_p, cache = lm.prefill(params, cfg, {"tokens": toks[:, :S]}, L)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, S - 1]), rtol=2e-2, atol=2e-2
    )
    pos = jnp.full((B,), S, jnp.int32)
    logits_d, _ = lm.decode_step(params, cfg, cache, {"tokens": toks[:, S]}, pos)
    got = np.asarray(logits_d, np.float32)
    want = np.asarray(full[:, S], np.float32)
    rel_l2 = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel_l2 < 0.05, rel_l2
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.5


def test_moe_capacity_matches_dropless_when_generous():
    cfg = ArchConfig(
        name="t", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab=128, n_experts=8, top_k=2,
        capacity_factor=8.0,
    )
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)
    y_ref = L.moe_dropless(p, x, cfg).astype(jnp.float32)
    for groups in (1, 2, 4):
        y = L.moe_capacity(p, x, cfg, groups=groups).astype(jnp.float32)
        rel = float(jnp.abs(y - y_ref).max() / jnp.abs(y_ref).max())
        assert rel < 2e-2, (groups, rel)


def test_moe_capacity_drops_under_tight_capacity():
    cfg = ArchConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=16, vocab=64, n_experts=4, top_k=2,
        capacity_factor=0.25,
    )
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32), jnp.bfloat16)
    y_tight = L.moe_capacity(p, x, cfg, groups=1)
    # residual path preserved: output finite and not exploding
    assert bool(jnp.isfinite(y_tight).all())


def test_rwkv_chunked_matches_stepwise():
    """models.rwkv chunked scan == naive per-token recurrence."""
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
    from repro.models import rwkv as R

    cfg = smoke_config(_cfg("rwkv6_7b"))
    p = R.rwkv_tmix_init(jax.random.PRNGKey(3), cfg)
    B, S, d = 1, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, d), jnp.float32) * 0.1
    xn = x  # feed raw: compare the wkv core only via the module output
    out_chunk, st = R._tmix_impl(p, x, cfg, chunk=8)
    out_full, st2 = R._tmix_impl(p, x, cfg, chunk=32)
    np.testing.assert_allclose(
        np.asarray(out_chunk, np.float32), np.asarray(out_full, np.float32),
        rtol=1e-3, atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(st["S"]), np.asarray(st2["S"]), rtol=1e-3, atol=1e-3
    )


def test_mamba_chunked_matches_unchunked():
    from repro.models import ssm as Smod

    cfg = smoke_config(_cfg("jamba_v0_1_52b"))
    p = Smod.mamba_init(jax.random.PRNGKey(5), cfg)
    B, S = 1, 32
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model), jnp.float32) * 0.2
    y8, c8 = Smod._mamba_impl(p, x, cfg)
    import dataclasses
    cfg_full = dataclasses.replace(cfg, mamba_chunk=32)
    y32, c32 = Smod._mamba_impl(p, x, cfg_full)
    np.testing.assert_allclose(
        np.asarray(y8, np.float32), np.asarray(y32, np.float32),
        rtol=1e-3, atol=1e-3,
    )
    np.testing.assert_allclose(c8["ssm"], c32["ssm"], rtol=1e-3, atol=1e-3)


def test_layer_plan_patterns():
    jamba = _cfg("jamba_v0_1_52b")
    plan = jamba.layer_plan()
    assert len(plan) == 32
    assert sum(1 for m, _ in plan if m == "attn") == 4  # 1:7 interleave
    assert sum(1 for _, f in plan if f == "moe") == 16  # every other
    assert len(jamba.pattern()) == 8 and jamba.n_repeats == 4
    rwkv = _cfg("rwkv6_7b")
    assert all(m == "rwkv" for m, _ in rwkv.layer_plan())
    dense = _cfg("qwen1_5_32b")
    assert all(f == "dense" for _, f in dense.layer_plan())


@pytest.mark.parametrize("arch", ARCHS)
def test_extracted_workload_positive_costs(arch):
    cfg = _cfg(arch)
    for mode in ("prefill", "decode", "train"):
        wl = arch_workload(cfg, batch=4, seq=256, mode=mode)
        assert wl.num_layers > 0
        assert wl.total_flops() > 0 and wl.total_bytes() > 0
    # train > prefill flops; decode much smaller
    f_train = arch_workload(cfg, 4, 256, "train").total_flops()
    f_pre = arch_workload(cfg, 4, 256, "prefill").total_flops()
    f_dec = arch_workload(cfg, 4, 256, "decode").total_flops()
    assert f_train > f_pre > f_dec


def test_param_counts_sane():
    # dense: active == total; moe: active < total
    q = _cfg("qwen1_5_32b").param_counts()
    assert q["active"] == q["total"]
    assert 25e9 < q["total"] < 40e9  # ~32B
    d = _cfg("dbrx_132b").param_counts()
    assert d["active"] < d["total"]
    assert 110e9 < d["total"] < 150e9
    g = _cfg("granite_moe_3b_a800m").param_counts()
    assert g["active"] < g["total"] / 2


def test_int8_kv_decode_close_to_bf16():
    """Serving §Perf variant: int8 KV decode within quantization noise."""
    from repro.models.layers import quantize_kv

    cfg = smoke_config(_cfg("qwen1_5_32b"))
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    B, S, L = 2, 16, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    _, cache = lm.prefill(params, cfg, {"tokens": toks[:, :S]}, L)
    pos = jnp.full((B,), S, jnp.int32)
    ref, _ = lm.decode_step(params, cfg, cache, {"tokens": toks[:, S]}, pos)
    cq = tuple(
        {
            "k": quantize_kv(b["k"])[0],
            "v": quantize_kv(b["v"])[0],
            "k_scale": quantize_kv(b["k"])[1],
            "v_scale": quantize_kv(b["v"])[1],
        }
        for b in cache
    )
    q8, cq2 = lm.decode_step(
        params, cfg, cq, {"tokens": toks[:, S]}, pos, kv_quant=True
    )
    got, want = np.asarray(q8, np.float32), np.asarray(ref, np.float32)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.05, rel
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.9
    # cache stayed int8 and the new token landed
    assert cq2[0]["k"].dtype == jnp.int8
    assert bool((jnp.abs(cq2[0]["k"][:, :, :, S]) > 0).any())
