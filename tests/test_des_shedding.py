"""Release-time shedding inside the DES (`SimConfig.shedding`).

Unit semantics of `ReleaseShedding` (hysteresis, drop vs demote, the
gating-chain liveness of dropped jobs), the `des_release_shedding`
adapter mirroring the gateway's limits, and the layer's property: for
every *surviving* job (matched across runs by release time), shedding
can only make the response better, never worse.
"""
import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.scheduler.des import (
    SHED_BEST_EFFORT,
    SHED_DROP,
    SHED_SUBMIT,
    ReleaseShedding,
    SimConfig,
    SimTask,
    simulate,
)
from repro.traffic import AdmissionController, TaskRequest
from repro.traffic.shedding import (
    BacklogMonitor,
    des_release_shedding,
    get_policy,
)


def _shed_task(task_id):
    """Drop every release of ``task_id`` while it is overloaded."""
    return lambda t, overloaded: (
        SHED_DROP if t == task_id and t in overloaded else SHED_SUBMIT
    )


# ---------------------------------------------------------------------------
# hysteresis
# ---------------------------------------------------------------------------
def test_release_shedding_hysteresis_matches_backlog_monitor():
    rs = ReleaseShedding(limits=(4,), classify=_shed_task(0))
    mon = BacklogMonitor()
    for pending in (3, 5, 4, 3, 2, 1, 5, 0):
        assert rs.observe(0, pending) == mon.observe(0, pending, 4)


# ---------------------------------------------------------------------------
# drop semantics
# ---------------------------------------------------------------------------
def _overdriven(wcet=0.5, gap=0.3, n=40):
    """One task overdriven past stage capacity (u = wcet/gap > 1)."""
    return SimTask(
        segments=((0, wcet),),
        period=1.0,  # provisioned contract (honoured by nobody)
        arrivals=tuple(i * gap for i in range(n)),
        name="hot",
    )


def test_des_shedding_restores_boundedness_and_counts():
    t = _overdriven()
    horizon = 40.0
    free = simulate([t], SimConfig(policy="fifo", horizon=horizon, backlog_limit=8))
    assert free.overload_detected and not free.schedulable

    shed = simulate(
        [t],
        SimConfig(
            policy="fifo",
            horizon=horizon,
            backlog_limit=8,
            shedding=ReleaseShedding(limits=(4,), classify=_shed_task(0)),
        ),
    )
    assert not shed.overload_detected
    assert shed.schedulable
    assert shed.jobs_shed == shed.shed_per_task[0] > 0
    # accounting: every arrival is either shed or released
    assert shed.jobs_released + shed.jobs_shed == 40
    # completions carry their release stamps, aligned 1:1
    assert len(shed.completed_releases[0]) == len(shed.response_times[0])
    assert shed.completed_releases[0] == sorted(shed.completed_releases[0])


def test_des_shedding_drop_does_not_deadlock_gating_chain():
    """`fifo_no_polling` gates job j on job j-1's completion; a dropped
    j-1 must be seen through, not waited for forever."""
    t = SimTask(
        segments=((0, 0.5), (1, 0.1)),
        period=1.0,
        arrivals=tuple(0.3 * i for i in range(20)),
        name="hot",
    )
    res = simulate(
        [t],
        SimConfig(
            policy="fifo_no_polling",
            horizon=30.0,
            backlog_limit=8,
            shedding=ReleaseShedding(limits=(3,), classify=_shed_task(0)),
        ),
    )
    assert res.jobs_shed > 0
    # jobs released after sheds still flow through both stages
    assert res.jobs_completed == res.jobs_released


def test_des_shedding_best_effort_demotes_instead_of_dropping():
    urgent = SimTask(segments=((0, 0.2),), period=1.0, name="urgent")
    hog = SimTask(
        segments=((0, 0.5),),
        period=1.0,
        deadline=0.9,
        arrivals=tuple(0.35 * i for i in range(40)),
        name="hog",
    )
    res = simulate(
        [urgent, hog],
        SimConfig(
            policy="edf",
            horizon=20.0,
            shedding=ReleaseShedding(
                limits=(64, 3),
                classify=lambda t, ov: (
                    SHED_BEST_EFFORT if t == 1 and t in ov else SHED_SUBMIT
                ),
            ),
        ),
    )
    assert res.degraded_per_task[1] > 0 and res.jobs_shed == 0
    # demoted jobs carry an infinite deadline: once the monitor has
    # engaged (the hog's backlog never drains, so it stays engaged),
    # every hog release runs behind the guaranteed work and the urgent
    # task's responses settle back to its isolated service time — the
    # early jobs legitimately queued behind still-guaranteed hog jobs
    tail = res.response_times[0][-5:]
    assert tail and max(tail) <= 0.2 + 0.5 + 1e-9


# ---------------------------------------------------------------------------
# the adapter
# ---------------------------------------------------------------------------
def test_des_release_shedding_adapter_mirrors_gateway_limits():
    ctl = AdmissionController([0.0, 0.0], preemptive=False)
    reqs = [
        TaskRequest("a", (0.2, 0.1), period=1.0, value=2.0),
        TaskRequest("b", (0.1, 0.3), period=2.0, value=1.0),
    ]
    for r in reqs:
        assert ctl.admit(r).admitted
    mon = BacklogMonitor(margin=2.0, fallback=8)
    rs = des_release_shedding(
        get_policy("reject_newest"), ctl, reqs, monitor=mon
    )
    bounds = ctl.response_bounds()
    expect = tuple(
        mon.limit_for(bounds[r.name], r.period) for r in reqs
    )
    assert rs.limits == expect
    # classify defers to the policy with the controller's admission
    # order: 'b' (admitted last) sheds first under reject-newest
    assert rs.classify(1, (0, 1)) == SHED_DROP
    assert rs.classify(0, (0, 1)) == SHED_SUBMIT
    assert rs.classify(0, (0,)) == SHED_DROP


# ---------------------------------------------------------------------------
# property: shedding never hurts a surviving job
# ---------------------------------------------------------------------------
@st.composite
def overload_system(draw):
    """A background task plus one overdriven task on a shared stage."""
    bg_w = draw(st.floats(0.05, 0.25, allow_nan=False))
    hot_w = draw(st.floats(0.2, 0.5, allow_nan=False))
    overdrive = draw(st.floats(1.5, 3.0, allow_nan=False))
    seed = draw(st.integers(0, 10_000))
    rng = random.Random(seed)
    horizon = 30.0
    gap = hot_w / overdrive  # hot alone overruns its stage
    t, arrivals = 0.0, []
    while t < horizon:
        arrivals.append(t)
        t += gap * (0.5 + rng.random())
    bg = SimTask(segments=((0, bg_w),), period=1.0, name="bg")
    hot = SimTask(
        segments=((0, hot_w),),
        period=1.0,
        arrivals=tuple(arrivals),
        name="hot",
    )
    limit = draw(st.integers(2, 6))
    return [bg, hot], limit, horizon


@pytest.mark.property
@settings(max_examples=25, deadline=None)
@given(overload_system(), st.sampled_from(["fifo", "edf"]))
def test_property_shedding_never_slows_a_surviving_job(sys_, policy):
    """Match jobs across the with/without-shedding runs by (task,
    release): every job that survives the shedding run responds no
    later than the same job in the shed-nothing run — dropping work is
    monotone for the survivors."""
    tasks, limit, horizon = sys_
    base_cfg = dict(policy=policy, horizon=horizon, backlog_limit=2048)
    free = simulate(list(tasks), SimConfig(**base_cfg))
    shed = simulate(
        list(tasks),
        SimConfig(
            **base_cfg,
            shedding=ReleaseShedding(
                limits=(2048, limit), classify=_shed_task(1)
            ),
        ),
    )
    for i in range(len(tasks)):
        free_by_rel = dict(
            zip(free.completed_releases[i], free.response_times[i])
        )
        for rel, resp in zip(
            shed.completed_releases[i], shed.response_times[i]
        ):
            if rel in free_by_rel:
                assert resp <= free_by_rel[rel] + 1e-9, (
                    policy,
                    tasks[i].name,
                    rel,
                )
