"""End-to-end system tests: the paper's headline behaviours + training
integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.core.dse.beam import beam_search
from repro.core.dse.space import evaluate_design
from repro.core.dse.throughput import throughput_guided_design, tg_simtasks
from repro.core.perfmodel.hardware import paper_platform
from repro.core.rt.schedulability import srt_schedulable
from repro.core.workloads import PAPER_WORKLOADS, make_taskset
from repro.launch.dryrun import load_config
from repro.launch.train import train_loop
from repro.scheduler.des import SimConfig, simulate, simulate_taskset


def test_sg_beats_tg_on_schedulability():
    """Fig. 1/6 trend: SRT-guided DSE finds schedulable designs on
    tasksets where the throughput-guided baseline fails."""
    plat = paper_platform(16)
    combo = ("pointnet", "mlp_mixer")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    sg_wins, tg_wins = 0, 0
    for ratios in [(0.6, 0.6), (0.8, 0.8), (1.0, 1.0), (1.0, 0.6)]:
        ts = make_taskset(combo, ratios, plat)
        sg = beam_search(wls, ts, plat, max_m=4, beam_width=8)
        sg_ok = sg.best is not None
        tg = throughput_guided_design(wls, ts, plat, 4)
        tg_ok = simulate(
            tg_simtasks(tg, ts), SimConfig(policy="fifo")
        ).schedulable
        sg_wins += sg_ok
        tg_wins += tg_ok
    assert sg_wins >= tg_wins
    assert sg_wins > 0


def test_sg_design_passes_eq3_and_des_agrees():
    plat = paper_platform(16)
    combo = ("point_transformer", "resmlp")
    wls = [PAPER_WORKLOADS[c] for c in combo]
    ts = make_taskset(combo, (0.7, 0.7), plat)
    res = beam_search(wls, ts, plat, max_m=4, beam_width=8)
    assert res.best is not None
    table = evaluate_design(res.best.accs, res.best.splits, wls, ts)
    assert srt_schedulable(table, ts, preemptive=False)
    for policy in ("fifo", "edf"):
        sim = simulate_taskset(table, ts, policy)
        assert sim.schedulable, policy
        assert sim.max_response_overall() > 0


def test_training_loss_decreases_smoke():
    """End-to-end driver: a reduced arch learns the synthetic data."""
    cfg = smoke_config(load_config("stablelm_1_6b"))
    losses = train_loop(cfg, steps=150, global_batch=8, seq_len=64,
                        lr=1e-3, log_every=1000)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.8, (first, last)


def test_training_checkpoint_resume_identical(tmp_path):
    """Crash/restart reproduces the uninterrupted trajectory exactly."""
    cfg = smoke_config(load_config("minitron_4b"))
    kw = dict(global_batch=4, seq_len=32, log_every=1000, ckpt_every=10,
              schedule_steps=30)
    full = train_loop(cfg, steps=30, ckpt_dir=str(tmp_path / "a"), **kw)
    # run 1: stop at 20 (simulated crash after checkpoint)
    train_loop(cfg, steps=20, ckpt_dir=str(tmp_path / "b"), **kw)
    resumed = train_loop(cfg, steps=30, ckpt_dir=str(tmp_path / "b"), **kw)
    np.testing.assert_allclose(resumed[-10:], full[-10:], rtol=1e-4)
