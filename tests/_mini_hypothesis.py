"""Deterministic fallback for `hypothesis` (optional dev dependency).

The property tests only use a small strategy surface — ``integers``,
``floats``, ``lists``, ``sampled_from`` and ``composite`` — so when the
real library is unavailable we substitute a seeded random sampler with
the same decorator API. No shrinking, no database, no edge-case oracle;
each test function gets a deterministic RNG derived from its name, so
failures reproduce run-to-run. Endpoints of numeric ranges are drawn
with a small boosted probability to keep some of hypothesis's
boundary-probing flavour.

Installed into ``sys.modules`` by ``tests/conftest.py`` iff the real
``hypothesis`` import fails; install it with ``pip install -e .[dev]``
to get the real engine back.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25
_EDGE_P = 0.05  # probability of drawing an exact range endpoint


class _Strategy:
    """A sampler: ``sample(rng) -> value``."""

    def __init__(self, sample_fn):
        self._sample_fn = sample_fn

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    def sample(rng):
        r = rng.random()
        if r < _EDGE_P:
            return min_value
        if r < 2 * _EDGE_P:
            return max_value
        return rng.randint(min_value, max_value)

    return _Strategy(sample)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    *,
    allow_nan: bool | None = None,
    allow_infinity: bool | None = None,
    **_kw,
) -> _Strategy:
    def sample(rng):
        r = rng.random()
        if r < _EDGE_P:
            return float(min_value)
        if r < 2 * _EDGE_P:
            return float(max_value)
        return rng.uniform(min_value, max_value)

    return _Strategy(sample)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]

    return _Strategy(sample)


def sampled_from(seq) -> _Strategy:
    pool = list(seq)
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def composite(fn):
    """``@st.composite`` — fn(draw, *args, **kwargs) -> value."""

    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def sample(rng):
            return fn(lambda s: s.sample(rng), *args, **kwargs)

        return _Strategy(sample)

    return builder


def given(*strategies, **kw_strategies):
    def deco(fn):
        # Like real hypothesis, positional strategies fill the
        # *rightmost* parameters; anything to their left (pytest
        # fixtures) flows through untouched. Bind drawn values by name
        # so fixtures passed as keywords never collide positionally.
        all_params = list(inspect.signature(fn).parameters.values())
        n_pos = len(strategies)
        strategy_names = [p.name for p in all_params[len(all_params) - n_pos:]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {
                    name: s.sample(rng)
                    for name, s in zip(strategy_names, strategies)
                }
                drawn.update(
                    (k, s.sample(rng)) for k, s in kw_strategies.items()
                )
                fn(*args, **drawn, **kwargs)

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution: the wrapper's visible signature keeps only the
        # leading params (real fixtures).
        params = all_params[: len(all_params) - n_pos] if n_pos else all_params
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``.strategies``)."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda cond: None
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers",
        "floats",
        "lists",
        "sampled_from",
        "just",
        "booleans",
        "composite",
    ):
        setattr(st_mod, name, globals()[name])
    hyp.strategies = st_mod
    hyp.__mini_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
