"""Substrate tests: optimizer, data pipeline, checkpoint store."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import DataConfig, SyntheticTokenDataset
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    params = {"w": jnp.array([2.0, -3.0]), "b": jnp.array(1.0)}
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=300,
                      weight_decay=0.0)
    state = adamw_init(params)
    lossf = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(300):
        g = jax.grad(lossf)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(lossf(params)) < 1e-6


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, 0)) == pytest.approx(0.0)
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0, rel=1e-6)
    assert float(cosine_schedule(cfg, 55)) < 1.0
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1, rel=1e-6)
    assert float(cosine_schedule(cfg, 1000)) == pytest.approx(0.1, rel=1e-6)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(clip_norm=1.0, lr_peak=1e-3, warmup_steps=0,
                      total_steps=10, weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, metrics = adamw_update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e9, rel=1e-6)


def test_moments_are_fp32_and_bf16_params_supported():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw_init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, s2, _ = adamw_update(params, g, state, AdamWConfig())
    assert p2["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4))
def test_data_deterministic_and_host_sharded(step, hosts):
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=8)
    full = SyntheticTokenDataset(cfg).batch(step)
    again = SyntheticTokenDataset(cfg).batch(step)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    if 8 % hosts == 0:
        parts = [
            SyntheticTokenDataset(cfg, h, hosts).batch(step)["tokens"]
            for h in range(hosts)
        ]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=4)
    b = SyntheticTokenDataset(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < 64 and b["tokens"].min() >= 0


def test_data_is_learnable_signal():
    """The affine-chain structure must be (partially) predictable."""
    cfg = DataConfig(vocab=64, seq_len=64, global_batch=8, coherence=1.0)
    b = SyntheticTokenDataset(cfg).batch(0)
    pred = (31 * b["tokens"] + 7) % 64
    agree = (pred == b["labels"]).mean()
    assert agree > 0.95


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------
def _state():
    return {
        "p": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.array(3),
    }


def test_checkpoint_roundtrip(tmp_path):
    root = str(tmp_path)
    st_ = _state()
    save_checkpoint(root, 7, st_)
    assert latest_step(root) == 7
    rest = restore_checkpoint(root, 7, st_)
    np.testing.assert_array_equal(rest["p"]["w"], st_["p"]["w"])


def test_checkpoint_atomicity(tmp_path):
    """Uncommitted directories are invisible to latest_step."""
    root = str(tmp_path)
    save_checkpoint(root, 5, _state())
    fake = os.path.join(root, "step_000000009")
    os.makedirs(fake)  # no COMMITTED marker
    assert latest_step(root) == 5
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(root, 9, _state())


def test_checkpoint_structure_mismatch_fails_loud(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _state())
    other = {"p": {"DIFFERENT": jnp.zeros((2, 3))}, "step": jnp.array(0)}
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_checkpoint(root, 1, other)


def test_manager_retention_and_resume(tmp_path):
    root = str(tmp_path)
    mgr = CheckpointManager(root, every=2, keep=2)
    st_ = _state()
    for step in range(1, 9):
        mgr.maybe_save(step, st_)
    kept = sorted(n for n in os.listdir(root) if n.startswith("step_"))
    assert len(kept) == 2 and kept[-1].endswith("8")
    step, rest = mgr.restore_latest(st_)
    assert step == 8
    empty = CheckpointManager(str(tmp_path / "none"), every=1)
    step0, same = empty.restore_latest(st_)
    assert step0 == 0 and same is st_
