"""End-to-end determinism: same seed, bit-identical schedule traces.

PHAROS's cross-layer conformance story (`repro.obs.diff`,
`repro.conformance.harness`) only works because a scenario run is a
pure function of its seed: the DSE search, the seeded traffic
processes, the event-heap tie-breaks and the trace emission order are
all deterministic. The `determinism` rtlint rule (see
``docs/static-analysis.md``) guards the *sources* of nondeterminism
statically; this test guards the property end to end — build a
scenario twice from scratch with identical seeds, run the DES on both,
and require the two trace streams to be equal tuple-for-tuple,
float-for-float.

Any drift (an unsorted dict iteration, an `id()`-keyed tie-break, a
shared `random` module call) shows up here as the first diverging
event, not as a flaky conformance run three layers up.
"""
from __future__ import annotations

import pytest

from repro.core.perfmodel.hardware import paper_platform
from repro.obs.trace import EVENT_KINDS, TraceRecorder
from repro.scheduler.des import simulate_taskset
from repro.traffic.admission import AdmissionController, CRITICALITY_HI
from repro.traffic.modes import ModeController
from repro.traffic.scenarios import build, get_scenario

SCENARIOS = ("sensor_fusion", "sharded_city", "av_stack")


def _event_tuples(rec: TraceRecorder) -> list[tuple]:
    return [
        (e.seq, e.t, e.layer, e.kind, e.task, e.stage, e.shard,
         e.release, e.attrs)
        for e in rec.events
    ]


def _run_once(name: str) -> tuple[list[tuple], tuple[float, ...]]:
    """Build the scenario from scratch and run the DES with tracing."""
    built = build(get_scenario(name), paper_platform(16), beam_width=4)
    periods = tuple(t.period for t in built.taskset.tasks)
    horizon = 20.0 * max(periods)
    rec = TraceRecorder()
    # mixed-criticality scenarios run with the mode machinery armed so
    # the determinism contract covers `mode_switch` emission too
    shedding = None
    if any(r.criticality == CRITICALITY_HI for r in built.requests):
        ctl = AdmissionController(
            [0.0] * len(built.table.overhead),
            preemptive=(built.scenario.policy == "edf"),
        )
        for r in built.requests:
            ctl.admit(r)
        shedding = ModeController(ctl, list(built.requests))
    simulate_taskset(
        built.table,
        built.taskset,
        built.scenario.policy,
        horizon=horizon,
        arrivals=built.des_arrivals(horizon),
        shedding=shedding,
        trace=rec,
    )
    return _event_tuples(rec), periods


@pytest.mark.parametrize("name", SCENARIOS)
def test_trace_bit_identical_across_runs(name):
    events_a, periods_a = _run_once(name)
    events_b, periods_b = _run_once(name)
    assert periods_a == periods_b, "DSE provisioning drifted across runs"
    assert events_a, f"scenario {name!r} produced an empty trace"
    # identical lengths first: a clean count diff beats a 10k-line one
    assert len(events_a) == len(events_b)
    for i, (ea, eb) in enumerate(zip(events_a, events_b)):
        assert ea == eb, (
            f"first trace divergence at event {i}:\n  a={ea}\n  b={eb}"
        )


@pytest.mark.parametrize("name", SCENARIOS)
def test_trace_kinds_are_canonical(name):
    """Every emitted kind is in the lint-enforced vocabulary (the
    dynamic twin of rtlint's `trace-vocab` rule)."""
    events, _ = _run_once(name)
    emitted = {e[3] for e in events}
    assert emitted <= set(EVENT_KINDS), (
        f"non-canonical kinds emitted: {sorted(emitted - set(EVENT_KINDS))}"
    )


# ---------------------------------------------------------------------------
# elastic serving: migration + autoscaling must be deterministic too
# ---------------------------------------------------------------------------
def _run_elastic_once(name: str = "sharded_city"):
    """Build from scratch and run the full elastic stack with tracing:
    a live migration over the shared-clock co-simulation, then an
    autoscaled ramp (grow + drain-and-shrink) over the same scenario."""
    from repro.traffic import (
        Autoscaler,
        MigrationController,
        MigrationPlan,
        RampPhase,
        ShardedGateway,
    )

    built = build(get_scenario(name), paper_platform(16), beam_width=4)
    pmax = max(t.period for t in built.taskset.tasks)
    horizon = 15.0 * pmax

    mig_rec = TraceRecorder()
    gw = ShardedGateway.from_built(
        built,
        shards=2,
        placement="least_loaded",
        elastic=True,
        trace=mig_rec,
    )
    mc = MigrationController(
        [MigrationPlan(tenant=built.requests[0].name, at=0.3 * horizon)],
        trace=mig_rec,
    )
    gw.run(horizon, controller=mc)

    ramp_rec = TraceRecorder()
    scaler = Autoscaler(
        built, min_shards=1, max_shards=2, trace=ramp_rec
    )
    ramp = scaler.run_ramp(
        [
            RampPhase(duration=6.0 * pmax, active=(0, 1)),
            RampPhase(duration=6.0 * pmax, active=(0, 1, 2, 3)),
            RampPhase(duration=6.0 * pmax, active=(0,)),
        ]
    )
    return (
        _event_tuples(mig_rec),
        _event_tuples(ramp_rec),
        mc.final_assignment(),
        [(r.tenant, r.committed, r.target) for r in mc.records],
        ramp.shard_counts(),
        ramp.final_assignment(),
    )


def test_elastic_ramp_trace_bit_identical_across_runs():
    """Autoscaling + a live migration, built and simulated twice from
    scratch: bit-identical trace streams and identical final shard
    plans. Elasticity must not introduce a nondeterministic tie-break
    anywhere in drain / proof / commit / grow / shrink."""
    a = _run_elastic_once()
    b = _run_elastic_once()
    for field_a, field_b in zip(a[2:], b[2:]):
        assert field_a == field_b
    for events_a, events_b in ((a[0], b[0]), (a[1], b[1])):
        assert events_a  # the elastic machinery actually traced
        assert len(events_a) == len(events_b)
        for i, (ea, eb) in enumerate(zip(events_a, events_b)):
            assert ea == eb, (
                f"first trace divergence at event {i}:\n  a={ea}\n  b={eb}"
            )


def test_elastic_trace_kinds_are_canonical_and_migration_visible():
    mig_events, ramp_events, _, records, counts, _ = _run_elastic_once()
    emitted = {e[3] for e in mig_events} | {e[3] for e in ramp_events}
    assert emitted <= set(EVENT_KINDS), (
        f"non-canonical kinds emitted: {sorted(emitted - set(EVENT_KINDS))}"
    )
    # the migration protocol left its mark in the vocabulary
    assert {e[3] for e in mig_events} >= {"migrate_start", "migrate_commit"}
    assert any(committed for _, committed, _ in records)
    assert len(counts) == 3
