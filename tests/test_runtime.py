"""Distributed-runtime tests: fault tolerance, stragglers, compression,
elastic re-meshing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.runtime import (
    ErrorFeedbackState,
    FaultTolerantLoop,
    HeartbeatMonitor,
    StragglerMitigator,
    WorkerState,
    compress_gradients,
    decompress_gradients,
    plan_remesh,
)
from repro.runtime.compression import compression_ratio


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def _step_fn(step, state):
    return {"x": state["x"] + step, "rng": state["rng"] * 31 % 10007}


def test_ft_loop_recovers_and_matches_clean_run(tmp_path):
    init = {"x": jnp.array(0), "rng": jnp.array(7)}
    clean_mgr = CheckpointManager(str(tmp_path / "clean"), every=3)
    clean, _ = FaultTolerantLoop(clean_mgr, _step_fn).run(init, 20)

    fail_at = {5, 11, 17}
    seen = set()

    def hook(step):
        if step in fail_at and step not in seen:
            seen.add(step)
            return True
        return False

    mgr = CheckpointManager(str(tmp_path / "faulty"), every=3)
    state, report = FaultTolerantLoop(mgr, _step_fn, failure_hook=hook).run(
        init, 20
    )
    assert report.restarts == 3
    assert report.failures_seen == 3
    assert report.resumed_from  # actually resumed from checkpoints
    # deterministic recovery: same final state as the clean run
    assert int(state["x"]) == int(clean["x"])
    assert int(state["rng"]) == int(clean["rng"])


def test_ft_loop_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=100)
    loop = FaultTolerantLoop(
        mgr, _step_fn, failure_hook=lambda s: s == 0, max_restarts=2
    )
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.array(0), "rng": jnp.array(1)}, 5)


def test_heartbeat_state_machine():
    t = [0.0]
    mon = HeartbeatMonitor(
        ["w0", "w1"], suspect_after=5, dead_after=15, clock=lambda: t[0]
    )
    t[0] = 4.0
    assert mon.sweep()["w0"] is WorkerState.HEALTHY
    t[0] = 6.0
    assert mon.sweep()["w0"] is WorkerState.SUSPECT
    mon.beat("w0")
    assert mon.sweep()["w0"] is WorkerState.HEALTHY
    t[0] = 25.0
    states = mon.sweep()
    assert states["w1"] is WorkerState.DEAD
    assert mon.dead() and mon.healthy_count() == 0  # w0 silent since 6.0


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------
def test_straggler_detection_and_escalation():
    m = StragglerMitigator(["a", "b", "c", "d"], threshold=1.5, miss_budget=3)
    for _ in range(10):
        for w in "abc":
            m.observe(w, 1.0)
        m.observe("d", 3.0)
    r1 = m.assess()
    assert r1.stragglers == ["d"]
    assert r1.actions["d"] == "backup"
    m.assess()
    r3 = m.assess()
    assert r3.actions["d"] == "exclude"  # exceeded miss budget


def test_straggler_recovers():
    m = StragglerMitigator(["a", "b", "c"], threshold=1.5, ewma=1.0)
    for w in "ab":
        m.observe(w, 1.0)
    m.observe("c", 5.0)
    assert m.assess().stragglers == ["c"]
    m.observe("c", 1.0)
    assert m.assess().stragglers == []


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_compression_roundtrip_error_bounded():
    g = {"w": jnp.linspace(-3, 3, 256).reshape(16, 16)}
    payload, _ = compress_gradients(g)
    rec = decompress_gradients(payload)
    assert payload["q"]["w"].dtype == jnp.int8
    err = float(jnp.abs(rec["w"] - g["w"]).max())
    assert err <= float(jnp.abs(g["w"]).max()) / 127.0 + 1e-6
    assert compression_ratio(g) > 3.5


def test_error_feedback_preserves_mean_signal():
    """EF: accumulated compressed grads converge to accumulated truth."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (32,)) * 1e-3}
    ef = ErrorFeedbackState.init(g)
    total_true = jnp.zeros(32)
    total_sent = jnp.zeros(32)
    for i in range(50):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        payload, ef = compress_gradients(gi, ef)
        total_sent += decompress_gradients(payload)["w"]
        total_true += gi["w"]
    # residual carries over; totals differ by at most the last residual
    gap = float(jnp.abs(total_sent - total_true).max())
    last_res = float(jnp.abs(ef.residual["w"]).max())
    assert gap <= last_res + 1e-6


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------
@settings(max_examples=80, deadline=None)
@given(
    st.integers(1, 512),
    st.sampled_from([1, 2, 4, 8, 16]),
    st.sampled_from([64, 128, 256]),
    st.sampled_from([1, 2, 4, 8, 16]),
)
def test_elastic_plan_invariants(chips, tp, global_batch, old_dp):
    plan = plan_remesh(
        chips,
        model_parallel=tp,
        global_batch=global_batch,
        old_data_parallel=old_dp,
    )
    if not plan.valid:
        assert chips < tp
        return
    assert plan.chips_used <= chips
    assert plan.model_parallel == tp  # TP degree preserved (weight shapes)
    assert global_batch % plan.data_parallel == 0
    # capacity conservation: dp * accum >= old dp (global batch kept)
    assert plan.data_parallel * plan.grad_accumulation >= old_dp


def test_elastic_shrink_example():
    plan = plan_remesh(
        200, model_parallel=16, global_batch=256, old_data_parallel=16
    )
    assert plan.data_parallel == 12 or plan.data_parallel <= 12
    assert plan.chips_used <= 200
    assert 256 % plan.data_parallel == 0
