"""Per-kernel correctness: shape/dtype sweeps against the ref.py oracles
(interpret mode executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.preemptible_matmul import (
    grid_geometry,
    matmul,
    matmul_resumable,
    matmul_window,
    pick_window,
)
from repro.kernels.preemptible_matmul.ref import (
    matmul_partial_ref,
    matmul_ref,
    matmul_window_ref,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

BLOCK = (128, 128, 128)


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-9)


# ---------------------------------------------------------------------------
# preemptible matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "M,K,N", [(128, 128, 128), (256, 128, 384), (384, 256, 256)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pmm_full_product(M, K, N, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    got = matmul(a, b, block=BLOCK, window_tiles=2)
    want = matmul_ref(a, b)
    assert _rel_err(got, want) < (1e-5 if dtype == jnp.float32 else 2e-2)


@pytest.mark.parametrize("window", [1, 2, 3, 6])
def test_pmm_window_oracle(window):
    M, K, N = 256, 128, 384  # 2x3 = 6 tiles
    a = jax.random.normal(jax.random.PRNGKey(2), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (K, N), jnp.float32)
    w = pick_window(6, window)
    c = jnp.zeros((M, N), jnp.float32)
    for start in range(0, 6, w):
        got, nxt = matmul_window(a, b, c, start, block=BLOCK, window_tiles=w)
        want = matmul_window_ref(a, b, c, start, w, BLOCK)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        c = got
    np.testing.assert_allclose(c, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_pmm_preempt_resume_identity():
    """Preempting between windows and resuming is exact (paper §3.4)."""
    M, K, N = 256, 256, 256
    a = jax.random.normal(jax.random.PRNGKey(4), (M, K), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(5), (K, N), jnp.bfloat16)
    c1, prog = matmul_resumable(a, b, block=BLOCK, window_tiles=1, max_windows=3)
    assert not prog.done and prog.next_tile == 3
    np.testing.assert_allclose(
        c1, matmul_partial_ref(a, b, 3, BLOCK), rtol=1e-2, atol=1e-2
    )
    # interleave: run an unrelated job (separate buffers), then resume
    other, _ = matmul_resumable(b, a, block=BLOCK, window_tiles=2)
    c2, prog2 = matmul_resumable(
        a, b, block=BLOCK, window_tiles=1, start_tile=prog.next_tile, c_acc=c1
    )
    assert prog2.done
    assert _rel_err(c2, matmul_ref(a, b)) < 2e-2


def test_pmm_geometry_and_window_picker():
    n_m, n_n, k_steps, total = grid_geometry(384, 256, 128, BLOCK)
    assert (n_m, n_n, k_steps, total) == (3, 2, 1, 6)
    assert pick_window(6, 4) == 3  # largest divisor <= 4
    assert pick_window(6, 7) == 6
    assert pick_window(5, 2) == 1
    with pytest.raises(ValueError):
        grid_geometry(100, 128, 128, BLOCK)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,bq,bk", [(128, 64, 64), (256, 64, 128), (64, 64, 64)])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_sweep(S, bq, bk, H, Hkv):
    B, hd = 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v)
    assert _rel_err(got, want) < 1e-5


def test_flash_attention_bf16_and_noncausal():
    B, S, H, hd = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=False)
    assert _rel_err(got, want) < 3e-2


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_mamba_scan_sweep(S, chunk):
    B, di, ns = 2, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    Bm = jax.random.normal(ks[1], (B, S, ns))
    Cm = jax.random.normal(ks[2], (B, S, ns))
    x = jax.random.normal(ks[3], (B, S, di))
    A = -jnp.abs(jax.random.normal(ks[4], (di, ns)))
    y, h = mamba_scan(dt, Bm, Cm, x, A, chunk=chunk)
    yr, hr = mamba_scan_ref(dt, Bm, Cm, x, A)
    assert _rel_err(y, yr) < 1e-4
    assert _rel_err(h, hr) < 1e-4


def test_mamba_scan_carry_chaining():
    """Scanning two halves with carried h equals one full scan."""
    B, S, di, ns = 1, 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    Bm = jax.random.normal(ks[1], (B, S, ns))
    Cm = jax.random.normal(ks[2], (B, S, ns))
    x = jax.random.normal(ks[3], (B, S, di))
    A = -jnp.abs(jax.random.normal(ks[4], (di, ns)))
    y_full, h_full = mamba_scan(dt, Bm, Cm, x, A, chunk=8)
    half = S // 2
    y1, h1 = mamba_scan(dt[:, :half], Bm[:, :half], Cm[:, :half], x[:, :half], A, chunk=8)
    y2, h2 = mamba_scan(dt[:, half:], Bm[:, half:], Cm[:, half:], x[:, half:], A, h0=h1, chunk=8)
    np.testing.assert_allclose(
        np.concatenate([y1, y2], axis=1), np.asarray(y_full), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (32, 32)])
def test_rwkv6_scan_sweep(S, chunk):
    B, H, hd = 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, hd))
    logit = jnp.clip(jax.random.normal(ks[3], (B, S, H, hd)), -8, -1)
    w = jnp.exp(-jnp.exp(logit))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    y, sf = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    yr, sr = rwkv6_scan_ref(r, k, v, w, u)
    assert _rel_err(y, yr) < 1e-4
    assert _rel_err(sf, sr) < 1e-4
